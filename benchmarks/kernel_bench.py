"""Kernel benchmarks.  On this CPU container Pallas runs in interpret mode,
so wall-clock favours the jnp reference — the meaningful numbers here are
(a) correctness deltas vs the oracle at serving-relevant shapes, and
(b) the analytic per-tile VMEM footprint + arithmetic intensity that the
BlockSpecs claim on the TPU target (checked against the 16 MiB v5e VMEM
budget).  Real-TPU wall-time belongs to the roofline table (§Roofline)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

VMEM_BUDGET = 16 * 2 ** 20        # v5e per-core VMEM


def _time(fn, *args, reps=3) -> float:
    fn(*args)                                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> List[Tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)

    # flash attention: tile VMEM + error at a serving shape
    from repro.kernels.flash_attention import flash_attention as fk
    from repro.kernels.flash_attention import ops as fops
    from repro.kernels.flash_attention import ref as fref
    bq, bk, dh = fk.DEFAULT_BLOCK_Q, fk.DEFAULT_BLOCK_K, 128
    vmem = (bq * dh + 2 * bk * dh) * 4 + (bq * dh + 2 * bq) * 4 \
        + bq * bk * 4
    q = jnp.asarray(rng.standard_normal((1, 512, 8, dh)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, dh)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, dh)), jnp.bfloat16)
    t_k = _time(lambda: fops.flash_attention(q, k, v))
    err = float(jnp.max(jnp.abs(
        fops.flash_attention(q, k, v).astype(jnp.float32)
        - fref.gqa_attention(q, k, v).astype(jnp.float32))))
    # causal flash: ~(S^2/2)*4*H*Dh flops over (S^2)*Hkv*Dh*2*2 ref bytes
    intensity = (0.5 * 4 * dh) / (2 * 2)
    rows.append(("kernel/flash_attn_512", t_k * 1e6,
                 f"vmem_tile={vmem/2**20:.2f}MiB_of_16MiB_"
                 f"err={err:.1e}_AI={intensity:.0f}f/B"))
    assert vmem < VMEM_BUDGET

    # rwkv6 chunked scan
    from repro.kernels.rwkv6_scan import ops as rops
    from repro.kernels.rwkv6_scan import ref as rref
    b, s, h, d = 1, 256, 4, 64
    r_ = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k_ = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v_ = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    w_ = jnp.asarray(rng.uniform(0.9, 0.999, (b, s, h, d)), jnp.float32)
    u_ = jnp.asarray(rng.standard_normal((h, d)), jnp.float32)
    t_k = _time(lambda: rops.wkv6(r_, k_, v_, w_, u_))
    y, _ = rops.wkv6(r_, k_, v_, w_, u_)
    yr, _ = rref.wkv6(r_, k_, v_, w_, u_,
                      jnp.zeros((b, h, d, d), jnp.float32))
    err = float(jnp.max(jnp.abs(y - yr)))
    chunk_vmem = (4 * 64 * d + d * d + 64 * 64) * 4
    rows.append(("kernel/rwkv6_scan_256", t_k * 1e6,
                 f"vmem_tile={chunk_vmem/2**20:.3f}MiB_err={err:.1e}"))

    # mamba selective scan
    from repro.kernels.mamba_scan import ops as mops
    from repro.kernels.mamba_scan import ref as mref
    b, s, di, n = 1, 128, 256, 16
    u2 = jnp.asarray(rng.standard_normal((b, s, di)), jnp.float32)
    dt2 = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, di)), jnp.float32)
    a2 = jnp.asarray(-rng.uniform(0.5, 2, (di, n)), jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    c2 = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    t_k = _time(lambda: mops.selective_scan(u2, dt2, a2, b2, c2))
    y, _ = mops.selective_scan(u2, dt2, a2, b2, c2)
    yr, _ = mref.selective_scan(u2, dt2, a2, b2, c2,
                                jnp.zeros((b, di, n), jnp.float32))
    err = float(jnp.max(jnp.abs(y - yr)))
    rows.append(("kernel/mamba_scan_128", t_k * 1e6, f"err={err:.1e}"))

    # quant cast: wire-byte reduction
    from repro.kernels.quant_cast import ops as qops
    x = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    t_k = _time(lambda: qops.quantize(x))
    qv, sc = qops.quantize(x)
    ratio = x.nbytes / (qv.nbytes + sc.nbytes)
    back = qops.dequantize(qv, sc, x.shape)
    err = float(jnp.max(jnp.abs(back - x)))
    rows.append(("kernel/quant_cast_64k", t_k * 1e6,
                 f"compress={ratio:.2f}x_err={err:.2e}"))

    # serial vs concurrent kernel dispatch: 8 independent quant casts run
    # back-to-back vs overlapped on a 4-thread pool (the executor's
    # cast-migration concurrency, measured at the kernel level).  Reported,
    # not asserted — on a GIL-bound CPU interpret path the ratio can dip
    # below 1; on device backends dispatch overlap wins.
    from concurrent.futures import ThreadPoolExecutor
    xs = [jnp.asarray(rng.standard_normal(1 << 14), jnp.float32)
          for _ in range(8)]
    for x_ in xs:
        jax.block_until_ready(qops.quantize(x_))          # compile once

    def _serial():
        for x_ in xs:
            jax.block_until_ready(qops.quantize(x_))

    def _concurrent():
        with ThreadPoolExecutor(max_workers=4) as pool:
            list(pool.map(
                lambda x_: jax.block_until_ready(qops.quantize(x_)), xs))

    t_serial = _time(_serial)
    t_conc = _time(_concurrent)
    rows.append(("kernel/quant_cast_8x_concurrent", t_conc * 1e6,
                 f"serial_us={t_serial*1e6:.1f}_"
                 f"speedup={t_serial/max(t_conc, 1e-12):.2f}x"))
    return rows
