"""Migration-route matrix (paper §V.C): binary vs staged vs quant casts
across object sizes — bytes/second per route.  The binary:staged gap is the
paper's 'efficient binary migration' claim; quant shows the beyond-paper
int8 re-coding cast (4x wire-byte reduction at bounded error)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm
from repro.core.api import default_deployment
from repro.core.migrator import MigrationParams


def run(sizes=(1_000, 30_000), reps: int = 5) -> List[Tuple[str, float,
                                                            str]]:
    bd = default_deployment()
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        table = dm.Table({
            "id": jnp.asarray(np.arange(n)),
            "val": jnp.asarray(rng.standard_normal(n)),
        })
        bd.engines["hoststore0"].put(f"tbl_{n}", table)
        nbytes = table.nbytes()
        for method in ("binary", "staged", "quant"):
            dst = bd.engines["kvstore0" if method == "quant"
                             else "densehbm0"]
            ts = []
            for i in range(reps):
                t0 = time.perf_counter()
                bd.migrator.migrate(
                    bd.engines["hoststore0"], f"tbl_{n}", dst,
                    f"out_{method}_{n}_{i}",
                    MigrationParams(method=method))
                ts.append(time.perf_counter() - t0)
            med = float(np.median(ts))
            rows.append((f"migration/{method}_n{n}", med * 1e6,
                         f"MBps={nbytes/med/1e6:.1f}"))
    return rows
