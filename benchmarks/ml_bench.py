"""ML inference benchmark: scored windows/second delivered to N
synthetic tenants whose identical ``bdml(infer(...))`` subscriptions
share ONE standing-query execution (and one wave) per tick through the
``FrontDoor``, against the same N tenants each running an independent
direct ``register_continuous`` scored query (N model forwards per
tick).  The ``ml/infer_tick`` row is **ratio-type**: both rates are
measured in the same pass on the same host, so runner speed (and the
one-time jit compile, which both sides share through the process-wide
params cache) cancels out — the ratio is the warm-sharing win over the
model-bound tick and grows with the tenant count.  The absolute rates
ride along in the ``derived`` column and ``LAST_META``."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

TENANTS = 4
TICKS = 8
WINDOW = 16
PASSES = 2
QUERY = f"bdml(infer(window(ml.bench, {WINDOW}), models.lm))"

# set by run(): tenant/tick config + measured rates — read by
# benchmarks.run to stamp the JSON report's ml metadata
LAST_META: Dict[str, object] = {}


def _batches() -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(23)
    return [{"ts": np.arange(float(WINDOW)) + i * WINDOW,
             "v": 60.0 + 5.0 * rng.standard_normal(WINDOW)}
            for i in range(TICKS)]


def _frontdoor_rate(batches) -> float:
    """Scored windows/sec to TENANTS tenants via the front door — one
    shared infer execution (one model forward) per tick."""
    from repro.core.api import default_deployment
    from repro.serve.engine import ServeConfig
    from repro.serve.frontdoor import FrontDoor
    from repro.stream.spec import StreamSpec

    bd = default_deployment()
    bd.register_model("lm")
    door = FrontDoor(bd, ServeConfig(streams=(
        StreamSpec("ml.bench", ("ts", "v"), capacity=4 * WINDOW),)),
        stream_engine="streamstore0", max_tenants=TENANTS,
        result_buffer=TICKS + 1)
    subs = [door.open_session(f"tenant{i}").subscribe(QUERY)
            for i in range(TENANTS)]
    stream = bd.engines["streamstore0"].get("ml.bench")
    stream.append(batches[0])
    bd.streams.tick()                 # warm the plan cache + jit forward
    for sub in subs:
        sub.poll()
    t0 = time.perf_counter()
    for batch in batches[1:]:
        stream.append(batch)
        bd.streams.tick()
    dt = time.perf_counter() - t0
    delivered = sum(len(sub.poll()) for sub in subs)
    assert delivered == TENANTS * (TICKS - 1)
    door.close()
    return delivered / dt


def _direct_rate(batches) -> float:
    """Scored windows/sec with every tenant running its own direct
    standing query — N model forwards per tick, the no-sharing
    baseline."""
    from repro.core.api import default_deployment
    from repro.stream.spec import StreamSpec

    bd = default_deployment()
    bd.register_model("lm")
    bd.register_stream("streamstore0", StreamSpec(
        "ml.bench", ("ts", "v"), capacity=4 * WINDOW))
    for i in range(TENANTS):
        bd.streams.register_continuous(QUERY, name=f"direct{i}")
    stream = bd.engines["streamstore0"].get("ml.bench")
    stream.append(batches[0])
    bd.streams.tick()                 # warm the plan cache + jit forward
    t0 = time.perf_counter()
    for batch in batches[1:]:
        stream.append(batch)
        bd.streams.tick()
    dt = time.perf_counter() - t0
    return TENANTS * (TICKS - 1) / dt


def run() -> List[Tuple]:
    batches = _batches()
    # best-of-PASSES on each side: CPU-steal bursts cannot poison the
    # self-normalized ratio (same policy as serve/tenants_qps)
    fd_best = max(_frontdoor_rate(batches) for _ in range(PASSES))
    direct_best = max(_direct_rate(batches) for _ in range(PASSES))
    ratio = fd_best / direct_best
    from repro.stream import ml
    stats = ml.stats()
    LAST_META.clear()
    LAST_META.update({
        "tenants": TENANTS, "ticks": TICKS, "window": WINDOW,
        "frontdoor_windows_per_s": round(fd_best, 1),
        "direct_windows_per_s": round(direct_best, 1),
        "params_cache_hits": stats["params_cache_hits"],
        "waves": stats["waves"],
        "ratio": round(ratio, 3)})
    return [("ml/infer_tick", ratio,
             f"tenants={TENANTS} frontdoor={fd_best:.0f}/s "
             f"direct={direct_best:.0f}/s window={WINDOW}", "ratio")]


if __name__ == "__main__":
    for name, value, derived, kind in run():
        print(f"{name},{value:.3f},{derived}")
