"""Paper Fig. 5 reproduction: task-timing breakdown of an inter-island
(array <- relational) query.  Reports per-stage medians over N runs and the
middleware-overhead fraction (paper claims engine exec + migration ~ 75%,
middleware ~ 10%, mostly planning)."""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.core.api import default_deployment
from repro.data.mimic import load_mimic_demo

QUERY = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
         " mimic2v26.poe_order), poe_order_copy,"
         " '<subject_id:int32>[poe_id=0:*,10000000,0]', array)))")

# middleware = everything that isn't engine execution or data transfer.
# Lean-mode queries now come in two planning flavours: a plan-cache hit
# ("Plan cache hit") or a miss ("Plan enumeration" + "Monitor lookup");
# both count toward the paper's middleware fraction.
MIDDLEWARE_STAGES = ("Parse", "Plan enumeration", "Monitor lookup",
                     "Plan cache hit", "Migrator dispatch")


def run(runs: int = 50, num_orders: int = 8192) -> List[Tuple[str, float,
                                                              str]]:
    bd = default_deployment()
    load_mimic_demo(bd, num_orders=num_orders)
    bd.query(QUERY, training=True)              # train once (paper flow)

    stage_times: Dict[str, List[float]] = defaultdict(list)
    totals = []
    for _ in range(runs):
        r = bd.query(QUERY)
        for name, s in r.stages:
            stage_times[name].append(s)
        totals.append(r.seconds)

    total_med = float(np.median(totals))
    rows = []
    mid = 0.0
    for name, ts in stage_times.items():
        med = float(np.median(ts))
        frac = med / total_med if total_med else 0.0
        rows.append((f"fig5/{name.replace(' ', '_')}", med * 1e6,
                     f"frac={frac:.3f}"))
        if name in MIDDLEWARE_STAGES:
            mid += med
    rows.append(("fig5/total", total_med * 1e6, "frac=1.000"))
    rows.append(("fig5/middleware_overhead", mid * 1e6,
                 f"frac={mid/total_med:.3f}"))
    return rows
