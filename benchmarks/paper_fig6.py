"""Paper Fig. 6 reproduction: execution-time distributions for eight query
classes x N runs — single-island vs intra-island-migration vs cross-island-
migration queries.  Expected ordering (paper §VII): migration queries are
slower; same-data-model (binary) migration is fast; cross-island staged
migration pays format translation."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.api import default_deployment
from repro.data.mimic import load_mimic_demo

QUERIES = {
    "q1_rel_limit": "bdrel(select * from mimic2v26.d_patients limit 4)",
    "q2_rel_filter": ("bdrel(select poe_id, dose from mimic2v26.poe_order"
                      " where dose > 25)"),
    "q3_rel_groupby": ("bdrel(select sex, avg(dob_year) from"
                       " mimic2v26.d_patients group by sex)"),
    "q4_array_filter": "bdarray(filter(myarray, dim1>150))",
    "q5_array_agg": "bdarray(aggregate(mimic2v26.waveform, avg(signal)))",
    "q6_text_range": ("bdtext({ 'op' : 'range', 'table' : 'mimic_logs',"
                      " 'range' : { 'start' : ['r_0001','',''],"
                      " 'end' : ['r_0015','',''] } })"),
    "q7_cast_rel_to_array": (
        "bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
        " mimic2v26.poe_order), c7,"
        " '<subject_id:int32>[poe_id=0:*,10000000,0]', array)))"),
    "q8_cast_array_to_rel": (
        "bdrel(select * from bdcast(bdarray(filter(myarray, dim1>10)),"
        " c8, '', relational) limit 5)"),
}

MIGRATION_CLASSES = ("q7_cast_rel_to_array", "q8_cast_array_to_rel")


def run(runs: int = 50) -> List[Tuple[str, float, str]]:
    bd = default_deployment()
    load_mimic_demo(bd, num_orders=4096)
    rows = []
    medians = {}
    for name, q in QUERIES.items():
        bd.query(q, training=True)
        ts = []
        for _ in range(runs):
            r = bd.query(q)
            ts.append(sum(s for n, s in r.stages))
        ts = np.asarray(ts)
        medians[name] = float(np.median(ts))
        rows.append((f"fig6/{name}", float(np.median(ts)) * 1e6,
                     f"p25={np.percentile(ts,25)*1e6:.0f}us_"
                     f"p75={np.percentile(ts,75)*1e6:.0f}us"))
    single = [v for k, v in medians.items() if k not in MIGRATION_CLASSES]
    mig = [v for k, v in medians.items() if k in MIGRATION_CLASSES]
    rows.append(("fig6/check_migration_slower",
                 0.0,
                 f"median_mig={np.median(mig)*1e6:.0f}us>"
                 f"median_single={np.median(single)*1e6:.0f}us="
                 f"{np.median(mig) > np.median(single)}"))
    return rows
