"""Planner/Monitor benchmarks (paper §V.B/§V.E): training-mode exploration
cost vs lean-mode steady-state (now through the signature-keyed plan
cache), monitor lookup latency, closest-signature hit quality on perturbed
queries, and the concurrent executor's critical-path vs serial-sum numbers
on a cross-engine two-branch plan."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import bql, signatures
from repro.core.api import default_deployment
from repro.data.mimic import load_mimic_demo

BASE = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
        " mimic2v26.poe_order), c, "
        "'<subject_id:int32>[poe_id=0:*,1000,0]', array)))")
PERTURBED = [
    BASE.replace("subject_id", "icustay_id"),
    BASE.replace("0:*,1000,0", "0:*,5000,0"),
    ("bdarray(scan(bdcast(bdrel(select poe_id, dose from"
     " mimic2v26.poe_order where dose > 10), c,"
     " '<dose:double>[poe_id=0:*,1000,0]', array)))"),
]
# two independent sub-queries on different engines feeding one array join:
# the DAG executor overlaps the branches (critical path < serial sum)
CROSS = (
    "bdarray(cross_join("
    "bdcast(bdrel(select subject_id, dob_year from mimic2v26.d_patients),"
    " pat_arr, '<dob_year:int32>[subject_id=0:*,1000,0]', array),"
    "bdcast(bdrel(select poe_id, dose from mimic2v26.poe_order),"
    " ord_arr, '<dose:double>[poe_id=0:*,1000,0]', array)))")


def run(runs: int = 20) -> List[Tuple[str, float, str]]:
    bd = default_deployment()
    load_mimic_demo(bd, num_orders=2048)
    rows = []

    t0 = time.perf_counter()
    r = bd.query(BASE, training=True)
    t_train = time.perf_counter() - t0
    rows.append(("planner/training_mode", t_train * 1e6,
                 f"plans={r.plans_considered}"))

    ts = []
    for _ in range(runs):
        t0 = time.perf_counter()
        bd.query(BASE)
        ts.append(time.perf_counter() - t0)
    cache_stats = bd.planner.plan_cache.stats()
    rows.append(("planner/lean_mode", float(np.median(ts)) * 1e6,
                 f"speedup={t_train/np.median(ts):.1f}x"))
    rows.append(("planner/plan_cache", float(np.median(ts)) * 1e6,
                 f"hits={cache_stats['hits']}_"
                 f"misses={cache_stats['misses']}_"
                 f"stale={cache_stats['stale_evictions']}"))

    # concurrent DAG executor on a cross-engine two-branch plan: report the
    # overlap-aware critical path against the Fig-5 serial-sum, plus the
    # measured wall-clock of serial vs concurrent scheduling
    from repro.core.executor import QueryExecutionPlan, assign_ids
    root = bql.parse(CROSS)
    nodes, casts = assign_ids(root)
    # pin the two relational branches to different engines (d_patients on
    # hoststore0, poe_order replica on hoststore1, join on densehbm0)
    plan = QueryExecutionPlan(
        root=root,
        node_engines={0: "hoststore0", 1: "hoststore1", 2: "densehbm0"},
        cast_methods={cid: "binary" for cid in casts})
    ex = bd.planner.executor
    ex.execute_plan(plan, mode="serial")      # warm jit caches untimed
    r_serial = ex.execute_plan(plan, mode="serial")
    r_conc = ex.execute_plan(plan, mode="concurrent")
    serial_sum = r_conc.serial_sum_seconds
    crit = r_conc.critical_path_seconds
    rows.append(("executor/serial_sum", serial_sum * 1e6,
                 "sum_of_all_stage_times"))
    rows.append(("executor/critical_path", crit * 1e6,
                 f"overlap_speedup={serial_sum/max(crit, 1e-12):.2f}x"))
    rows.append(("executor/wall_concurrent", r_conc.wall_seconds * 1e6,
                 f"serial_wall_us={r_serial.wall_seconds*1e6:.1f}_"
                 f"wall_speedup="
                 f"{r_serial.wall_seconds/max(r_conc.wall_seconds, 1e-12):.2f}x"))

    # monitor signature matching on perturbed queries
    base_sig = signatures.of_query(bql.parse(BASE))
    hits = 0
    lookup_ts = []
    for q in PERTURBED:
        sig = signatures.of_query(bql.parse(q))
        t0 = time.perf_counter()
        closest = bd.monitor.get_closest_signature(sig)
        lookup_ts.append(time.perf_counter() - t0)
        if closest is not None and closest.distance(base_sig) < 1e-9:
            hits += 1
    rows.append(("monitor/closest_signature",
                 float(np.median(lookup_ts)) * 1e6,
                 f"hits={hits}/{len(PERTURBED)}"))
    return rows
