"""Planner/Monitor benchmarks (paper §V.B/§V.E): training-mode exploration
cost vs lean-mode steady-state, monitor lookup latency, and closest-
signature hit quality on perturbed queries."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import bql, signatures
from repro.core.api import default_deployment
from repro.data.mimic import load_mimic_demo

BASE = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
        " mimic2v26.poe_order), c, "
        "'<subject_id:int32>[poe_id=0:*,1000,0]', array)))")
PERTURBED = [
    BASE.replace("subject_id", "icustay_id"),
    BASE.replace("0:*,1000,0", "0:*,5000,0"),
    ("bdarray(scan(bdcast(bdrel(select poe_id, dose from"
     " mimic2v26.poe_order where dose > 10), c,"
     " '<dose:double>[poe_id=0:*,1000,0]', array)))"),
]


def run(runs: int = 20) -> List[Tuple[str, float, str]]:
    bd = default_deployment()
    load_mimic_demo(bd, num_orders=2048)
    rows = []

    t0 = time.perf_counter()
    r = bd.query(BASE, training=True)
    t_train = time.perf_counter() - t0
    rows.append(("planner/training_mode", t_train * 1e6,
                 f"plans={r.plans_considered}"))

    ts = []
    for _ in range(runs):
        t0 = time.perf_counter()
        bd.query(BASE)
        ts.append(time.perf_counter() - t0)
    rows.append(("planner/lean_mode", float(np.median(ts)) * 1e6,
                 f"speedup={t_train/np.median(ts):.1f}x"))

    # monitor signature matching on perturbed queries
    base_sig = signatures.of_query(bql.parse(BASE))
    hits = 0
    lookup_ts = []
    for q in PERTURBED:
        sig = signatures.of_query(bql.parse(q))
        t0 = time.perf_counter()
        closest = bd.monitor.get_closest_signature(sig)
        lookup_ts.append(time.perf_counter() - t0)
        if closest is not None and closest.distance(base_sig) < 1e-9:
            hits += 1
    rows.append(("monitor/closest_signature",
                 float(np.median(lookup_ts)) * 1e6,
                 f"hits={hits}/{len(PERTURBED)}"))
    return rows
