"""Regenerates the generated sections of EXPERIMENTS.md (§Dry-run table,
§Roofline table) from experiments/dryrun_results.jsonl.

  PYTHONPATH=src python -m benchmarks.render_report
"""
from __future__ import annotations

import json
import re

from benchmarks import roofline

EXP = "EXPERIMENTS.md"


def dryrun_table() -> str:
    rows = {}
    for cell in roofline.load_cells():
        rows[(cell["arch"], cell["shape"], cell["mesh"])] = cell
    lines = [
        "| arch | shape | mesh | status | compile s | devices | ubatch |"
        " args GiB/dev | temp GiB/dev | collective ops |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(rows):
        r = rows[key]
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped |"
                f" — | — | — | — | — | {r['reason'][:40]}… |")
            continue
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                f" {r['status']} | — | — | — | — | — | — |")
            continue
        mem = r["memory"]
        coll = r.get("collectives", {})
        nops = sum(v["count"] for k, v in coll.items()
                   if isinstance(v, dict))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok |"
            f" {r['compile_seconds']} | {r['devices']} |"
            f" {r.get('microbatches', '—')} |"
            f" {mem['argument_bytes']/2**30:.2f} |"
            f" {mem['temp_bytes']/2**30:.2f} |"
            f" {nops if coll else '—'} |")
    return "\n".join(lines)


PERF_CELLS = {
    "A": ("deepseek-coder-33b", "train_4k", 6),
    "B": ("seamless-m4t-medium", "train_4k", 6),
    "C": ("command-r-plus-104b", "decode_32k", 2),
}


def _terms(r, mult):
    f = r["flops_per_device"]
    b = r["bytes_per_device"]
    c = r["collectives"]["total_bytes"]
    model = mult * r["n_active"] * r["tokens"] / r["devices"]
    step = max(f / roofline.PEAK_FLOPS, b / roofline.HBM_BW,
               c / roofline.ICI_BW)
    return (f / roofline.PEAK_FLOPS, b / roofline.HBM_BW,
            c / roofline.ICI_BW, (model / roofline.PEAK_FLOPS) / step)


def perf_final_table() -> str:
    import os
    v1 = {(c["arch"], c["shape"], c["mesh"]): c for c in roofline.load_cells(
        "experiments/dryrun_results_v1_noconstraints.jsonl")}
    v2 = {(c["arch"], c["shape"], c["mesh"]): c for c in
          roofline.load_cells()}
    opt = {}
    if os.path.exists("experiments/perf_log.jsonl"):
        with open("experiments/perf_log.jsonl") as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") == "ok" and r.get("iteration") == 5:
                    opt[r["cell"]] = r
    lines = [
        "| cell | variant | compute s | memory s | collective s |"
        " roofline frac | Δ dominant vs paper-faithful |",
        "|---|---|---|---|---|---|---|",
    ]
    for cell, (arch, shape, mult) in PERF_CELLS.items():
        key = (arch, shape, "16x16")
        rows = [("paper-faithful v1 (propagation-only)", v1.get(key)),
                ("v2 baseline (constraint system active)", v2.get(key)),
                ("beyond-paper optimized", opt.get(cell))]
        base_dom = None
        for name, r in rows:
            if r is None or "flops_per_device" not in r:
                continue
            t = _terms(r, mult)
            dom = max(t[:3])
            if base_dom is None:
                base_dom = dom
            lines.append(
                f"| {cell} {arch}×{shape} | {name} | {t[0]:.3f} |"
                f" {t[1]:.3f} | {t[2]:.3f} | {t[3]:.4f} |"
                f" {base_dom/dom:.1f}× |")
    return "\n".join(lines)


def main() -> None:
    with open(EXP) as f:
        text = f.read()
    roof = roofline.markdown()
    text = re.sub(
        r"<!-- PERF_FINAL_TABLE -->.*?(?=\n### |\n## |\Z)",
        "<!-- PERF_FINAL_TABLE -->\n\n" + perf_final_table() + "\n\n",
        text, flags=re.DOTALL)
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n### What would|\n## |\Z)",
        "<!-- ROOFLINE_TABLE -->\n\n" + roof + "\n\n"
        "(terms in seconds/step on the 16x16 mesh; decode cells are "
        "seconds/token — see per-cell notes below)\n\n"
        "### Dry-run cell matrix (both meshes)\n\n" + dryrun_table()
        + "\n\n",
        text, flags=re.DOTALL)
    with open(EXP, "w") as f:
        f.write(text)
    print("rendered §Roofline + §Dry-run tables into", EXP)


if __name__ == "__main__":
    main()
