"""Roofline analysis (deliverable (g)): reads the dry-run JSONL and derives
the three per-device roofline terms per (arch x shape) cell:

  compute term    = flops_per_device / PEAK_FLOPS          (197 TF/s bf16)
  memory term     = bytes_per_device / HBM_BW              (819 GB/s)
  collective term = collective_wire_bytes_per_device / ICI (50 GB/s/link)

Conventions (see EXPERIMENTS.md §Dry-run methodology):
* cost_analysis is post-SPMD per-device, so no further /chips division;
* collective wire bytes use ring-cost factors parsed from replica_groups;
* MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (serve) —
  the useful-FLOPs yardstick; ratio MODEL/HLO exposes remat + reference-
  attention + redundant-compute waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

DEFAULT_PATH = os.path.join("experiments", "dryrun_results.jsonl")


def load_cells(path: str = DEFAULT_PATH) -> List[dict]:
    cells = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"))
            cells[key] = r                      # last write wins (resume)
    return list(cells.values())


def terms(cell: dict) -> Optional[dict]:
    if cell.get("status") != "ok" or "flops_per_device" not in cell:
        return None
    flops = cell["flops_per_device"]
    bytes_ = cell["bytes_per_device"]
    coll = cell.get("collectives", {}).get("total_bytes", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_n = coll / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m),
                    ("collective", t_n)), key=lambda kv: kv[1])[0]
    mult = {"train": 6, "prefill": 2, "decode": 2}[cell["kind"]]
    model_flops = mult * cell["n_active"] * cell["tokens"] \
        / cell["devices"]
    ratio = model_flops / flops if flops else 0.0
    # roofline fraction: useful model flops vs the time the dominant term
    # pins the step at (how close the step is to the compute roofline)
    step_time = max(t_c, t_m, t_n)
    frac = (model_flops / PEAK_FLOPS) / step_time if step_time else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell["kind"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant, "model_flops_per_dev": model_flops,
        "hlo_flops_per_dev": flops, "model_hlo_ratio": ratio,
        "roofline_fraction": frac,
        "mem_args_gib": cell["memory"]["argument_bytes"] / 2 ** 30,
        "mem_temp_gib": cell["memory"]["temp_bytes"] / 2 ** 30,
    }


def table(path: str = DEFAULT_PATH) -> List[dict]:
    out = []
    for cell in load_cells(path):
        t = terms(cell)
        if t is not None:
            out.append(t)
    return sorted(out, key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def markdown(path: str = DEFAULT_PATH) -> str:
    rows = table(path)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} |"
            f" {r['memory_s']:.3f} | {r['collective_s']:.3f} |"
            f" {r['dominant']} | {r['model_hlo_ratio']:.2f} |"
            f" {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for r in table():
        if r["mesh"] != "16x16":
            continue
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']}_frac={r['roofline_fraction']:.3f}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run launch.drive_dryrun first"))
    return rows
