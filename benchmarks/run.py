"""Benchmark harness (deliverable (d)): one module per paper table/figure
plus migration matrix, kernels, planner/monitor, and the dry-run roofline
reader.  Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` also
writes a machine-readable report (uploaded as the CI bench-smoke
artifact, named ``BENCH_<sha>.json`` there — the bench trajectory).

  PYTHONPATH=src python -m benchmarks.run [--only fig5,fig6,...] [--json out.json]

Perf-regression gate: ``--compare BASELINE.json --tolerance 0.25`` diffs
the current run's per-row **medians** (collect several with
``--samples N``; rows repeating a name within one report are pooled)
against a committed baseline report and exits non-zero when any common
row's median exceeds ``baseline * (1 + tolerance)`` — so speedups and
regressions stop being invisible in CI.  ``--write-baseline PATH``
refreshes the committed baseline from the current run.

Row kinds: most rows are wall-clock (``us_per_call``, smaller is
better).  A suite may mark a row ``kind="ratio"`` (4th tuple element):
its value is a self-normalizing bigger-is-better ratio (e.g. concurrent
vs serial ingest throughput measured in the same pass), so the gate
compares ratios directly and stays machine-independent — runner drift
cannot fire it and cannot hide behind a baseline refresh either.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import traceback
from typing import Any, Dict, List, Tuple

SUITES = ("fig5", "fig6", "migration", "kernels", "planner", "stream",
          "serve", "ml", "roofline")


def _run_suite(name: str, runs: int) -> List[Tuple[str, float, str]]:
    if name == "fig5":
        from benchmarks import paper_fig5
        return paper_fig5.run(runs=runs)
    if name == "fig6":
        from benchmarks import paper_fig6
        return paper_fig6.run(runs=runs)
    if name == "migration":
        from benchmarks import migration_matrix
        return migration_matrix.run()
    if name == "kernels":
        from benchmarks import kernel_bench
        return kernel_bench.run()
    if name == "planner":
        from benchmarks import planner_monitor
        return planner_monitor.run()
    if name == "stream":
        from benchmarks import stream_bench
        return stream_bench.run()
    if name == "serve":
        from benchmarks import serve_bench
        return serve_bench.run()
    if name == "ml":
        from benchmarks import ml_bench
        return ml_bench.run()
    if name == "roofline":
        from benchmarks import roofline
        return roofline.run()
    raise ValueError(f"unknown suite {name!r}")


def _row_pools(report: Dict[str, Any]
               ) -> Dict[Tuple[str, str], List[float]]:
    """(suite, row name) -> every us_per_call occurrence in the report
    (multiple ``--samples`` passes repeat row names)."""
    pools: Dict[Tuple[str, str], List[float]] = {}
    for suite, rows in report.get("suites", {}).items():
        for row in rows:
            pools.setdefault((suite, row["name"]), []).append(
                float(row["us_per_call"]))
    return pools


def report_medians(report: Dict[str, Any]) -> Dict[Tuple[str, str], float]:
    """(suite, row name) -> median us_per_call over every occurrence."""
    return {k: statistics.median(v)
            for k, v in _row_pools(report).items()}


def report_kinds(report: Dict[str, Any]) -> Dict[Tuple[str, str], str]:
    """(suite, row name) -> row kind for rows that declare one ("ratio"
    or "time"); rows without a kind field are omitted, so a report from
    before the field existed cannot demote a known ratio row."""
    kinds: Dict[Tuple[str, str], str] = {}
    for suite, rows in report.get("suites", {}).items():
        for row in rows:
            if "kind" in row:
                kinds[(suite, row["name"])] = row["kind"]
    return kinds


def compare_reports(baseline: Dict[str, Any], current: Dict[str, Any],
                    tolerance: float = 0.25) -> Dict[str, Any]:
    """Diff two ``--json`` reports by per-row median us_per_call.

    A row *regresses* when its current **median** exceeds the baseline
    median by more than ``tolerance`` (relative) AND its best (minimum)
    sample does too: a genuine code regression elevates every sample,
    while scheduler noise on micro-rows usually leaves at least one
    sample near baseline — so one lucky sample vetoes a false alarm but
    cannot hide a real slowdown.  Rows faster by the same margin are
    reported as improvements.  Only rows present in both reports are
    compared — renamed or new rows can't fail the gate, but they are
    listed so a silently vanished benchmark is visible.

    ``kind="ratio"`` rows invert the direction: their value is a
    bigger-is-better self-normalized ratio, so a row regresses when its
    current median falls below ``baseline * (1 - tolerance)`` AND its
    best (maximum) sample does too."""
    base = report_medians(baseline)
    cur = report_medians(current)
    cur_pools = _row_pools(current)
    # the current report's kind wins (a row may change kind in the PR
    # that converts it); baseline-only kinds cover the transition run
    kinds = {**report_kinds(baseline), **report_kinds(current)}
    rows, regressions, improvements = [], [], []
    for key in sorted(base.keys() & cur.keys()):
        b, c = base[key], cur[key]
        ratio = c / b if b > 0 else float("inf")
        kind = kinds.get(key, "time")
        if kind == "ratio":
            cutoff = b * (1.0 - tolerance)
            regressed = c < cutoff and max(cur_pools[key]) < cutoff
            improved = c > b * (1.0 + tolerance)
        else:
            cutoff = b * (1.0 + tolerance)
            regressed = c > cutoff and min(cur_pools[key]) > cutoff
            improved = c < b * (1.0 - tolerance)
        name = f"{key[0]}/{key[1]}" if not key[1].startswith(key[0]) \
            else key[1]
        rows.append({"suite": key[0], "name": key[1], "kind": kind,
                     "baseline_us": round(b, 3), "current_us": round(c, 3),
                     "ratio": round(ratio, 4), "regressed": regressed})
        if regressed:
            regressions.append(name)
        elif improved:
            improvements.append(name)
    return {"tolerance": tolerance, "rows": rows,
            "regressions": regressions, "improvements": improvements,
            "only_in_baseline": sorted(
                f"{s}/{n}" for s, n in base.keys() - cur.keys()),
            "only_in_current": sorted(
                f"{s}/{n}" for s, n in cur.keys() - base.keys())}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--runs", type=int, default=50,
                    help="repetitions for fig5/fig6 (paper uses 50)")
    ap.add_argument("--samples", type=int, default=1,
                    help="full passes over the selected suites; per-row "
                         "medians pool across passes (use >1 with "
                         "--compare for stable medians)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write results as JSON to this path")
    ap.add_argument("--compare", type=str, default=None,
                    help="baseline report JSON to diff medians against; "
                         "exits non-zero on any regression")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression tolerance for --compare "
                         "(0.25 = fail rows >25%% over baseline)")
    ap.add_argument("--write-baseline", type=str, default=None,
                    help="write this run's report as a fresh baseline")
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    report: Dict[str, Any] = {"suites": {}, "meta": {}, "failures": []}
    for _ in range(max(1, args.samples)):
        for name in selected:
            if name not in SUITES:
                print(f"unknown suite {name}", file=sys.stderr)
                continue
            try:
                rows = _run_suite(name, args.runs)
                if name == "stream":
                    # shard/engine config rides along so BENCH_*.json
                    # trajectories stay comparable across shard configs
                    from benchmarks import stream_bench
                    report["meta"]["stream"] = dict(stream_bench.LAST_META)
                if name == "serve":
                    from benchmarks import serve_bench
                    report["meta"]["serve"] = dict(serve_bench.LAST_META)
                if name == "ml":
                    from benchmarks import ml_bench
                    report["meta"]["ml"] = dict(ml_bench.LAST_META)
                for row in rows:
                    row_name, us, derived = row[0], row[1], row[2]
                    kind = row[3] if len(row) > 3 else "time"
                    report["suites"].setdefault(name, []).append(
                        {"name": row_name, "us_per_call": us,
                         "derived": derived, "kind": kind})
                    value = f"{us:.3f}" if kind == "ratio" else f"{us:.1f}"
                    print(f"{row_name},{value},{derived}")
            except Exception:                             # noqa: BLE001
                report["failures"].append(
                    {"suite": name, "traceback": traceback.format_exc()})
                traceback.print_exc()

    # the unified metrics registry accumulated over every suite rides
    # along (one scrape per bench run), so a BENCH_*.json also carries
    # the observability view of what the benchmarks actually did
    from repro.obs import metrics as obs_metrics
    report["meta"]["obs"] = obs_metrics.snapshot()

    comparison = None
    if args.compare:
        with open(args.compare) as fh:
            baseline = json.load(fh)
        comparison = compare_reports(baseline, report,
                                     tolerance=args.tolerance)
        report["compare"] = dict(comparison, baseline=args.compare)
        for row in comparison["rows"]:
            flag = "REGRESSED" if row["regressed"] else "ok"
            print(f"compare,{row['suite']}/{row['name']},"
                  f"{row['ratio']:.2f}x,{flag}", file=sys.stderr)
        if comparison["regressions"]:
            print(f"PERF REGRESSION (> {args.tolerance:.0%} over "
                  f"{args.compare}): "
                  + ", ".join(comparison["regressions"]),
                  file=sys.stderr)
        else:
            print(f"perf gate OK: {len(comparison['rows'])} rows within "
                  f"{args.tolerance:.0%} of {args.compare}"
                  + (f" (improved: "
                     f"{', '.join(comparison['improvements'])})"
                     if comparison["improvements"] else ""),
                  file=sys.stderr)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1)
    if args.write_baseline:
        with open(args.write_baseline, "w") as fh:
            json.dump(report, fh, indent=1)
    if report["failures"]:
        sys.exit(1)
    if comparison is not None and comparison["regressions"]:
        sys.exit(2)


if __name__ == "__main__":
    main()
