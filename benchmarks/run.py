"""Benchmark harness (deliverable (d)): one module per paper table/figure
plus migration matrix, kernels, planner/monitor, and the dry-run roofline
reader.  Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` also
writes a machine-readable report (uploaded as the CI bench-smoke artifact).

  PYTHONPATH=src python -m benchmarks.run [--only fig5,fig6,...] [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

SUITES = ("fig5", "fig6", "migration", "kernels", "planner", "stream",
          "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--runs", type=int, default=50,
                    help="repetitions for fig5/fig6 (paper uses 50)")
    ap.add_argument("--json", type=str, default=None,
                    help="also write results as JSON to this path")
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    report = {"suites": {}, "meta": {}, "failures": []}
    for name in selected:
        try:
            if name == "fig5":
                from benchmarks import paper_fig5
                rows = paper_fig5.run(runs=args.runs)
            elif name == "fig6":
                from benchmarks import paper_fig6
                rows = paper_fig6.run(runs=args.runs)
            elif name == "migration":
                from benchmarks import migration_matrix
                rows = migration_matrix.run()
            elif name == "kernels":
                from benchmarks import kernel_bench
                rows = kernel_bench.run()
            elif name == "planner":
                from benchmarks import planner_monitor
                rows = planner_monitor.run()
            elif name == "stream":
                from benchmarks import stream_bench
                rows = stream_bench.run()
                # shard/engine config rides along so BENCH_*.json
                # trajectories stay comparable across shard configs
                report["meta"]["stream"] = dict(stream_bench.LAST_META)
            elif name == "roofline":
                from benchmarks import roofline
                rows = roofline.run()
            else:
                print(f"unknown suite {name}", file=sys.stderr)
                continue
            report["suites"][name] = [
                {"name": row_name, "us_per_call": us, "derived": derived}
                for row_name, us, derived in rows]
            for row_name, us, derived in rows:
                print(f"{row_name},{us:.1f},{derived}")
        except Exception:                                 # noqa: BLE001
            report["failures"].append(
                {"suite": name, "traceback": traceback.format_exc()})
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=1)
    if report["failures"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
