"""Serving front-door benchmark: results/second delivered to N
synthetic tenants through the ``FrontDoor`` (identical subscriptions
share ONE standing-query execution per tick, fanned out) against the
same N tenants each running an independent direct
``register_continuous`` query (N executions per tick).  The
``serve/tenants_qps`` row is **ratio-type**: both rates are measured in
the same pass on the same host, so runner speed cancels out and the CI
gate on it is machine-independent — the ratio is the warm-sharing win
and grows with the tenant count.  The absolute delivery rates and the
p50/p99 per-tick latency under the tenant fleet ride along in the
``derived`` column and ``LAST_META``."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

TENANTS = 8
TICKS = 24
BATCH_ROWS = 256
PASSES = 3
QUERY = "bdstream(aggregate(window(serve.bench, 64), avg(v)))"

# set by run(): tenant/tick config + measured rates and latencies —
# read by benchmarks.run to stamp the JSON report's serve metadata
LAST_META: Dict[str, object] = {}


def _batches() -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(11)
    return [{"ts": np.arange(float(BATCH_ROWS)) + i * BATCH_ROWS,
             "v": rng.standard_normal(BATCH_ROWS)}
            for i in range(TICKS)]


def _frontdoor_rate(batches) -> Tuple[float, float, float]:
    """(results/sec to TENANTS tenants via the front door, p50 tick ms,
    p99 tick ms) — one shared execution per tick."""
    from repro.core.api import default_deployment
    from repro.serve.engine import ServeConfig
    from repro.serve.frontdoor import FrontDoor
    from repro.stream.spec import StreamSpec

    bd = default_deployment()
    door = FrontDoor(bd, ServeConfig(streams=(
        StreamSpec("serve.bench", ("ts", "v"),
                   capacity=4 * BATCH_ROWS),)),
        stream_engine="streamstore0", max_tenants=TENANTS,
        result_buffer=TICKS + 1)
    subs = [door.open_session(f"tenant{i}").subscribe(QUERY)
            for i in range(TENANTS)]
    stream = bd.engines["streamstore0"].get("serve.bench")
    stream.append(batches[0])
    bd.streams.tick()                        # warm the plan cache
    for sub in subs:
        sub.poll()
    t0 = time.perf_counter()
    for batch in batches[1:]:
        stream.append(batch)
        bd.streams.tick()
    dt = time.perf_counter() - t0
    delivered = sum(len(sub.poll()) for sub in subs)
    assert delivered == TENANTS * (TICKS - 1)
    stats = door.stats()
    door.close()
    return delivered / dt, stats["p50_tick_ms"], stats["p99_tick_ms"]


def _direct_rate(batches) -> float:
    """Results/sec with every tenant running its own direct standing
    query — N executions per tick, the no-front-door baseline."""
    from repro.core.api import default_deployment
    from repro.stream.spec import StreamSpec

    bd = default_deployment()
    bd.register_stream("streamstore0", StreamSpec(
        "serve.bench", ("ts", "v"), capacity=4 * BATCH_ROWS))
    for i in range(TENANTS):
        bd.streams.register_continuous(QUERY, name=f"direct{i}")
    stream = bd.engines["streamstore0"].get("serve.bench")
    stream.append(batches[0])
    bd.streams.tick()                        # warm the plan cache
    t0 = time.perf_counter()
    for batch in batches[1:]:
        stream.append(batch)
        bd.streams.tick()
    dt = time.perf_counter() - t0
    return TENANTS * (TICKS - 1) / dt


def run() -> List[Tuple]:
    batches = _batches()
    # best-of-PASSES on each side: CPU-steal bursts cannot poison the
    # self-normalized ratio (same policy as stream/ingest_producersN)
    fd_best, p50, p99 = 0.0, 0.0, 0.0
    for _ in range(PASSES):
        rate, pass_p50, pass_p99 = _frontdoor_rate(batches)
        if rate > fd_best:
            fd_best, p50, p99 = rate, pass_p50, pass_p99
    direct_best = max(_direct_rate(batches) for _ in range(PASSES))
    ratio = fd_best / direct_best
    LAST_META.clear()
    LAST_META.update({
        "tenants": TENANTS, "ticks": TICKS, "batch_rows": BATCH_ROWS,
        "frontdoor_results_per_s": round(fd_best, 1),
        "direct_results_per_s": round(direct_best, 1),
        "p50_tick_ms": round(p50, 3), "p99_tick_ms": round(p99, 3),
        "ratio": round(ratio, 3)})
    return [("serve/tenants_qps", ratio,
             f"tenants={TENANTS} frontdoor={fd_best:.0f}/s "
             f"direct={direct_best:.0f}/s p50_tick={p50:.2f}ms "
             f"p99_tick={p99:.2f}ms", "ratio")]


if __name__ == "__main__":
    for name, value, derived, kind in run():
        print(f"{name},{value:.3f},{derived}")
