"""Streaming island benchmarks (paper §III / arXiv:1609.07548 S-Store):
ingest throughput into the ring buffer, standing-query tick latency vs
window size (2nd+ ticks ride the signature plan cache), and the staged
window->table route.  Rows land in ``benchmarks.run --json`` so CI's
bench-smoke artifact records ingest rows/sec and per-tick latency."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.api import default_deployment

STREAM = "mimic2v26.waveform_stream"


def _window_query(size: int) -> str:
    return (f"bdarray(aggregate(bdcast(bdstream(window({STREAM}, {size})),"
            f" w_arr, '<signal:double>[tick=0:{size - 1},{size},0]',"
            f" array), avg(signal)))")


def run(batch_rows: int = 512, num_batches: int = 16,
        window_sizes: Tuple[int, ...] = (64, 256, 1024),
        ticks_per_window: int = 8) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    rng = np.random.default_rng(0)

    # -- ingest throughput: rows/second into the bounded ring buffer ---------
    bd = default_deployment()
    stream = bd.register_stream("streamstore0", STREAM,
                                ("signal", "hr"), capacity=8192)
    batches = [{"signal": rng.standard_normal(batch_rows),
                "hr": 75.0 + rng.standard_normal(batch_rows)}
               for _ in range(num_batches)]
    t0 = time.perf_counter()
    for batch in batches:
        stream.append(batch)
    ingest_s = time.perf_counter() - t0
    total = batch_rows * num_batches
    rows.append(("stream/ingest", ingest_s / num_batches * 1e6,
                 f"rows_per_sec={total / ingest_s:.0f}_"
                 f"batch_rows={batch_rows}"))

    # -- standing-query tick latency vs window size --------------------------
    # fresh deployment per window size so each plan-cache line is clean
    for size in window_sizes:
        bd = default_deployment()
        bd.register_stream("streamstore0", STREAM, ("signal", "hr"),
                           capacity=max(8192, 2 * size))
        cq = bd.register_continuous(_window_query(size), every_n_ticks=1,
                                    name=f"w{size}")
        tick_ts = []
        for _ in range(ticks_per_window):
            bd.engines["streamstore0"].get(STREAM).append({
                "signal": rng.standard_normal(size),
                "hr": 75.0 + rng.standard_normal(size)})
            t0 = time.perf_counter()
            bd.streams.tick()
            tick_ts.append(time.perf_counter() - t0)
        # first tick pays the plan-cache miss; steady state is the median
        # of the remaining (cache-hit) ticks
        steady = float(np.median(tick_ts[1:]))
        rows.append((f"stream/tick_w{size}", steady * 1e6,
                     f"first_tick_us={tick_ts[0] * 1e6:.1f}_"
                     f"cache_hits={cq.cache_hits}/{cq.executions}"))

    # -- staged window->table route (relational standing query) --------------
    bd = default_deployment()
    bd.register_stream("streamstore0", STREAM, ("signal", "hr"),
                       capacity=8192)
    cq = bd.register_continuous(
        f"bdrel(select max(hr) from bdcast(bdstream(window({STREAM},"
        f" 256, 128)), w_tbl, '', relational))",
        every_n_ticks=1, name="hr_table")
    tick_ts = []
    for _ in range(ticks_per_window):
        bd.engines["streamstore0"].get(STREAM).append({
            "signal": rng.standard_normal(256),
            "hr": 75.0 + rng.standard_normal(256)})
        t0 = time.perf_counter()
        bd.streams.tick()
        tick_ts.append(time.perf_counter() - t0)
    rows.append(("stream/tick_staged_w256",
                 float(np.median(tick_ts[1:])) * 1e6,
                 f"cache_hits={cq.cache_hits}/{cq.executions}"))
    return rows
