"""Streaming island benchmarks (paper §III / arXiv:1609.07548 S-Store):
ingest throughput into the ring buffer (single stream vs hash-partitioned
shards across multiple StreamEngines), concurrent multi-producer ingest
vs the same workload fed serially (the ``ingest_producersN`` rows are
**ratio-type**: self-normalizing concurrent/serial throughput, so the CI
perf gate on them is machine-independent), gathered-window bit-identity
vs the unsharded baseline, the rolling window-aggregate fast path,
event-time rows (out-of-order ingest through the insertion buffer/
watermark path, and the cross-stream interval join over co-located
shards), standing-query tick latency vs window size (2nd+ ticks ride the
signature plan cache), and the staged window->table route.  Rows land in
``benchmarks.run --json`` so CI's bench-smoke artifact records ingest
rows/sec and per-tick latency; the shard/engine configuration is exported
via ``LAST_META`` so BENCH_*.json trajectories stay comparable across
shard configs."""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.api import default_deployment

STREAM = "mimic2v26.waveform_stream"

# sharded-ingest configuration (also recorded in the --json metadata)
INGEST_SHARDS = 4
INGEST_BATCH_ROWS = 65536
INGEST_BATCHES = 24

# multi-producer ingest configuration: each producer computes its
# payload (a GIL-releasing feature transform — realistic producers do
# work between appends) and appends this many one-seq-block batches of
# this many rows.  The ratio compares N concurrent producers against
# ONE producer feeding the identical workload serially; each side is
# measured PRODUCER_PASSES times and the best rate wins, so CPU-steal
# bursts on oversubscribed hosts cannot poison the self-normalized
# ratio.  The ratio scales with the host's usable cores: producers <=
# cores overlap payload prep with ring writes (> 1.0 even on the
# 2-vCPU dev container); producers beyond the core budget pay CPython
# GIL-switch overhead instead (see ROADMAP known limits)
PRODUCER_COUNTS = (2, 4)
PRODUCER_BATCH_ROWS = 16384
PRODUCER_BATCHES_EACH = 24
PRODUCER_PREP_COLS = 32
PRODUCER_PASSES = 5

# set by run(): {"shards", "stream_engines", "batch_rows", ...} — read by
# benchmarks.run to stamp the JSON report's stream-suite metadata
LAST_META: Dict[str, object] = {}


def _window_query(size: int) -> str:
    return (f"bdarray(aggregate(bdcast(bdstream(window({STREAM}, {size})),"
            f" w_arr, '<signal:double>[tick=0:{size - 1},{size},0]',"
            f" array), avg(signal)))")


def _sharded_ingest_rate(shards: int, batches: List[Dict[str, np.ndarray]],
                         batch_rows: int) -> float:
    """Rows/second appended through the logical stream at a given shard
    count (1 = plain Stream; >1 = scatter across StreamEngines with the
    per-shard ring writes fanned out in parallel)."""
    bd = default_deployment()
    stream = bd.register_stream(
        "streamstore0", STREAM, ("signal", "hr"),
        capacity=8 * batch_rows, shards=shards, num_engines=shards,
        block_rows=max(1, batch_rows // max(1, shards)))
    stream.append(batches[0])                    # warm the ring / pool
    t0 = time.perf_counter()
    for batch in batches:
        stream.append(batch)
    dt = time.perf_counter() - t0
    if shards > 1:
        stream.close()
    return batch_rows * len(batches) / dt


def _producer_ingest_rates(producers: int) -> Tuple[float, float]:
    """(serial rows/sec, concurrent rows/sec) for the same workload:
    ``producers`` x ``PRODUCER_BATCHES_EACH`` batches, each computed by
    a small GIL-releasing matmul (producers do real work between
    appends) and appended — once by ONE thread running every producer's
    loop back-to-back (serial ingest: prep and ring writes strictly
    alternate), once by ``producers`` barrier-started threads each
    holding a ``stream.producer()`` handle (the seq-block reservation
    path: one producer's prep overlaps another's ring write).  Self-
    normalizing: both sides share data, allocator state and host noise,
    so the ratio measures concurrency benefit rather than machine
    speed; best-of-``PRODUCER_PASSES`` per side approximates steal-free
    capability on oversubscribed hosts."""
    rng = np.random.default_rng(7)
    seeds = [rng.standard_normal(
        (PRODUCER_BATCH_ROWS, PRODUCER_PREP_COLS)).astype(np.float32)
        for _ in range(producers)]
    weights = rng.standard_normal(
        (PRODUCER_PREP_COLS, 2)).astype(np.float32)
    total = producers * PRODUCER_BATCHES_EACH * PRODUCER_BATCH_ROWS

    def build():
        bd = default_deployment()
        return bd.register_stream(
            "streamstore0", "bench.producers", ("k", "v"),
            capacity=8 * PRODUCER_BATCH_ROWS, shards=INGEST_SHARDS,
            num_engines=2,
            # one seq block per batch: whole batches round-robin across
            # the shard rings, so concurrent producers mostly publish
            # to different shards at any instant
            block_rows=PRODUCER_BATCH_ROWS)

    def producer_loop(stream, pid: int) -> None:
        for _ in range(PRODUCER_BATCHES_EACH):
            feat = seeds[pid] @ weights          # GIL-released prep
            stream.append({"k": feat[:, 0], "v": feat[:, 1]})

    def serial_pass() -> float:
        stream = build()
        stream.append({"k": np.zeros(4), "v": np.zeros(4)})  # warm
        t0 = time.perf_counter()
        for pid in range(producers):
            producer_loop(stream, pid)
        dt = time.perf_counter() - t0
        stream.close()
        return total / dt

    def concurrent_pass() -> float:
        stream = build()
        stream.append({"k": np.zeros(4), "v": np.zeros(4)})
        barrier = threading.Barrier(producers)

        def feed(pid: int) -> None:
            with stream.producer():
                barrier.wait()
                producer_loop(stream, pid)

        threads = [threading.Thread(target=feed, args=(pid,))
                   for pid in range(producers)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        stream.close()
        return total / dt

    serial_rate = concurrent_rate = 0.0
    for _ in range(PRODUCER_PASSES):          # interleave the two sides
        serial_rate = max(serial_rate, serial_pass())
        concurrent_rate = max(concurrent_rate, concurrent_pass())
    return serial_rate, concurrent_rate


def run(batch_rows: int = 512, num_batches: int = 16,
        window_sizes: Tuple[int, ...] = (64, 256, 1024),
        ticks_per_window: int = 8) -> List[Tuple]:
    # rows are (name, value, derived[, kind]); kind="ratio" marks
    # self-normalizing rows whose value is a bigger-is-better ratio
    rows: List[Tuple] = []
    rng = np.random.default_rng(0)

    # -- ingest throughput: rows/second into the bounded ring buffer ---------
    bd = default_deployment()
    stream = bd.register_stream("streamstore0", STREAM,
                                ("signal", "hr"), capacity=8192)
    batches = [{"signal": rng.standard_normal(batch_rows),
                "hr": 75.0 + rng.standard_normal(batch_rows)}
               for _ in range(num_batches)]
    t0 = time.perf_counter()
    for batch in batches:
        stream.append(batch)
    ingest_s = time.perf_counter() - t0
    total = batch_rows * num_batches
    rows.append(("stream/ingest", ingest_s / num_batches * 1e6,
                 f"rows_per_sec={total / ingest_s:.0f}_"
                 f"batch_rows={batch_rows}"))

    # -- sharded ingest: scatter across N StreamEngines vs one ring ----------
    # large batches so the per-shard ring writes (numpy copies, GIL
    # released) dominate the scatter bookkeeping; the speedup is bounded
    # by the host's usable cores/memory bandwidth
    big = [{"signal": rng.standard_normal(INGEST_BATCH_ROWS),
            "hr": 75.0 + rng.standard_normal(INGEST_BATCH_ROWS)}
           for _ in range(INGEST_BATCHES)]
    rate1 = _sharded_ingest_rate(1, big, INGEST_BATCH_ROWS)
    rate_n = _sharded_ingest_rate(INGEST_SHARDS, big, INGEST_BATCH_ROWS)
    rows.append((f"stream/ingest_shards{INGEST_SHARDS}",
                 INGEST_BATCH_ROWS / rate_n * 1e6,     # us per batch
                 f"rows_per_sec={rate_n:.0f}_speedup_vs_1shard="
                 f"{rate_n / rate1:.2f}x_1shard_rows_per_sec={rate1:.0f}"))

    # -- multi-producer ingest: concurrent vs serial throughput RATIO --------
    # ratio-type rows are self-normalizing (both rates measured on the
    # same host in the same pass), so the perf gate on them is machine-
    # independent — no runner-drift baseline refreshes.  Absolute rates
    # ride along in the derived column and LAST_META.
    producer_meta = {}
    for nprod in PRODUCER_COUNTS:
        serial_rate, concurrent_rate = _producer_ingest_rates(nprod)
        ratio = concurrent_rate / serial_rate
        rows.append((f"stream/ingest_producers{nprod}", ratio,
                     f"concurrent_rows_per_sec={concurrent_rate:.0f}_"
                     f"serial_rows_per_sec={serial_rate:.0f}_"
                     f"shards={INGEST_SHARDS}_"
                     f"batch_rows={PRODUCER_BATCH_ROWS}", "ratio"))
        producer_meta[f"producers{nprod}"] = {
            "serial_rows_per_sec": round(serial_rate),
            "concurrent_rows_per_sec": round(concurrent_rate),
            "ratio": round(ratio, 3)}

    # -- gathered window: bit-identical to the unsharded baseline ------------
    bd_ref = default_deployment()
    ref = bd_ref.register_stream("streamstore0", STREAM,
                                 ("signal", "hr"), capacity=8192)
    bd_sh = default_deployment()
    sh = bd_sh.register_stream("streamstore0", STREAM, ("signal", "hr"),
                               capacity=8192, shards=INGEST_SHARDS,
                               num_engines=INGEST_SHARDS, block_rows=64)
    for _ in range(8):
        batch = {"signal": rng.standard_normal(512),
                 "hr": 75.0 + rng.standard_normal(512)}
        ref.append(batch)
        sh.append(batch)
    sh.window(1024)                       # warm jnp dispatch before timing
    t0 = time.perf_counter()
    gathered = sh.window(1024)
    gather_s = time.perf_counter() - t0
    identical = bool(np.array_equal(
        np.asarray(ref.window(1024).attrs["signal"]),
        np.asarray(gathered.attrs["signal"])))
    rows.append(("stream/gather_window_w1024", gather_s * 1e6,
                 f"bit_identical_to_unsharded={identical}_"
                 f"shards={INGEST_SHARDS}"))

    # -- rolling aggregate fast path: O(1) repeat ticks on a big window ------
    agg_ts = []
    for _ in range(ticks_per_window):
        t0 = time.perf_counter()
        sh.window_aggregate(2048, "avg", "signal")
        agg_ts.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    materialized = float(np.asarray(sh.window(2048).attrs["signal"],
                                    np.float64).mean())
    full_s = time.perf_counter() - t0
    assert abs(materialized - sh.window_aggregate(2048, "avg", "signal")) \
        < 1e-6
    rows.append(("stream/agg_rolling_w2048",
                 float(np.median(agg_ts[1:])) * 1e6,
                 f"first_compute_us={agg_ts[0] * 1e6:.1f}_"
                 f"materialized_us={full_s * 1e6:.1f}_"
                 f"cache_hits={sh.agg_cache_hits}"))

    LAST_META.clear()
    LAST_META.update({
        "shards": INGEST_SHARDS,
        "stream_engines": INGEST_SHARDS,
        "ingest_batch_rows": INGEST_BATCH_ROWS,
        "ingest_batches": INGEST_BATCHES,
        "sharded_ingest_rows_per_sec": round(rate_n),
        "unsharded_ingest_rows_per_sec": round(rate1),
        "sharded_speedup": round(rate_n / rate1, 3),
        "gather_bit_identical": identical,
        "multi_producer_ingest": producer_meta,
    })

    # -- event time: out-of-order ingest + watermarked cross-stream join -----
    # two jittered feeds over a shared ts axis; rows arrive shuffled by a
    # bounded network jitter, park in the insertion buffer, and flush in
    # ts order once the watermark passes — then an interval join pairs
    # the two streams' rows (the partial path: co-located shard pairs)
    bd_ev = default_deployment()
    ev_rows, ev_jitter = 4096, 8.0
    left = bd_ev.register_stream("streamstore0", "bench.abp",
                                 ("ts", "abp"), capacity=2 * ev_rows,
                                 shards=2, num_engines=2,
                                 ts_field="ts", max_delay=2.5 * ev_jitter)
    right = bd_ev.register_stream("streamstore0", "bench.ecg",
                                  ("ts", "ecg"), capacity=2 * ev_rows,
                                  shards=2, num_engines=2,
                                  ts_field="ts", max_delay=2.5 * ev_jitter)
    ts = np.arange(ev_rows, dtype=np.float64)
    order = np.argsort(ts + rng.uniform(-ev_jitter, ev_jitter, ev_rows))
    t0 = time.perf_counter()
    for a in range(0, ev_rows, 512):
        sl = order[a:a + 512]
        left.append({"ts": ts[sl], "abp": 90.0 + np.sin(ts[sl])})
        right.append({"ts": ts[sl] + 0.25, "ecg": np.cos(ts[sl])})
    left.flush()
    right.flush()
    ingest_ev_s = time.perf_counter() - t0
    rows.append(("stream/ingest_event_time",
                 ingest_ev_s / (ev_rows / 512) * 1e6,
                 f"rows_per_sec={2 * ev_rows / ingest_ev_s:.0f}_"
                 f"jitter={ev_jitter}_late={left.total_late}"))
    join_q = ("bdstream(join(ewindow(bench.abp, 512),"
              " ewindow(bench.ecg, 512), on=ts, tol=0.5))")
    bd_ev.query(join_q)                   # warm plan cache + jnp dispatch
    join_ts = []
    for _ in range(ticks_per_window):
        t0 = time.perf_counter()
        r = bd_ev.query(join_q)
        join_ts.append(time.perf_counter() - t0)
    pairs = int(np.asarray(r.value.columns["dt"]).shape[0])
    rows.append(("stream/join_ew512", float(np.median(join_ts)) * 1e6,
                 f"pairs={pairs}_tol=0.5_shards=2_colocated=True"))
    LAST_META.update({"event_time_jitter": ev_jitter,
                      "event_time_late": left.total_late,
                      "join_pairs": pairs})

    # -- standing-query tick latency vs window size --------------------------
    # fresh deployment per window size so each plan-cache line is clean
    for size in window_sizes:
        bd = default_deployment()
        bd.register_stream("streamstore0", STREAM, ("signal", "hr"),
                           capacity=max(8192, 2 * size))
        cq = bd.register_continuous(_window_query(size), every_n_ticks=1,
                                    name=f"w{size}")
        tick_ts = []
        for _ in range(ticks_per_window):
            bd.engines["streamstore0"].get(STREAM).append({
                "signal": rng.standard_normal(size),
                "hr": 75.0 + rng.standard_normal(size)})
            t0 = time.perf_counter()
            bd.streams.tick()
            tick_ts.append(time.perf_counter() - t0)
        # first tick pays the plan-cache miss; steady state is the median
        # of the remaining (cache-hit) ticks
        steady = float(np.median(tick_ts[1:]))
        rows.append((f"stream/tick_w{size}", steady * 1e6,
                     f"first_tick_us={tick_ts[0] * 1e6:.1f}_"
                     f"cache_hits={cq.cache_hits}/{cq.executions}"))

    # -- staged window->table route (relational standing query) --------------
    bd = default_deployment()
    bd.register_stream("streamstore0", STREAM, ("signal", "hr"),
                       capacity=8192)
    cq = bd.register_continuous(
        f"bdrel(select max(hr) from bdcast(bdstream(window({STREAM},"
        f" 256, 128)), w_tbl, '', relational))",
        every_n_ticks=1, name="hr_table")
    tick_ts = []
    for _ in range(ticks_per_window):
        bd.engines["streamstore0"].get(STREAM).append({
            "signal": rng.standard_normal(256),
            "hr": 75.0 + rng.standard_normal(256)})
        t0 = time.perf_counter()
        bd.streams.tick()
        tick_ts.append(time.perf_counter() - t0)
    rows.append(("stream/tick_staged_w256",
                 float(np.median(tick_ts[1:])) * 1e6,
                 f"cache_hits={cq.cache_hits}/{cq.executions}"))

    # -- compiled query path: jit vs interpreter RATIO rows ------------------
    # self-normalizing like ingest_producersN (both backends timed on
    # the same host, interleaved passes, best-pass median each), so the
    # CI perf gate can require jit_tick > 1.0 machine-independently.
    # jit_tick is the sliding-window standing query — the interpreter
    # materializes every window slice in a Python loop, the compiled
    # plan is one cached jitted gather.  jit_join is the banded
    # interval join over the co-located 2-shard event-time pair.
    rows.extend(_jit_ratio_rows(rng, ticks_per_window))

    # -- tracing overhead RATIO row ------------------------------------------
    # tick rate with REPRO_TRACE off vs on, interleaved passes: keeps
    # the disabled span machinery honest (the default-off path must stay
    # near-free — the ratio slides toward 1.0 if it grows overhead, and
    # the committed baseline's ratio gate catches that drift)
    rows.append(_trace_overhead_row(rng, ticks_per_window))

    # -- durability replay RATIO row -----------------------------------------
    # replayed rows/sec (recover() rebuilding the stream from its
    # segment log) over durable-live ingest rows/sec, measured paired
    # per pass.  Self-normalizing: both sides run the same ring-write
    # code on the same host, so the gate holds machine-independently.
    rows.append(_replay_rate_row(rng))
    return rows


REPLAY_PASSES = 3
REPLAY_BATCH_ROWS = 512
REPLAY_BATCHES = 16


def _replay_rate_row(rng) -> Tuple:
    """``stream/replay_rate``: rows/sec of ``recover()`` replaying the
    segment log vs rows/sec of the durable *live* ingest that wrote it.
    Bigger is better — replay re-applies committed batches without
    producer-side reservation work, so it should at least keep up with
    live ingest; a ratio sliding toward 0 means log decode/apply grew
    overhead that would stretch crash-recovery windows.

    Noise design: each pass ingests a fresh log then immediately
    replays it (paired sides back to back), contributing one per-pass
    ratio; the row reports the median.  Pairing cancels machine-wide
    drift the same way the trace-overhead row does."""
    import shutil
    import tempfile

    from repro.stream import durability
    from repro.stream.engine import Stream

    batch = {"signal": rng.standard_normal(REPLAY_BATCH_ROWS)}
    ratios, live_rates, replay_rates = [], [], []
    for _ in range(REPLAY_PASSES):
        d = tempfile.mkdtemp(prefix="bench_replay_")
        try:
            s = Stream("bench.replay", ("signal",),
                       REPLAY_BATCH_ROWS * REPLAY_BATCHES)
            durability.attach(s, d)
            t0 = time.perf_counter()
            for _ in range(REPLAY_BATCHES):
                s.append(batch)
            live_s = time.perf_counter() - t0
            result = durability.recover(d, repair=False)
            rows_total = REPLAY_BATCH_ROWS * REPLAY_BATCHES
            assert result.rows_replayed == rows_total
            live_rate = rows_total / live_s
            replay_rate = rows_total / result.seconds
            ratios.append(replay_rate / live_rate)
            live_rates.append(live_rate)
            replay_rates.append(replay_rate)
        finally:
            shutil.rmtree(d, ignore_errors=True)
    ratio = float(np.median(ratios))
    live = float(np.median(live_rates))
    replay = float(np.median(replay_rates))
    LAST_META["replay_rate_ratio"] = round(ratio, 3)
    return ("stream/replay_rate", ratio,
            f"replay_rows_per_sec={replay:.0f}_live={live:.0f}"
            f"_rows={REPLAY_BATCH_ROWS * REPLAY_BATCHES}", "ratio")


JIT_PASSES = 5


TRACE_PASSES = 5


def _trace_overhead_row(rng, reps: int) -> Tuple:
    """``stream/trace_overhead``: tick_rate(tracing off) /
    tick_rate(on) for the windowed standing query.  Bigger is better:
    the value is how much faster the default REPRO_TRACE=off path runs
    than full span recording.  It sits above 1 while the disabled path
    is near-free; if disabled-mode instrumentation ever grows real
    overhead the ratio slides toward 1.0 (the committed baseline's
    ratio gate catches the drift) and below 0.85 — disabled clearly
    slower than enabled, which can only be a bug — the bench fails
    outright.

    Noise design: each pass measures BOTH sides back to back (order
    alternating between passes) and contributes one *paired* per-pass
    ratio; the row reports the median of those ratios.  Pairing inside
    a pass cancels machine-wide drift that an unpaired best-of-N
    cannot — on a 2-vCPU container with CPU steal, unpaired sides can
    invert by ~10% on pure noise."""
    from repro.obs import trace

    bd = default_deployment()
    s = bd.register_stream("streamstore0", "bench.trace", ("signal",),
                           capacity=8192)
    bd.register_continuous(
        "bdstream(aggregate(window(bench.trace, 256), avg(signal)))",
        every_n_ticks=1, name="trace_cq")
    batch = rng.standard_normal(256)
    s.append({"signal": batch})
    bd.streams.tick()                         # warm the plan cache

    def _side(on: bool) -> float:
        trace.set_enabled(on)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            s.append({"signal": batch})
            bd.streams.tick()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    ratios, med = [], {False: [], True: []}
    prev = trace.enabled()
    try:
        for i in range(TRACE_PASSES):
            order = (False, True) if i % 2 == 0 else (True, False)
            pass_t = {}
            for on in order:
                pass_t[on] = _side(on)
            ratios.append(pass_t[True] / pass_t[False])
            for on, t in pass_t.items():
                med[on].append(t)
    finally:
        trace.set_enabled(prev)
        trace.reset()
    ratio = float(np.median(ratios))          # rate(off) / rate(on)
    off_us = float(np.median(med[False])) * 1e6
    on_us = float(np.median(med[True])) * 1e6
    assert ratio >= 0.85, (
        f"REPRO_TRACE=off ticks slower than tracing enabled: ratio "
        f"{ratio:.3f} (off={off_us:.1f}us on={on_us:.1f}us)")
    LAST_META["trace_overhead_ratio"] = round(ratio, 3)
    return ("stream/trace_overhead", ratio,
            f"off_us={off_us:.1f}_on_us={on_us:.1f}_w=256", "ratio")


def _jit_backend_ratio(bd, query: str, reps: int) -> Tuple[float, float,
                                                           float]:
    """(interp_us, jit_us, ratio) for one query: interleaved passes,
    per-pass median of ``reps`` executions, best pass per backend —
    bursty CPU steal hits both sides equally and cannot fake a
    regression.  Asserts bitwise parity while timing (the ratio of two
    *different* results would be meaningless)."""
    import os

    from repro.stream import compile as query_compile

    prev = os.environ.get(query_compile.BACKEND_ENV)
    best = {"interpreter": float("inf"), "jit": float("inf")}
    try:
        for be in best:                       # warm: plan cache + jit
            os.environ[query_compile.BACKEND_ENV] = be
            ref = bd.query(query).value
        for _ in range(JIT_PASSES):
            for be in best:
                os.environ[query_compile.BACKEND_ENV] = be
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    out = bd.query(query).value
                    ts.append(time.perf_counter() - t0)
                best[be] = min(best[be], float(np.median(ts)))
        cols = getattr(out, "columns", None) or out.attrs
        ref_cols = getattr(ref, "columns", None) or ref.attrs
        for k in cols:
            assert np.array_equal(np.asarray(cols[k]),
                                  np.asarray(ref_cols[k])), k
    finally:
        if prev is None:
            os.environ.pop(query_compile.BACKEND_ENV, None)
        else:
            os.environ[query_compile.BACKEND_ENV] = prev
    interp_us = best["interpreter"] * 1e6
    jit_us = best["jit"] * 1e6
    return interp_us, jit_us, interp_us / jit_us


def _jit_ratio_rows(rng, reps: int) -> List[Tuple]:
    from repro.stream import compile as query_compile

    rows: List[Tuple] = []
    if not query_compile.JAX_AVAILABLE:       # jitless host: skip rows
        return rows

    # sliding-window standing query over a deep ring
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "bench.jit", ("signal",),
                           capacity=16384)
    for _ in range(8):
        s.append({"signal": rng.standard_normal(2048)})
    interp_us, jit_us, ratio = _jit_backend_ratio(
        bd, "bdstream(window(bench.jit, 1024, 64))", reps)
    rows.append(("stream/jit_tick", ratio,
                 f"interp_us={interp_us:.1f}_jit_us={jit_us:.1f}_"
                 f"w=1024_slide=64", "ratio"))

    # banded interval join over a co-located 2-shard event-time pair
    bd_j = default_deployment()
    ev_rows = 4096
    a = bd_j.register_stream("streamstore0", "bench.jit_abp",
                             ("ts", "abp"), capacity=2 * ev_rows,
                             shards=2, num_engines=2, ts_field="ts",
                             max_delay=0.0)
    b = bd_j.register_stream("streamstore0", "bench.jit_ecg",
                             ("ts", "ecg"), capacity=2 * ev_rows,
                             shards=2, num_engines=2, ts_field="ts",
                             max_delay=0.0)
    ts = np.arange(ev_rows, dtype=np.float64)
    a.append({"ts": ts, "abp": 90.0 + np.sin(ts)})
    b.append({"ts": ts + 0.25, "ecg": np.cos(ts)})
    a.flush()
    b.flush()
    interp_us, jit_us, ratio = _jit_backend_ratio(
        bd_j, "bdstream(join(ewindow(bench.jit_abp, 2048),"
        " ewindow(bench.jit_ecg, 2048), on=ts, tol=2.0))", reps)
    rows.append(("stream/jit_join", ratio,
                 f"interp_us={interp_us:.1f}_jit_us={jit_us:.1f}_"
                 f"w=2048_tol=2.0_shards=2_colocated=True", "ratio"))
    LAST_META.update({"jit_tick_ratio": round(rows[0][1], 3),
                      "jit_join_ratio": round(ratio, 3)})
    return rows
