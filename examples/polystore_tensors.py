"""The paper's technique applied to model state (DESIGN.md §3): parameters,
optimizer moments and KV caches as polystore objects with engine placement
policies, moved only through Migrator casts — including the int8 quant cast
and a BQL look at the resulting catalog.

  PYTHONPATH=src python examples/polystore_tensors.py
"""
import sys

sys.path.insert(0, "src")

import jax                                                 # noqa: E402
import jax.numpy as jnp                                    # noqa: E402

from repro.core.api import default_deployment              # noqa: E402
from repro.core.tensorstore import (PlacementPolicy,       # noqa: E402
                                    TensorPolystore)
from repro.models import registry                          # noqa: E402
from repro.train.step import init_train_state              # noqa: E402


def main() -> None:
    cfg = registry.get_config("olmoe-1b-7b", reduced=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    state["opt"]["v"] = jax.tree.map(
        lambda p: jnp.abs(p.astype(jnp.float32)) * 0.02, state["params"])

    for moments in ("resident", "offload", "compressed"):
        bd = default_deployment()
        store = TensorPolystore(bd, PlacementPolicy(moments=moments))
        store.register_train_state(cfg.name, state)
        back = store.fetch_train_state(cfg.name)
        v0 = jax.tree.leaves(state["opt"]["v"])[0]
        v1 = jax.tree.leaves(back["opt"]["v"])[0]
        err = float(jnp.max(jnp.abs(jnp.asarray(v0) - jnp.asarray(v1))))
        engine = {"resident": "densehbm0", "offload": "hoststore0",
                  "compressed": "kvstore0"}[moments]
        stored = bd.engines[engine].list_objects()
        print(f"policy={moments:10s} -> moments engine={engine:10s} "
              f"roundtrip_err={err:.2e} objects={stored[:2]}...")

    print("\ncatalog view of the last deployment:")
    for row in bd.query("bdcatalog(select name, physical_db"
                        " from objects)").value:
        print("  ", row)


if __name__ == "__main__":
    main()
