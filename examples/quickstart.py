"""Quickstart: stand up the BigDAWG-style polystore, load the synthetic
MIMIC-II demo, and run the paper's §VI example queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.api import default_deployment            # noqa: E402
from repro.data.mimic import load_mimic_demo             # noqa: E402


def main() -> None:
    bd = default_deployment()
    load_mimic_demo(bd)
    print("engines:", ", ".join(sorted(bd.engines)))

    print("\n-- relational island (paper §VI-b) --")
    r = bd.query("bdrel(select * from mimic2v26.d_patients limit 4)")
    for i in range(r.value.num_rows):
        print("  ", {k: int(v[i]) for k, v in r.value.columns.items()})

    print("\n-- array island (paper §VI-c) --")
    r = bd.query("bdarray(filter(myarray, dim1>150))")
    print(f"   {int(r.value.mask().sum())} cells pass the filter")

    print("\n-- text island (paper §VI-d) --")
    r = bd.query("bdtext({ 'op' : 'range', 'table' : 'mimic_logs',"
                 " 'range' : { 'start' : ['r_0001','',''],"
                 " 'end' : ['r_0015','',''] } })")
    print(f"   {len(r.value)} rows;  first: {r.value[0]}")

    print("\n-- inter-island cast (paper §VI-e) --")
    q = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
         " mimic2v26.poe_order), poe_order_copy,"
         " '<subject_id:int32>[poe_id=0:*,10000000,0]', array)))")
    r = bd.query(q, training=True)
    print(f"   considered {r.plans_considered} plans; best: {r.qep_id}")
    for name, s in r.stages:
        print(f"   {name:36s} {s*1e3:8.2f} ms")

    print("\n-- catalog (paper §V.A) --")
    r = bd.query("bdcatalog(select name, connection_properties"
                 " from engines)")
    for row in r.value:
        print("  ", row)


if __name__ == "__main__":
    main()
