"""Serving driver: wave-batched prefill+decode with KV-cache pages stored
(optionally int8-quantized) in the polystore's KVStore engine.

  PYTHONPATH=src python examples/serve_lm.py --requests 6 --int8-kv
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                                 # noqa: E402
import numpy as np                                         # noqa: E402

from repro.core.api import default_deployment              # noqa: E402
from repro.core.tensorstore import (PlacementPolicy,       # noqa: E402
                                    TensorPolystore)
from repro.models import registry                          # noqa: E402
from repro.serve.engine import (Request, Scheduler,        # noqa: E402
                                ServeConfig, ServeSession)
from repro.train.step import init_train_state              # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, reduced=True)
    params = init_train_state(cfg, jax.random.PRNGKey(0))["params"]
    scfg = ServeConfig(max_batch=4, cache_len=64,
                       max_new_tokens=args.max_new)
    sess = ServeSession(cfg, params, scfg)
    sched = Scheduler(sess)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(4, 12))
        sched.submit(Request(rid, rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    done = sched.run()
    wall = time.time() - t0
    total_new = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.rid):
        print(f"req {c.rid}: {c.tokens.tolist()}"
              f"  (prefill {c.prefill_seconds*1e3:.0f} ms,"
              f" decode {c.decode_seconds*1e3:.0f} ms)")
    print(f"{len(done)} requests, {total_new} tokens,"
          f" {total_new/wall:.1f} tok/s")

    # park the final KV cache in the polystore (int8 pages if requested)
    bd = default_deployment()
    store = TensorPolystore(bd, PlacementPolicy(
        kv_codec="int8" if args.int8_kv else "raw"))
    cache = registry.init_cache(cfg, scfg.max_batch, scfg.cache_len)
    store.register_kv_cache(cfg.name, cache)
    print(f"kv cache registered in KVStore engine"
          f" (codec={'int8' if args.int8_kv else 'raw'}):",
          bd.engines["kvstore0"].list_objects())


if __name__ == "__main__":
    main()
