"""Streaming island demo (paper §III; arXiv:1609.07548's S-Store member):
continuous MIMIC waveform ingest + standing queries over the polystore.

Feeds the synthetic physiologic waveform into a ring-buffer stream batch
by batch; two standing BQL queries re-execute as data lands —

  wave_avg   every tick:    tumbling window -> binary cast into the array
                            island -> avg(signal)
  hr_table   every 4 ticks: sliding windows -> staged cast into the
                            relational island -> per-window max(hr)

The first tick of each query populates the signature plan cache; every
later tick skips plan enumeration (watch the cache_hits counter climb).

  PYTHONPATH=src python examples/streaming_mimic.py
"""
import json
import sys

sys.path.insert(0, "src")

from repro.core import admin                             # noqa: E402
from repro.core.api import default_deployment            # noqa: E402
from repro.data.mimic import stream_mimic_waveforms      # noqa: E402

WAVE_AVG = ("bdarray(aggregate(bdcast(bdstream(window("
            "mimic2v26.waveform_stream, 64)), w_arr,"
            " '<signal:double>[tick=0:63,64,0]', array), avg(signal)))")
HR_TABLE = ("bdrel(select max(hr) from bdcast(bdstream(window("
            "mimic2v26.waveform_stream, 64, 32)), w_tbl, '', relational))")


def main() -> None:
    bd = default_deployment()
    bd.register_continuous(WAVE_AVG, every_n_ticks=1, name="wave_avg")
    bd.register_continuous(HR_TABLE, every_n_ticks=4, name="hr_table")

    print("-- feeding 24 waveform batches (64 rows each) --")
    for info in stream_mimic_waveforms(bd, batch_rows=64, num_batches=24,
                                       capacity=1024):
        ran = ", ".join(f"{n}{'*' if hit else ''}" for n, hit in
                        info["ran"]) or "-"
        print(f"   batch {info['batch']:2d}  rows={info['rows']:4d}"
              f"  dropped={info['dropped']}  ran: {ran}   (*=cache hit)")

    print("\n-- standing query state --")
    for name, cq in bd.streams.queries.items():
        m = cq.metrics()
        print(f"   {name}: {m['executions']} executions,"
              f" {m['cache_hits']} plan-cache hits,"
              f" p50 {m['p50_latency_ms']} ms")

    print("\n-- streams status (admin §IV) --")
    print(json.dumps(admin.status(bd)["streams"], indent=1))

    print("\n-- plan cache --")
    print(json.dumps(admin.status(bd)["plan_cache"], indent=1))


if __name__ == "__main__":
    main()
