"""Streaming island demo (paper §III; arXiv:1609.07548's S-Store member):
continuous MIMIC waveform ingest + standing queries over the polystore.

Feeds the synthetic physiologic waveform into a ring-buffer stream batch
by batch; two standing BQL queries re-execute as data lands —

  wave_avg   every tick:    tumbling window -> binary cast into the array
                            island -> avg(signal)
  hr_table   every 4 ticks: sliding windows -> staged cast into the
                            relational island -> per-window max(hr)

The first tick of each query populates the signature plan cache; every
later tick skips plan enumeration (watch the cache_hits counter climb).

A second deployment then shards the same stream across two StreamEngines
(scatter appends, seq-ordered gathers — bit-identical results) and
live-migrates a shard between engines mid-standing-query.

  PYTHONPATH=src python examples/streaming_mimic.py
"""
import json
import sys

sys.path.insert(0, "src")

from repro.core import admin                             # noqa: E402
from repro.core.api import default_deployment            # noqa: E402
from repro.data.mimic import stream_mimic_waveforms      # noqa: E402

WAVE_AVG = ("bdarray(aggregate(bdcast(bdstream(window("
            "mimic2v26.waveform_stream, 64)), w_arr,"
            " '<signal:double>[tick=0:63,64,0]', array), avg(signal)))")
HR_TABLE = ("bdrel(select max(hr) from bdcast(bdstream(window("
            "mimic2v26.waveform_stream, 64, 32)), w_tbl, '', relational))")


def main() -> None:
    bd = default_deployment()
    bd.register_continuous(WAVE_AVG, every_n_ticks=1, name="wave_avg")
    bd.register_continuous(HR_TABLE, every_n_ticks=4, name="hr_table")

    print("-- feeding 24 waveform batches (64 rows each) --")
    for info in stream_mimic_waveforms(bd, batch_rows=64, num_batches=24,
                                       capacity=1024):
        ran = ", ".join(f"{n}{'*' if hit else ''}" for n, hit in
                        info["ran"]) or "-"
        print(f"   batch {info['batch']:2d}  rows={info['rows']:4d}"
              f"  dropped={info['dropped']}  ran: {ran}   (*=cache hit)")

    print("\n-- standing query state --")
    for name, cq in bd.streams.queries.items():
        m = cq.metrics()
        print(f"   {name}: {m['executions']} executions,"
              f" {m['cache_hits']} plan-cache hits,"
              f" p50 {m['p50_latency_ms']} ms")

    print("\n-- streams status (admin §IV) --")
    print(json.dumps(admin.status(bd)["streams"], indent=1))

    print("\n-- plan cache --")
    print(json.dumps(admin.status(bd)["plan_cache"], indent=1))

    # -- sharded scale-out: same stream, 4 shards over 2 StreamEngines ----
    print("\n-- sharded streaming (4 shards / 2 engines) --")
    bds = default_deployment()
    # prime the stream with one complete 64-window before registering
    # the standing query, so every tick below has a window to aggregate
    for info in stream_mimic_waveforms(bds, batch_rows=32, num_batches=2,
                                       capacity=1024, shards=4,
                                       num_engines=2):
        pass
    # pure-streaming aggregate: takes the rolling fast path (per-shard
    # partials + per-window memo).  Batches are half a window, so every
    # other tick re-reads the same window index — a memo hit.
    bds.register_continuous(
        "bdstream(aggregate(window(mimic2v26.waveform_stream, 64),"
        " avg(signal)))", every_n_ticks=1, name="wave_avg")
    for info in stream_mimic_waveforms(bds, batch_rows=32,
                                       num_batches=22, capacity=1024):
        pass
    sharded = bds.engines["streamstore0"].get("mimic2v26.waveform_stream")
    print("   shard placement:", sharded.shard_engines())
    agg_total = sharded.agg_computes + sharded.agg_cache_hits
    print(f"   rolling-agg cache hits: {sharded.agg_cache_hits}"
          f"/{agg_total}")
    move = bds.rebalance_stream("mimic2v26.waveform_stream", shard=0,
                                to_engine="streamstore1")
    print("   live shard move:", move)
    for info in stream_mimic_waveforms(bds, batch_rows=64, num_batches=4,
                                       capacity=1024):
        pass
    cq = bds.streams.queries["wave_avg"]
    print(f"   standing query after move: {cq.executions} executions,"
          f" {cq.errors} errors (continuity preserved)")


if __name__ == "__main__":
    main()
