"""End-to-end training driver (deliverable (b)): train a ~100M-parameter
qwen2-family model with the full substrate — deterministic data pipeline,
AdamW + cosine schedule, microbatch accumulation, checkpoint/restart with
failure injection, straggler monitoring, and polystore-registered state.

  PYTHONPATH=src python examples/train_lm.py --steps 300   # full run
  PYTHONPATH=src python examples/train_lm.py --steps 8     # smoke
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax                                                # noqa: E402
import jax.numpy as jnp                                   # noqa: E402

from repro.checkpoint.manager import CheckpointManager    # noqa: E402
from repro.core.api import default_deployment             # noqa: E402
from repro.core.tensorstore import (PlacementPolicy,      # noqa: E402
                                    TensorPolystore)
from repro.data.pipeline import DataConfig, TokenDataset  # noqa: E402
from repro.models import registry                         # noqa: E402
from repro.optim.adamw import AdamWConfig                 # noqa: E402
from repro.runtime.fault import (FailureInjector,         # noqa: E402
                                 run_with_recovery)
from repro.train.step import (TrainConfig,                # noqa: E402
                              init_train_state, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=registry.ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=512,
                    help="width of the ~100M CPU-trainable variant")
    ap.add_argument("--layers", type=int, default=8)
    args = ap.parse_args()

    # ~100M-param CPU-trainable variant of the chosen family
    cfg = registry.get_config(args.arch, reduced=True)
    cfg = dataclasses.replace(
        cfg, d_model=args.d_model, num_layers=args.layers,
        num_heads=max(4, args.d_model // 128),
        num_kv_heads=max(2, args.d_model // 256),
        head_dim=min(128, args.d_model // 4),
        d_ff=args.d_model * 4, vocab_size=32768)
    from repro.sharding import logical as L
    n = L.count_params(registry.param_specs(cfg))
    print(f"arch={cfg.name} variant: {n/1e6:.1f}M params")

    tcfg = TrainConfig(optimizer=AdamWConfig(
        learning_rate=3e-4, warmup_steps=20, total_steps=args.steps),
        microbatches=2)
    step_jit = jax.jit(make_train_step(cfg, tcfg))
    ds = TokenDataset(cfg, DataConfig(seq_len=args.seq_len,
                                      global_batch=args.batch))
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    bd = default_deployment()
    store = TensorPolystore(bd, PlacementPolicy(moments="resident"))

    log = {"t0": time.time()}

    def step_fn(state, i):
        batch = jax.tree.map(jnp.asarray, ds.batch_at(i))
        state, metrics = step_jit(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.time() - log["t0"]
            toks = args.batch * args.seq_len
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}"
                  f"  gnorm={float(metrics['grad_norm']):.2f}"
                  f"  lr={float(metrics['lr']):.2e}"
                  f"  ({toks/max(dt,1e-9):,.0f} tok/s)", flush=True)
            log["t0"] = time.time()
        return state

    injector = None
    if args.inject_failure_at is not None:
        injector = FailureInjector({args.inject_failure_at: 0})

    report = run_with_recovery(
        init_state=lambda: init_train_state(cfg, jax.random.PRNGKey(0)),
        step_fn=step_fn, ckpt=ckpt, num_steps=args.steps,
        checkpoint_every=25, injector=injector)
    print(f"done: {report.steps_run} steps,"
          f" {report.failures_recovered} failures recovered"
          f" (restarts at {report.restarts})")

    final, step = ckpt.restore(
        init_train_state(cfg, jax.random.PRNGKey(0)))
    store.register_train_state(cfg.name, final)
    rows = bd.query("bdcatalog(select name from objects)").value
    print(f"polystore objects: {[r['name'] for r in rows]}")


if __name__ == "__main__":
    main()
