"""Checkpointing: async sharded save, atomic manifest promote, keep-last-k,
and **elastic restore** — a checkpoint written under one mesh restores onto
any other mesh (leaves are saved as global arrays; restore re-shards via
device_put with the new NamedSharding).  This is the restart path for node
failures and for elastic re-scaling (DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        # serializes _write (tmp dir + promote + keep-last-k prune): a
        # blocking save overlapping an async one must never let _gc
        # prune a sibling's half-written .tmp or race two promotes
        self._write_lock = threading.Lock()

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state, *, blocking: bool = True) -> str:
        """Write state under <dir>/step_<n>.tmp then atomically promote.

        Any still-pending async save is joined first — for BOTH modes.
        A blocking save that skipped the join could run its keep-last-k
        prune while the async thread is still writing, deleting the
        in-flight checkpoint mid-write (and _gc could even prune the
        promoted-but-newer step).  Join-then-write keeps saves strictly
        ordered."""
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        self.wait()
        if blocking:
            return self._write(step, host_state)
        self._pending = threading.Thread(
            target=self._write, args=(step, host_state), daemon=True)
        self._pending.start()
        return os.path.join(self.directory, f"step_{step:08d}")

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_state) -> str:
        # crash points let the durability test layer kill a save between
        # the tmp write, the atomic promote, and the prune (late import:
        # runtime.fault imports this module)
        from repro.runtime.fault import crash_point

        with self._write_lock:
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten_with_paths(host_state)
            manifest = {"step": step, "leaves": {}, "time": time.time()}
            for key, leaf in flat.items():
                fname = key.replace("/", "__") + ".npy"
                arr = np.asarray(leaf)
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            crash_point("checkpoint/promote")   # tmp complete, not live
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)              # atomic promote
            crash_point("checkpoint/gc")        # promoted, not pruned
            self._gc()
            return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``.  When ``shardings``
        (a matching pytree of NamedShardings) is given, each leaf is placed
        with device_put — this is what makes restore *elastic*: the target
        mesh may differ from the one that wrote the checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        flat_template = _flatten_with_paths(template)
        flat_shard = (_flatten_with_paths(shardings)
                      if shardings is not None else {})
        restored = {}
        for key in flat_template:
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            if key in flat_shard and flat_shard[key] is not None:
                restored[key] = jax.device_put(arr, flat_shard[key])
            else:
                restored[key] = jax.numpy.asarray(arr)

        # rebuild the tree in template order
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(
            template)
        ordered = []
        for pth, _ in leaves_paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in pth)
            ordered.append(restored[key])
        return jax.tree_util.tree_unflatten(treedef, ordered), step

    def restore_flat(self, step: Optional[int] = None
                     ) -> Dict[str, np.ndarray]:
        """Template-free restore: the manifest's leaves as a flat
        {key: host array} dict.  This is the recovery entry point for
        callers that serialize self-describing state (e.g. the stream
        durability layer) — after a crash there is no live object to
        borrow a template pytree from."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return {key: np.load(os.path.join(path, meta["file"]))
                for key, meta in manifest["leaves"].items()}
