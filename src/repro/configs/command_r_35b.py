"""Command R 35B [hf:CohereForAI/c4ai-command-r-v01; unverified]: 40L d8192
64H(kv8) d_ff=22528 vocab 256000; parallel block, tied embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000, head_dim=128,
    parallel_block=True, norm_kind="layernorm", tie_embeddings=True,
    rope_theta=8000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256)
