"""Command R+ 104B [hf:CohereForAI; unverified]: 64L d12288 96H(kv8)
d_ff=33792 vocab 256000; cohere-style parallel attn+FFN block, LayerNorm
(no bias handled via layernorm specs), tied embeddings, no qkv bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000, head_dim=128,
    parallel_block=True, norm_kind="layernorm", tie_embeddings=True,
    rope_theta=75000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256)
