"""DeepSeek-Coder-33B [arXiv:2401.14196; hf]: llama-arch, 62L d7168
56H(kv8) d_ff=19200 vocab 32256, RMSNorm + swiglu + rope."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128,
    rope_theta=100000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256)
