"""DeepSeekMoE-16B [arXiv:2401.06066; hf]: 28L d2048 16H(kv16), fine-grained
MoE 64 routed top-6 + 2 shared experts (d_ff=1408 each), first layer dense
(d_ff=10944), vocab 102400."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    num_experts=64, num_shared_experts=2, top_k=6, moe_d_ff=1408,
    moe_every=1, first_k_dense=1, dense_d_ff=10944,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=96, moe_d_ff=96, vocab_size=256, num_experts=4,
        num_shared_experts=2, top_k=2, first_k_dense=1, dense_d_ff=160)
