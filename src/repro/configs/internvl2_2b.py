"""InternVL2-2B [arXiv:2404.16821; hf]: InternLM2-1.8B backbone 24L d2048
16H(kv8) d_ff=8192 vocab 92553 + InternViT frontend (STUB: input_specs
provides 256 patch embeddings prepended to the text sequence)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    frontend="vision", num_prefix_embeds=256, rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, num_prefix_embeds=8)
