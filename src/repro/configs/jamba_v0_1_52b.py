"""Jamba-v0.1-52B [arXiv:2403.19887; hf]: 32L d4096, Mamba:attention 7:1
interleave (attn at sub-layer 4 of each period-8 block), MoE 16e top-2 on
odd sub-layers (d_ff=14336 per expert), 32H(kv8), vocab 65536; runs
long_500k (hybrid sub-quadratic decode)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    num_experts=16, top_k=2, moe_d_ff=14336, moe_every=2, moe_offset=1,
    ssm_kind="mamba", attn_every=8, attn_offset=4,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, moe_d_ff=96, vocab_size=256, num_experts=4,
        top_k=2, attn_every=4, attn_offset=2, moe_every=2, moe_offset=1,
        ssm_state=8, ssm_conv=4)
