"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 16L d2048 16H(kv16) MoE 64e top-8,
per-expert d_ff=1024, vocab 50304, QK-norm, RMSNorm, swiglu."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    num_experts=64, top_k=8, moe_d_ff=1024, moe_every=1,
    qk_norm=True, rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=96, moe_d_ff=96, vocab_size=256, num_experts=4,
        top_k=2)
