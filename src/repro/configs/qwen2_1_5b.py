"""Qwen2-1.5B [arXiv:2407.10671; hf]: 28L d1536 12H(kv2) d_ff=8960
vocab 151936, QKV bias, RMSNorm + swiglu, tied embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    qkv_bias=True, tie_embeddings=True, rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256)
