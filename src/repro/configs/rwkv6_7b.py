"""RWKV6-7B "Finch" [arXiv:2404.05892; hf]: 32L d4096, attention-free
data-dependent-decay linear recurrence, d_ff=14336 channel mix,
vocab 65536, head size 64; runs long_500k (O(1) decode state)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536, head_dim=64,
    ssm_kind="rwkv6", attn_every=0, rwkv_head_dim=64,
    norm_kind="layernorm",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=1, num_kv_heads=1,
        head_dim=64, rwkv_head_dim=32, d_ff=128, vocab_size=256)
