"""SeamlessM4T-medium [arXiv:2308.11596; hf]: enc-dec, 12L enc + 12L dec,
d1024 16H(kv16) d_ff=4096 vocab 256206; audio frontend STUB (input_specs
provides frame embeddings), LayerNorm + gelu FFN, learned positions."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    encoder_layers=12, cross_attention=True, frontend="audio",
    norm_kind="layernorm", mlp_kind="gelu", src_ratio=4,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256)
