"""Assigned input shapes (LM-family): each cell = (arch x shape).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``.  ``long_500k`` requires sub-quadratic
decode and is only run for SSM/hybrid archs (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = tuple(SHAPES)


def applicable(cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full-attention arch: 512k decode is quadratic; "
                       "skipped per brief (DESIGN.md §4)")
    return True, ""
