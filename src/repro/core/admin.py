"""Administrative interface (paper §IV): start, stop and view the status
of a BigDAWG setup.  Programmatic API + a small CLI:

  PYTHONPATH=src python -m repro.core.admin status
  PYTHONPATH=src python -m repro.core.admin streams    # live streaming demo
  PYTHONPATH=src python -m repro.core.admin rebalance  # shard-move demo
  PYTHONPATH=src python -m repro.core.admin joins      # event-time join demo
  PYTHONPATH=src python -m repro.core.admin ml         # scored-stream demo

See docs/OPERATIONS.md for the status() JSON schema and every knob.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict

from repro.core.api import BigDawg, default_deployment
from repro.core import datamodel as dm


def status(bd: BigDawg) -> Dict[str, Any]:
    """Deployment status: engines, islands, objects, monitor health.

    Monitor-sourced sections all render one ``Monitor.snapshot()`` —
    a deep copy taken under the Monitor lock — because the background
    MonitoringTask / StreamRuntime tick mutate the live dicts
    concurrently (iterating ``monitor.engine_ewma`` etc. directly from
    this thread raced and could die mid-resize).  The same series are
    exported through ``repro.obs.metrics`` (``admin metrics``)."""
    out: Dict[str, Any] = {"engines": {}, "islands": {}, "monitor": {}}
    for name, engine in bd.engines.items():
        objs = engine.list_objects()
        out["engines"][name] = {
            "kind": engine.kind,
            "objects": len(objs),
            "bytes": int(sum(
                dm.object_nbytes(engine.get(o)) for o in objs)),
            "ops_logged": len(engine.op_log),
            "ops_recorded": engine.ops_recorded,
            "op_log_limit": engine.OP_LOG_LIMIT,
        }
    for isl in bd.catalog.islands.values():
        out["islands"][isl.name] = [
            e.name for e in bd.catalog.engines_for_island(isl.name)]
    snap = bd.monitor.snapshot()
    out["monitor"] = {
        "engine_ewma_ms": {k: round(v * 1e3, 3)
                           for k, v in snap["engine_ewma"].items()},
        "stragglers": snap["stragglers"],
        "monitoring_task_running": bd.monitoring_task is not None,
    }
    cfg = bd.planner_config
    out["concurrency"] = {
        "executor_mode": cfg.executor.mode,
        "executor_max_workers": cfg.executor.max_workers,
        "plan_parallelism": cfg.plan_parallelism,
        "early_cancel": cfg.early_cancel,
        "early_cancel_margin": cfg.early_cancel_margin,
        "cost_model_cancels": bd.planner.cost_model_cancels,
    }
    # streaming island: per-stream ring-buffer health + standing queries
    out["streams"] = bd.streams.status()
    out["streams"]["monitor_ewma_ms"] = {
        k: round(v * 1e3, 3) for k, v in snap["stream_ewma"].items()}
    # event-time health: per-stream low watermark + late/pending rows
    # (the Monitor's copy, fed every tick — matches each stream's stats)
    out["streams"]["watermarks"] = snap["stream_watermarks"]
    # multi-producer ingest health: per-stream producer counts, seq
    # blocks reserved, in-flight rows and ordered-commit contention
    # (the Monitor's per-tick copy of stream.ingest_concurrency())
    out["streams"]["ingest_concurrency"] = snap["ingest_stats"]
    # compiled query path: active backend plus plan-compile/cache-hit/
    # fallback counters (the Monitor's per-tick copy of
    # repro.stream.compile.stats(); fallbacks stay 0 on a healthy lane)
    out["streams"]["query_backend"] = snap["jit_stats"]
    # durability: per-stream segment-log/checkpoint counters and the
    # last recover_stream outcome (fed per tick for durable streams)
    out["streams"]["durability"] = snap["durability_stats"]
    out["streams"]["recoveries"] = snap["recoveries"]
    # serving front door: tenants, subscriptions, shared queries,
    # admission rejects, delivered/dropped results, replicas (the
    # Monitor's copy of FrontDoor.stats(); empty without a front door)
    out["serve"] = snap["serve_stats"]
    # ml island: inference counters (models loaded, waves, windows
    # scored, params-cache hits, jax fallbacks) — the Monitor's per-tick
    # copy of repro.stream.ml.stats(); empty until an ml engine ticks
    out["ml"] = snap["ml_stats"]
    out["plan_cache"] = dict(bd.planner.plan_cache.stats(),
                             capacity=cfg.cache_size,
                             max_age_seconds=cfg.cache_max_age_seconds)
    out["catalog"] = {t: len(getattr(bd.catalog, t))
                      for t in bd.catalog.TABLES}
    return out


def rebalance(bd: BigDawg, factor: float = 3.0) -> Dict[str, Any]:
    """The shard rebalance hook: for every sharded stream whose Monitor
    per-shard ingest/drop stats have gone lopsided (a shard's load >
    ``factor`` x the median shard's), move one shard off the busiest
    StreamEngine through the Migrator's live ``stream`` route.  Returns
    {"moves": [...], "skipped": [...]} — a lopsided stream is skipped
    when no move would even out the per-engine load (e.g. every engine
    already holds exactly one shard)."""
    moves, skipped = [], []
    for name in sorted(bd.streams._sharded_streams()):
        hot = bd.monitor.lopsided_shards(name, factor=factor)
        if not hot:
            continue
        try:
            moves.append(bd.streams.rebalance(name))
        except ValueError as exc:
            skipped.append({"stream": name, "hot_shards": hot,
                            "reason": str(exc)})
    return {"moves": moves, "skipped": skipped}


def start(bd: BigDawg, interval_seconds: float = 30.0) -> None:
    """Start the background MonitoringTask daemon (paper §V.E)."""
    task = bd.start_monitoring(interval_seconds)
    task.start()


def stop(bd: BigDawg) -> None:
    if bd.monitoring_task is not None:
        bd.monitoring_task.stop()
        bd.monitoring_task = None


def _demo_streams(bd: BigDawg, ticks: int) -> None:
    """The ``streams`` demo feed (shared by the trace/metrics
    commands): a standing cross-island window-average query over the
    synthetic MIMIC waveform stream, one execution per batch."""
    from repro.data.mimic import stream_mimic_waveforms
    bd.register_continuous(
        "bdarray(aggregate(bdcast(bdstream(window("
        "mimic2v26.waveform_stream, 64)), w_arr,"
        " '<signal:double>[tick=0:63,64,0]', array), avg(signal)))",
        every_n_ticks=1, name="wave_avg")
    for _ in stream_mimic_waveforms(bd, batch_rows=64,
                                    num_batches=ticks):
        pass


def main() -> None:
    from repro.core.executor import ExecutorConfig
    from repro.core.planner import PlannerConfig

    ap = argparse.ArgumentParser(description="BigDAWG admin interface")
    ap.add_argument("command",
                    choices=("status", "demo-status", "streams",
                             "rebalance", "joins", "trace", "metrics",
                             "recover", "serve", "ml"))
    ap.add_argument("--tenants", type=int, default=4,
                    help="synthetic tenants for the serve demo")
    ap.add_argument("--ticks", type=int, default=8,
                    help="feed batches for the streams/rebalance/trace/"
                         "metrics commands")
    ap.add_argument("--out", type=str, default="trace.json",
                    help="Chrome trace-event JSON output path for the "
                         "trace command (load in Perfetto)")
    ap.add_argument("--dir", type=str, default=None,
                    help="durability directory for the recover demo "
                         "(default: a fresh temp dir)")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count for the rebalance demo stream")
    ap.add_argument("--stream-engines", type=int, default=2,
                    help="StreamEngines for the rebalance demo")
    ap.add_argument("--executor-mode", choices=("concurrent", "serial"),
                    default="concurrent",
                    help="stage scheduler: overlapped DAG or serial")
    ap.add_argument("--executor-workers", type=int, default=4,
                    help="thread budget for concurrent stage execution")
    ap.add_argument("--plan-parallelism", type=int, default=4,
                    help="concurrent QEPs during training-mode exploration")
    ap.add_argument("--plan-cache-size", type=int, default=128,
                    help="signature-keyed plan cache LRU capacity")
    args = ap.parse_args()
    if args.command == "trace":
        # the trace demo runs the jit query backend by default (unless
        # the caller pinned one) so the export carries compile-layer
        # spans alongside planner/executor, stream tick and committer
        import os
        os.environ.setdefault("REPRO_QUERY_BACKEND", "jit")
    if args.command == "rebalance" and args.shards < 2:
        ap.error("rebalance demo needs --shards >= 2 (a single ring "
                 "has nothing to move)")
    cfg = PlannerConfig(
        plan_parallelism=args.plan_parallelism,
        cache_size=args.plan_cache_size,
        executor=ExecutorConfig(mode=args.executor_mode,
                                max_workers=args.executor_workers))
    bd = default_deployment(planner_config=cfg)
    if args.command == "demo-status":
        from repro.data.mimic import load_mimic_demo
        load_mimic_demo(bd)
    elif args.command == "rebalance":
        # live-migration demo: a key-hashed sharded stream fed a skewed
        # key distribution goes lopsided; the rebalance hook moves a
        # shard off the hot StreamEngine while a standing query runs
        import numpy as np
        from repro.stream.spec import Sharding, StreamSpec
        bd.register_stream("streamstore0", StreamSpec(
            "vitals.stream", ("patient", "hr"), capacity=4096,
            sharding=Sharding(shards=args.shards, shard_key="patient",
                              num_engines=args.stream_engines)))
        bd.register_continuous(
            "bdstream(aggregate(window(vitals.stream, 64), avg(hr)))",
            every_n_ticks=1, name="hr_avg")
        rng = np.random.default_rng(0)
        stream = bd.engines["streamstore0"].get("vitals.stream")
        for _ in range(args.ticks):
            # heavy hitter: ~85% of rows are one patient, hashing onto a
            # single shard — the classic skew that strands one engine hot
            patient = np.where(
                rng.random(256) < 0.85, 1.0,
                rng.integers(0, 4 * args.shards, 256).astype(float))
            stream.append({"patient": patient,
                           "hr": 75 + rng.standard_normal(256)})
            bd.streams.tick()
        before = {i: s["engine"] for i, s in
                  bd.monitor.shard_stats.get("vitals.stream",
                                             {}).items()}
        outcome = rebalance(bd)
        after = {i: s["engine"] for i, s in
                 stream.shard_stats().items()}
        st = status(bd)
        print(json.dumps({
            "shards_before": before, "rebalance": outcome,
            "shards_after": after,
            "standing_query": st["streams"]["queries"]["hr_avg"],
        }, indent=1))
        return
    elif args.command == "joins":
        # event-time demo: two jittered out-of-order MIMIC waveform
        # streams (ABP + ECG) with a standing cross-stream interval join
        # that ticks only when the low watermark advances
        from repro.data.mimic import stream_mimic_paired_waveforms
        cq = bd.register_continuous(
            "bdstream(join(ewindow(mimic2v26.abp_stream, 16),"
            " ewindow(mimic2v26.ecg_stream, 16), on=ts, tol=0.5))",
            every_n_ticks=1, name="abp_ecg_join")
        last = None
        for info in stream_mimic_paired_waveforms(bd,
                                                  num_batches=args.ticks):
            last = info
        st = status(bd)
        joined = cq.last_value
        print(json.dumps({
            "feed_tail": last,
            "standing_join": st["streams"]["queries"]["abp_ecg_join"],
            "watermarks": st["streams"]["watermarks"],
            "joined_rows": (0 if joined is None
                            else len(joined.columns["dt"])),
        }, indent=1))
        return
    elif args.command == "streams":
        # live streaming island demo: feed the synthetic MIMIC waveform
        # stream, run a standing window-average query on every batch
        _demo_streams(bd, args.ticks)
        st = status(bd)
        print(json.dumps({"streams": st["streams"],
                          "plan_cache": st["plan_cache"]}, indent=1))
        return
    elif args.command == "trace":
        # run the streams demo with tracing on and export the span ring:
        # Chrome trace-event JSON (Perfetto-loadable) + text flamegraph
        from repro.obs import trace
        trace.set_enabled(True)
        trace.reset()
        _demo_streams(bd, args.ticks)
        recorded = trace.spans()
        n_events = trace.save_chrome_trace(args.out, recorded)
        print(trace.flamegraph(recorded))
        slow = trace.slow_ops()
        print(json.dumps({
            "out": args.out, "spans": n_events,
            "layers": sorted({r.name.split("/", 1)[0]
                              for r in recorded}),
            "slow_ops": slow[-5:],
            "slow_op_threshold_ms": trace.slow_op_threshold_ms(),
        }, indent=1))
        return
    elif args.command == "recover":
        # durability demo: feed a durable sharded stream (checkpoints on
        # tick cadence), "crash" by discarding the deployment, rebuild a
        # fresh one with recover_stream, and prove the recovered stream
        # is bit-identical — then replay(S) as a deterministic load gen
        import tempfile
        import numpy as np
        from repro.stream.durability import fingerprint
        from repro.stream.spec import Durability, Sharding, StreamSpec
        wal_dir = args.dir or tempfile.mkdtemp(prefix="bigdawg_wal_")
        stream = bd.register_stream("streamstore0", StreamSpec(
            "vitals.stream", ("patient", "hr"), capacity=4096,
            sharding=Sharding(shards=2),
            durability=Durability(wal_dir,
                                  checkpoint_every_rows=256)))
        rng = np.random.default_rng(0)
        for _ in range(args.ticks):
            stream.append({
                "patient": rng.integers(0, 8, 128).astype(float),
                "hr": 75 + rng.standard_normal(128)})
            bd.streams.tick()
        # a tail batch past the last checkpoint, so recovery actually
        # replays from the segment log rather than only restoring
        stream.append({"patient": rng.integers(0, 8, 64).astype(float),
                       "hr": 75 + rng.standard_normal(64)})
        before = fingerprint(stream)
        stream._durable.close()
        bd2 = default_deployment(planner_config=cfg)   # the "restart"
        recovered = bd2.recover_stream("streamstore0", wal_dir)
        identical = fingerprint(recovered) == before
        replay_stats = bd2.query(
            "bdstream(replay(vitals.stream))").value
        st = status(bd2)
        print(json.dumps({
            "dir": wal_dir, "identical": identical,
            "rows": recovered.total_appended,
            "durability": st["streams"]["durability"],
            "recovery": st["streams"]["recoveries"],
            "replay": {k: v[0] for k, v in
                       replay_stats.columns.items()},
        }, indent=1, default=float))
        return
    elif args.command == "serve":
        # serving front-door demo: N synthetic tenants share one
        # standing window-average over a spec-registered stream; the
        # middle tenant also gets a private cadence-2 query.  Prints
        # the serve health block admin.status() renders.
        import numpy as np
        from repro.serve.engine import ServeConfig
        from repro.serve.frontdoor import FrontDoor
        from repro.stream.spec import StreamSpec
        door = FrontDoor(bd, ServeConfig(streams=(
            StreamSpec("vitals.stream", ("ts", "hr"),
                       capacity=4096),)),
            stream_engine="streamstore0",
            max_tenants=max(1, args.tenants))
        shared_q = ("bdstream(aggregate(window(vitals.stream, 64),"
                    " avg(hr)))")
        subs = []
        for i in range(max(1, args.tenants)):
            session = door.open_session(f"tenant{i}")
            subs.append(session.subscribe(shared_q))
            if i == args.tenants // 2:
                session.subscribe(
                    "bdstream(rate(vitals.stream))", every_n_ticks=2)
        rng = np.random.default_rng(0)
        stream = bd.engines["streamstore0"].get("vitals.stream")
        for t in range(args.ticks):
            stream.append({"ts": np.arange(64.) + 64 * t,
                           "hr": 75 + rng.standard_normal(64)})
            bd.streams.tick()
        delivered = [len(s.poll()) for s in subs]
        st = status(bd)
        print(json.dumps({
            "serve": st["serve"],
            "delivered_per_tenant": delivered,
            "standing_queries": sorted(st["streams"]["queries"]),
        }, indent=1))
        door.close()
        return
    elif args.command == "ml":
        # ml-island demo: standing anomaly scoring over the jittered
        # out-of-order ABP/ECG paired-waveform feed.  Every tenant
        # subscribes the same scored query through the front door, so
        # warm sharing collapses N tenants to one infer execution per
        # tick — and the wave scheduler batches the ABP + ECG standing
        # queries into a single wave per tick.  Scores are mean
        # next-token NLL under the registered model: windows the model
        # finds unlikely (rhythm breaks, jitter artifacts) score high.
        from repro.data.mimic import stream_mimic_paired_waveforms
        from repro.serve.engine import ServeConfig
        from repro.serve.frontdoor import FrontDoor
        bd.register_model("lm")
        feed = stream_mimic_paired_waveforms(bd, num_batches=args.ticks)
        last = next(feed)                   # registers the two streams
        door = FrontDoor(bd, ServeConfig(),
                         stream_engine="streamstore0",
                         max_tenants=max(1, args.tenants))
        scored_abp = ("bdml(infer(ewindow(mimic2v26.abp_stream, 16.0),"
                      " models.lm, field=abp))")
        scored_ecg = ("bdml(infer(ewindow(mimic2v26.ecg_stream, 16.0),"
                      " models.lm, field=ecg))")
        subs = []
        for i in range(max(1, args.tenants)):
            session = door.open_session(f"tenant{i}")
            subs.append(session.subscribe(scored_abp))
            if i == 0:
                session.subscribe(scored_ecg)
        for last in feed:
            pass
        results = subs[0].poll()
        st = status(bd)
        print(json.dumps({
            "feed_tail": last,
            "ml": st["ml"],
            "serve": {k: st["serve"].get(k) for k in
                      ("tenants", "subscriptions", "shared_queries")},
            "delivered_to_tenant0": len(results),
            "abp_scores": [round(float(v.columns["score"][0]), 4)
                           for _, v in results],
            "standing_queries": sorted(st["streams"]["queries"]),
        }, indent=1))
        door.close()
        return
    elif args.command == "metrics":
        # run the streams demo, then dump the process-wide registry in
        # Prometheus text exposition format (what /metrics serves)
        from repro.obs import metrics
        _demo_streams(bd, args.ticks)
        print(metrics.prometheus_text(), end="")
        return
    print(json.dumps(status(bd), indent=1))


if __name__ == "__main__":
    main()
