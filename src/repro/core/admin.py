"""Administrative interface (paper §IV): start, stop and view the status
of a BigDAWG setup.  Programmatic API + a small CLI:

  PYTHONPATH=src python -m repro.core.admin status
  PYTHONPATH=src python -m repro.core.admin streams   # live streaming demo
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict

from repro.core.api import BigDawg, default_deployment
from repro.core import datamodel as dm


def status(bd: BigDawg) -> Dict[str, Any]:
    """Deployment status: engines, islands, objects, monitor health."""
    out: Dict[str, Any] = {"engines": {}, "islands": {}, "monitor": {}}
    for name, engine in bd.engines.items():
        objs = engine.list_objects()
        out["engines"][name] = {
            "kind": engine.kind,
            "objects": len(objs),
            "bytes": int(sum(
                dm.object_nbytes(engine.get(o)) for o in objs)),
            "ops_logged": len(engine.op_log),
            "ops_recorded": engine.ops_recorded,
            "op_log_limit": engine.OP_LOG_LIMIT,
        }
    for isl in bd.catalog.islands.values():
        out["islands"][isl.name] = [
            e.name for e in bd.catalog.engines_for_island(isl.name)]
    out["monitor"] = {
        "engine_ewma_ms": {k: round(v * 1e3, 3)
                           for k, v in bd.monitor.engine_ewma.items()},
        "stragglers": bd.monitor.stragglers(),
        "monitoring_task_running": bd.monitoring_task is not None,
    }
    cfg = bd.planner_config
    out["concurrency"] = {
        "executor_mode": cfg.executor.mode,
        "executor_max_workers": cfg.executor.max_workers,
        "plan_parallelism": cfg.plan_parallelism,
        "early_cancel": cfg.early_cancel,
        "early_cancel_margin": cfg.early_cancel_margin,
        "cost_model_cancels": bd.planner.cost_model_cancels,
    }
    # streaming island: per-stream ring-buffer health + standing queries
    out["streams"] = bd.streams.status()
    out["streams"]["monitor_ewma_ms"] = {
        k: round(v * 1e3, 3) for k, v in bd.monitor.stream_ewma.items()}
    out["plan_cache"] = dict(bd.planner.plan_cache.stats(),
                             capacity=cfg.cache_size,
                             max_age_seconds=cfg.cache_max_age_seconds)
    out["catalog"] = {t: len(getattr(bd.catalog, t))
                      for t in bd.catalog.TABLES}
    return out


def start(bd: BigDawg, interval_seconds: float = 30.0) -> None:
    """Start the background MonitoringTask daemon (paper §V.E)."""
    task = bd.start_monitoring(interval_seconds)
    task.start()


def stop(bd: BigDawg) -> None:
    if bd.monitoring_task is not None:
        bd.monitoring_task.stop()
        bd.monitoring_task = None


def main() -> None:
    from repro.core.executor import ExecutorConfig
    from repro.core.planner import PlannerConfig

    ap = argparse.ArgumentParser(description="BigDAWG admin interface")
    ap.add_argument("command", choices=("status", "demo-status", "streams"))
    ap.add_argument("--ticks", type=int, default=8,
                    help="feed batches to run for the streams command")
    ap.add_argument("--executor-mode", choices=("concurrent", "serial"),
                    default="concurrent",
                    help="stage scheduler: overlapped DAG or serial")
    ap.add_argument("--executor-workers", type=int, default=4,
                    help="thread budget for concurrent stage execution")
    ap.add_argument("--plan-parallelism", type=int, default=4,
                    help="concurrent QEPs during training-mode exploration")
    ap.add_argument("--plan-cache-size", type=int, default=128,
                    help="signature-keyed plan cache LRU capacity")
    args = ap.parse_args()
    cfg = PlannerConfig(
        plan_parallelism=args.plan_parallelism,
        cache_size=args.plan_cache_size,
        executor=ExecutorConfig(mode=args.executor_mode,
                                max_workers=args.executor_workers))
    bd = default_deployment(planner_config=cfg)
    if args.command == "demo-status":
        from repro.data.mimic import load_mimic_demo
        load_mimic_demo(bd)
    elif args.command == "streams":
        # live streaming island demo: feed the synthetic MIMIC waveform
        # stream, run a standing window-average query on every batch
        from repro.data.mimic import stream_mimic_waveforms
        bd.register_continuous(
            "bdarray(aggregate(bdcast(bdstream(window("
            "mimic2v26.waveform_stream, 64)), w_arr,"
            " '<signal:double>[tick=0:63,64,0]', array), avg(signal)))",
            every_n_ticks=1, name="wave_avg")
        for _ in stream_mimic_waveforms(bd, batch_rows=64,
                                        num_batches=args.ticks):
            pass
        st = status(bd)
        print(json.dumps({"streams": st["streams"],
                          "plan_cache": st["plan_cache"]}, indent=1))
        return
    print(json.dumps(status(bd), indent=1))


if __name__ == "__main__":
    main()
