"""The BigDAWG Query Endpoint (paper §IV Fig. 3): accepts BQL queries,
routes them to the middleware, responds with results.  ``BigDawg`` wires
the Catalog, engines, islands/shims, Migrator, Monitor, Executor and
Planner into one deployment, mirroring the docker-compose topology of the
v0.1 release (catalog + data engines + middleware).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.catalog import Catalog
from repro.core.engines import (DenseHBMEngine, Engine, HostStoreEngine,
                                KVStoreEngine, ReplicatedEngine)
from repro.core.migrator import Migrator
from repro.core.monitor import Monitor, MonitoringTask
from repro.core.planner import Planner, PlannerConfig, Response
from repro.stream.continuous import ContinuousQuery, StreamRuntime


class BigDawg:
    def __init__(self, mesh=None, rules=None,
                 planner_config: Optional[PlannerConfig] = None) -> None:
        self.catalog = Catalog()
        self.engines: Dict[str, Engine] = {}
        self.monitor = Monitor()
        self.migrator = Migrator(self.catalog)
        self.planner_config = planner_config or PlannerConfig()
        self.planner = Planner(self.catalog, self.engines, self.monitor,
                               self.migrator, config=self.planner_config)
        self.streams = StreamRuntime(self.planner, self.monitor,
                                     self.engines)
        self.mesh = mesh
        self.rules = rules
        self.monitoring_task: Optional[MonitoringTask] = None

    # -- administrative interface (paper §IV) ---------------------------------
    def add_engine(self, engine: Engine, islands=None) -> Engine:
        self.engines[engine.name] = engine
        row = self.catalog.add_engine(engine.name, host="local",
                                      connection_properties=engine.kind)
        self.catalog.add_database(row.eid, f"{engine.name}_db")
        for island_name in (islands or engine.islands):
            isl = (self.catalog.island_by_name(island_name)
                   or self.catalog.add_island(island_name))
            self.catalog.add_shim(isl.iid, row.eid)
        return engine

    def register_cast(self, src: str, dst: str, method: str) -> None:
        s = self.catalog.engine_by_name(src)
        d = self.catalog.engine_by_name(dst)
        assert s is not None and d is not None, (src, dst)
        self.catalog.add_cast(s.eid, d.eid, method)

    def register_object(self, engine_name: str, name: str, obj,
                        fields=()) -> None:
        engine = self.engines[engine_name]
        engine.put(name, obj)
        row = self.catalog.engine_by_name(engine_name)
        db = next(d for d in self.catalog.databases.values()
                  if d.engine_id == row.eid)
        self.catalog.add_object(name, fields, db.dbid, db.dbid)

    # -- the Query Endpoint -----------------------------------------------------
    def query(self, bql: str, training: bool = False) -> Response:
        return self.planner.process_query(bql, is_training_mode=training)

    # -- streaming island (repro.stream) --------------------------------------
    def register_stream(self, engine_name: str, name: str, fields,
                        capacity: int = 4096):
        """Create a ring-buffer stream on a StreamEngine and register it
        as a catalog object (so the Planner can place streaming nodes)."""
        from repro.stream.engine import Stream, StreamEngine
        assert isinstance(self.engines[engine_name], StreamEngine), \
            engine_name
        stream = Stream(name, fields, capacity)
        self.register_object(engine_name, name, stream,
                             fields=tuple(fields))
        return stream

    def register_continuous(self, bql: str, every_n_ticks: int = 1,
                            name: Optional[str] = None) -> ContinuousQuery:
        """Register a standing BQL query; it re-executes (lean mode, so
        2nd+ ticks ride the signature plan cache) on every
        ``every_n_ticks``-th ``self.streams.tick()``."""
        return self.streams.register_continuous(bql, every_n_ticks, name)

    def start_monitoring(self, interval_seconds: float = 30.0
                         ) -> MonitoringTask:
        def refresh() -> None:
            # re-estimate engine health from recent op logs (bounded ring
            # buffers — see Engine.OP_LOG_LIMIT / recent_ops)
            for engine in self.engines.values():
                for op, seconds in engine.recent_ops(8):
                    self.monitor.observe_engine(engine.name, seconds)
            # drop plan-cache entries superseded by new measurements
            self.planner.plan_cache.evict_stale()
        self.monitoring_task = MonitoringTask(self.monitor, refresh,
                                              interval_seconds)
        return self.monitoring_task


def default_deployment(mesh=None, rules=None,
                       planner_config: Optional[PlannerConfig] = None
                       ) -> BigDawg:
    """The v0.1 release topology: one relational, one array, one text engine
    (+ a second relational engine, as in the paper's docker-compose which
    ships postgres-data1 and postgres-data2), with binary+staged casts —
    extended with the streaming island's StreamEngine (S-Store analog,
    arXiv:1609.07548) whose window views cast into the array island over
    the binary route and into the relational island over the staged one."""
    from repro.stream.engine import StreamEngine

    bd = BigDawg(mesh=mesh, rules=rules, planner_config=planner_config)
    bd.add_engine(HostStoreEngine("hoststore0", mesh, rules))
    bd.add_engine(HostStoreEngine("hoststore1", mesh, rules))
    bd.add_engine(DenseHBMEngine("densehbm0", mesh, rules))
    bd.add_engine(KVStoreEngine("kvstore0", mesh, rules))
    bd.add_engine(ReplicatedEngine("replicated0", mesh, rules))
    names = ["hoststore0", "hoststore1", "densehbm0", "kvstore0"]
    for src in names:
        for dst in names:
            if src == dst:
                continue
            same_kind = src[:4] == dst[:4]
            bd.register_cast(src, dst, "binary")
            if not same_kind:
                bd.register_cast(src, dst, "staged")
    bd.register_cast("densehbm0", "kvstore0", "quant")
    # streaming island: window->array rides the fast binary route;
    # window->table pays the staged (format-translating) route
    bd.add_engine(StreamEngine("streamstore0", mesh, rules))
    bd.register_cast("streamstore0", "densehbm0", "binary")
    bd.register_cast("streamstore0", "hoststore0", "staged")
    bd.register_cast("streamstore0", "hoststore1", "staged")
    return bd
