"""The BigDAWG Query Endpoint (paper §IV Fig. 3): accepts BQL queries,
routes them to the middleware, responds with results.  ``BigDawg`` wires
the Catalog, engines, islands/shims, Migrator, Monitor, Executor and
Planner into one deployment, mirroring the docker-compose topology of the
v0.1 release (catalog + data engines + middleware).
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

from repro.core.catalog import Catalog
from repro.core.engines import (DenseHBMEngine, Engine, HostStoreEngine,
                                KVStoreEngine, ReplicatedEngine)
from repro.core.migrator import Migrator
from repro.core.monitor import Monitor, MonitoringTask
from repro.core.planner import Planner, PlannerConfig, Response
from repro.stream.continuous import ContinuousQuery, StreamRuntime


class BigDawg:
    def __init__(self, mesh=None, rules=None,
                 planner_config: Optional[PlannerConfig] = None) -> None:
        self.catalog = Catalog()
        self.engines: Dict[str, Engine] = {}
        self.monitor = Monitor()
        self.migrator = Migrator(self.catalog)
        self.planner_config = planner_config or PlannerConfig()
        self.planner = Planner(self.catalog, self.engines, self.monitor,
                               self.migrator, config=self.planner_config)
        self.streams = StreamRuntime(self.planner, self.monitor,
                                     self.engines)
        self.mesh = mesh
        self.rules = rules
        self.monitoring_task: Optional[MonitoringTask] = None

    # -- administrative interface (paper §IV) ---------------------------------
    def add_engine(self, engine: Engine, islands=None) -> Engine:
        self.engines[engine.name] = engine
        row = self.catalog.add_engine(engine.name, host="local",
                                      connection_properties=engine.kind)
        self.catalog.add_database(row.eid, f"{engine.name}_db")
        for island_name in (islands or engine.islands):
            isl = (self.catalog.island_by_name(island_name)
                   or self.catalog.add_island(island_name))
            self.catalog.add_shim(isl.iid, row.eid)
        return engine

    def register_cast(self, src: str, dst: str, method: str) -> None:
        s = self.catalog.engine_by_name(src)
        d = self.catalog.engine_by_name(dst)
        assert s is not None and d is not None, (src, dst)
        self.catalog.add_cast(s.eid, d.eid, method)

    def _ensure_cast(self, src: str, dst: str, method: str) -> None:
        """register_cast, but idempotent (the ensure_* growers re-run)."""
        s = self.catalog.engine_by_name(src)
        d = self.catalog.engine_by_name(dst)
        if not any(c.method == method for c in
                   self.catalog.casts_between(s.eid, d.eid)):
            self.register_cast(src, dst, method)

    def register_object(self, engine_name: str, name: str, obj,
                        fields=()) -> None:
        engine = self.engines[engine_name]
        engine.put(name, obj)
        row = self.catalog.engine_by_name(engine_name)
        db = next(d for d in self.catalog.databases.values()
                  if d.engine_id == row.eid)
        self.catalog.add_object(name, fields, db.dbid, db.dbid)

    # -- the Query Endpoint -----------------------------------------------------
    def query(self, bql: str, training: bool = False) -> Response:
        return self.planner.process_query(bql, is_training_mode=training)

    # -- streaming island (repro.stream) --------------------------------------
    def ensure_stream_engines(self, n: int) -> list:
        """Grow the streaming island to ``n`` StreamEngines
        (``streamstore0..streamstore{n-1}``), registering the standard
        casts for each new engine — binary into the array island, staged
        into the relational island, and the live ``stream`` state-move
        route between every pair of StreamEngines.  Idempotent."""
        from repro.stream.engine import StreamEngine
        names = [f"streamstore{i}" for i in range(max(1, n))]
        for ename in names:
            if ename in self.engines:
                continue
            self.add_engine(StreamEngine(ename, self.mesh, self.rules))
            if "densehbm0" in self.engines:
                self.register_cast(ename, "densehbm0", "binary")
            for host in ("hoststore0", "hoststore1"):
                if host in self.engines:
                    self.register_cast(ename, host, "staged")
            for ml in [e for e in self.engines if e.startswith("mlhost")]:
                self._ensure_cast(ename, ml, "staged")
        # only the numbered pool is managed here; a user-added engine
        # like "streamstore_backup" is left alone (and must not break
        # the numeric sort below)
        stream_engines = [e for e in self.engines
                          if e.startswith("streamstore")
                          and e[len("streamstore"):].isdigit()]
        for src in stream_engines:
            for dst in stream_engines:
                if src == dst:
                    continue
                s = self.catalog.engine_by_name(src)
                d = self.catalog.engine_by_name(dst)
                if not any(c.method == "stream" for c in
                           self.catalog.casts_between(s.eid, d.eid)):
                    self.register_cast(src, dst, "stream")
        return sorted(stream_engines,
                      key=lambda e: int(e[len("streamstore"):]))

    def register_stream(self, engine_name: str, name=None, fields=None,
                        capacity: int = 4096, shards: int = 1,
                        shard_key: Optional[str] = None,
                        num_engines: Optional[int] = None,
                        rolling: bool = True, block_rows: int = 64,
                        ts_field: Optional[str] = None,
                        max_delay: float = 0.0,
                        idle_timeout: Optional[float] = None,
                        durability: Optional[str] = None,
                        checkpoint_every_rows: Optional[int] = None,
                        dead_letter: bool = False, *, spec=None):
        """Create a ring-buffer stream and register it in the catalog (so
        the Planner can place streaming nodes).

        Primary form — a declarative spec (see ``repro.stream.spec``):

            bd.register_stream("streamstore0", StreamSpec(
                "icu.abp", ("ts", "abp"), capacity=512,
                sharding=Sharding(shards=2),
                event_time=EventTime("ts", max_delay=4.0)))

        The ``StreamSpec`` groups what used to be 13 keywords into
        ``Sharding`` / ``EventTime`` / ``Durability`` sub-configs; the
        registered handle keeps it as ``stream.spec`` and the
        durability manifest persists it, so ``recover_stream`` hands
        the same spec back.  The semantics of every knob are documented
        on the sub-configs.

        Legacy form — ``register_stream(engine, name, fields,
        **kwargs)`` — still works: it folds the kwargs into the
        identical spec (bit-identical streams) but emits a
        ``DeprecationWarning``.  New knobs go on the spec's
        sub-configs, never on this shim — ``tools/check_api_freeze.py``
        pins the shim's signature in CI.
        """
        from repro.stream.spec import StreamSpec
        if isinstance(name, StreamSpec):
            if spec is not None:
                raise TypeError("pass the StreamSpec positionally or "
                                "via spec=, not both")
            spec, name = name, None
        if spec is None:
            warnings.warn(
                "register_stream(engine, name, fields, **kwargs) is "
                "deprecated; build a repro.stream.spec.StreamSpec and "
                "call register_stream(engine, spec)",
                DeprecationWarning, stacklevel=2)
            spec = StreamSpec.from_kwargs(
                name, fields, capacity=capacity, shards=shards,
                shard_key=shard_key, num_engines=num_engines,
                rolling=rolling, block_rows=block_rows,
                ts_field=ts_field, max_delay=max_delay,
                idle_timeout=idle_timeout, durability=durability,
                checkpoint_every_rows=checkpoint_every_rows,
                dead_letter=dead_letter)
        elif name is not None or fields is not None:
            raise TypeError("pass either a StreamSpec or the legacy "
                            "name/fields/kwargs, not both")
        return self._register_spec(engine_name, spec)

    def _register_spec(self, engine_name: str, spec):
        """The one registration path (both API forms land here)."""
        from repro.stream.engine import (SEQ_FIELD, ShardedStream, Stream,
                                         StreamEngine)
        assert isinstance(self.engines[engine_name], StreamEngine), \
            engine_name
        name, fields = spec.name, spec.fields
        et = spec.event_time
        if spec.shards <= 1:
            stream = Stream(name, fields, spec.capacity,
                            rolling=spec.rolling, ts_field=spec.ts_field,
                            max_delay=et.max_delay if et else 0.0,
                            idle_timeout=et.idle_timeout if et else None)
            stream.spec = spec
            self.register_object(engine_name, name, stream,
                                 fields=tuple(fields))
            self._stream_extras(engine_name, stream, spec)
            return stream
        sh = spec.sharding
        # ensure_stream_engines returns the whole (possibly larger)
        # streaming island; spread the shards over only the first
        # `num_engines` engines so the documented contract holds
        engine_names = self.ensure_stream_engines(
            sh.num_engines)[:sh.num_engines]
        per_shard = max(1, -(-int(spec.capacity) // sh.shards))  # ceil
        pairs = []
        for i in range(sh.shards):
            ename = engine_names[i % len(engine_names)]
            shard = Stream(f"{name}@shard{i}",
                           tuple(fields) + (SEQ_FIELD,),
                           per_shard, rolling=spec.rolling)
            self.register_object(ename, shard.name, shard,
                                 fields=shard.fields)
            pairs.append((ename, shard))
        handle = ShardedStream(name, fields, pairs,
                               shard_key=sh.shard_key,
                               block_rows=sh.block_rows,
                               ts_field=spec.ts_field,
                               max_delay=et.max_delay if et else 0.0,
                               idle_timeout=et.idle_timeout if et
                               else None)
        handle.spec = spec
        # the handle lives on every participating engine AND the caller's
        # anchor engine (shards always spread over streamstore0..spread-1,
        # but engine_name must still resolve the logical stream)
        participating = sorted(set(e for e, _ in pairs) | {engine_name})
        self.register_object(participating[0], name, handle,
                             fields=tuple(fields))
        for ename in participating[1:]:
            self.engines[ename].put(name, handle)
        self._stream_extras(engine_name, handle, spec)
        return handle

    def _stream_extras(self, engine_name: str, stream, spec) -> None:
        """Shared tail of register_stream/recover_stream: dead-letter
        sink registration and the durability attach (sink first — the
        durability meta must record it)."""
        from repro.stream.engine import Stream
        dead_letter = (spec.event_time is not None
                       and spec.event_time.dead_letter)
        if dead_letter and stream._late_sink is None:
            stream._late_sink = Stream(f"{stream.name}.__late",
                                       stream.fields, spec.capacity)
        if stream._late_sink is not None:
            self.register_object(engine_name, stream._late_sink.name,
                                 stream._late_sink,
                                 fields=tuple(stream.fields))
        if spec.durability is not None:
            from repro.stream.durability import attach
            attach(stream, spec.durability.directory,
                   checkpoint_every_rows=spec.durability
                   .checkpoint_every_rows,
                   keep=spec.durability.keep)
            self.streams.register_durable(stream)

    def recover_stream(self, engine_name: str, directory: str):
        """Rebuild a durable stream from its on-disk directory (latest
        checkpoint + log-tail replay, repairing any torn tail), register
        it — shard rings on their original engines, the handle on every
        participating engine, the dead-letter sink if any — and
        re-attach durability so ingest continues into the same log.
        Returns the recovered stream with its registration spec
        round-tripped from the manifest (``stream.spec`` — the same
        ``StreamSpec`` the stream was registered with, so recovery
        never requires the caller to restate registration kwargs); the
        house invariant is that the stream is bit-identical to the
        crashed one's durable prefix."""
        from repro.stream.durability import recover
        result = recover(directory)
        stream = result.stream
        meta = result  # RecoveryResult
        if hasattr(stream, "shard_engines"):      # ShardedStream
            engines = stream.shard_engines()
            pool = [int(e[len("streamstore"):]) + 1 for e in engines
                    if e.startswith("streamstore")
                    and e[len("streamstore"):].isdigit()]
            if pool:
                self.ensure_stream_engines(max(pool))
            for ename, shard in zip(engines, stream._shards):
                self.register_object(ename, shard.name, shard,
                                     fields=shard.fields)
            participating = sorted(set(engines) | {engine_name})
            self.register_object(participating[0], stream.name, stream,
                                 fields=tuple(stream.fields))
            for ename in participating[1:]:
                self.engines[ename].put(stream.name, stream)
        else:
            self.register_object(engine_name, stream.name, stream,
                                 fields=tuple(stream.fields))
        if result.late_sink is not None:
            self.register_object(engine_name, result.late_sink.name,
                                 result.late_sink,
                                 fields=tuple(stream.fields))
        import json as _json
        import os as _os
        from repro.stream.spec import StreamSpec
        with open(_os.path.join(directory, "meta.json")) as f:
            manifest = _json.load(f)
        spec = StreamSpec.from_manifest(manifest, directory)
        stream.spec = spec
        from repro.stream.durability import attach
        durable = attach(stream, directory,
                         checkpoint_every_rows=spec.durability
                         .checkpoint_every_rows,
                         keep=spec.durability.keep)
        durable.recovered += 1
        durable.last_recovery = {
            "checkpoint_step": meta.checkpoint_step,
            "records_replayed": meta.records_replayed,
            "rows_replayed": meta.rows_replayed,
            "seconds": meta.seconds,
            "truncated_records": meta.truncated_records}
        self.streams.register_durable(stream)
        self.monitor.observe_recovery(stream.name, meta.rows_replayed,
                                      meta.seconds)
        self.monitor.observe_durability(stream.name, durable.stats())
        return stream

    def rebalance_stream(self, stream: str, shard: Optional[int] = None,
                         to_engine: Optional[str] = None):
        """Move one shard of a sharded stream to another StreamEngine
        (live ring-buffer state; standing queries keep running) — see
        ``StreamRuntime.rebalance``."""
        return self.streams.rebalance(stream, shard=shard,
                                      to_engine=to_engine)

    # -- ml island (repro.stream.ml) -------------------------------------------
    def ensure_ml_engines(self, n: int = 1) -> list:
        """Grow the ml island to ``n`` MLEngines (``mlhost0..mlhost{n-1}``)
        with the standard casts: staged from every StreamEngine (windows
        migrate in via ``bdcast``), staged into the relational island
        (score tables migrate out) and binary into the array island.
        Idempotent; the ml island is opt-in — ``default_deployment``
        does not create it, call this (or ``register_model``, which
        does) before issuing ``bdml`` queries."""
        from repro.stream.ml import MLEngine
        names = [f"mlhost{i}" for i in range(max(1, n))]
        for ename in names:
            if ename in self.engines:
                continue
            self.add_engine(MLEngine(ename, runtime=self.streams,
                                     engines=self.engines))
            for src in [e for e in self.engines
                        if e.startswith("streamstore")]:
                self._ensure_cast(src, ename, "staged")
            for host in ("hoststore0", "hoststore1"):
                if host in self.engines:
                    self._ensure_cast(ename, host, "staged")
            if "densehbm0" in self.engines:
                self._ensure_cast(ename, "densehbm0", "binary")
        return sorted(e for e in self.engines if e.startswith("mlhost"))

    def register_model(self, alias: str, arch: Optional[str] = None,
                       engine_name: str = "mlhost0", seed: int = 0):
        """Register a model handle on the ml island so ``bdml`` queries
        can score stream windows through it:

            bd.register_model("moe")
            bd.query("bdml(infer(ewindow(icu.abp, 16.0), models.moe))")

        ``alias`` picks the registry architecture (``lm``/``moe``/
        ``rwkv6``/``mamba`` map to reduced-config registry archs; a full
        registry name like ``olmoe-1b-7b`` also works with an explicit
        ``alias``).  The catalog object is named ``models.<alias>`` —
        dotted, so the Planner's signature extractor sees it as a
        referenced object and pins infer reads to the model's home
        engine.  Params are derived from a fixed seed at first use and
        cached per (arch, seed), so every deployment (sharded, replayed,
        front-door) scores with bit-identical weights."""
        from repro.stream.ml import MLModel, resolve_arch
        self.ensure_ml_engines(
            max(1, int(engine_name[len("mlhost"):]) + 1)
            if engine_name.startswith("mlhost")
            and engine_name[len("mlhost"):].isdigit() else 1)
        handle = MLModel(name=f"models.{alias}",
                         arch=resolve_arch(arch or alias), seed=seed,
                         home_engine=engine_name)
        self.register_object(engine_name, handle.name, handle,
                             fields=("window", "rows", "score"))
        return handle

    def register_continuous(self, bql: str, every_n_ticks: int = 1,
                            name: Optional[str] = None) -> ContinuousQuery:
        """Register a standing BQL query; it re-executes (lean mode, so
        2nd+ ticks ride the signature plan cache) on every
        ``every_n_ticks``-th ``self.streams.tick()``."""
        return self.streams.register_continuous(bql, every_n_ticks, name)

    def start_monitoring(self, interval_seconds: float = 30.0
                         ) -> MonitoringTask:
        def refresh() -> None:
            # re-estimate engine health from recent op logs (bounded ring
            # buffers — see Engine.OP_LOG_LIMIT / recent_ops)
            for engine in self.engines.values():
                for op, seconds in engine.recent_ops(8):
                    self.monitor.observe_engine(engine.name, seconds)
            # drop plan-cache entries superseded by new measurements
            self.planner.plan_cache.evict_stale()
        self.monitoring_task = MonitoringTask(self.monitor, refresh,
                                              interval_seconds)
        return self.monitoring_task


def default_deployment(mesh=None, rules=None,
                       planner_config: Optional[PlannerConfig] = None,
                       stream_engines: int = 1) -> BigDawg:
    """The v0.1 release topology: one relational, one array, one text engine
    (+ a second relational engine, as in the paper's docker-compose which
    ships postgres-data1 and postgres-data2), with binary+staged casts —
    extended with the streaming island's StreamEngine (S-Store analog,
    arXiv:1609.07548) whose window views cast into the array island over
    the binary route and into the relational island over the staged one.
    ``stream_engines`` grows the streaming island for sharded streams
    (``register_stream(..., shards=N)`` auto-grows it on demand too)."""
    bd = BigDawg(mesh=mesh, rules=rules, planner_config=planner_config)
    bd.add_engine(HostStoreEngine("hoststore0", mesh, rules))
    bd.add_engine(HostStoreEngine("hoststore1", mesh, rules))
    bd.add_engine(DenseHBMEngine("densehbm0", mesh, rules))
    bd.add_engine(KVStoreEngine("kvstore0", mesh, rules))
    bd.add_engine(ReplicatedEngine("replicated0", mesh, rules))
    names = ["hoststore0", "hoststore1", "densehbm0", "kvstore0"]
    for src in names:
        for dst in names:
            if src == dst:
                continue
            same_kind = src[:4] == dst[:4]
            bd.register_cast(src, dst, "binary")
            if not same_kind:
                bd.register_cast(src, dst, "staged")
    bd.register_cast("densehbm0", "kvstore0", "quant")
    # streaming island: window->array rides the fast binary route;
    # window->table pays the staged (format-translating) route; between
    # StreamEngines the live "stream" state-move route backs rebalancing
    bd.ensure_stream_engines(stream_engines)
    return bd
