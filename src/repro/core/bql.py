"""BigDAWG Query Language (paper §VI): functional syntax with island
tokens — ``bdrel`` / ``bdarray`` / ``bdtext`` / ``bdstream`` for
intra-island queries, ``bdcast`` for inter-island migration (always
nested between island queries), ``bdcatalog`` for metadata.  This module
parses BQL into a CrossIslandQueryPlan tree (paper §V.B): nodes either
carry an intra-island query or an inter-island migration.

Island query text is opaque to this parser (each island's shim owns its
own grammar) — which is why the streaming island's keyword-argument ops
(``join(W1, W2, on=ts, tol=0.5)``) and event-time windows
(``ewindow(S, span)``) need no grammar changes here: ``=`` and nested
calls pass through ``_split_top_commas`` untouched, and only ``bdcast``
boundaries are rewritten.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

ISLAND_TOKENS = {"bdrel": "relational", "bdarray": "array",
                 "bdtext": "text", "bdstream": "streaming",
                 "bdml": "ml"}
ALL_TOKENS = tuple(ISLAND_TOKENS) + ("bdcast", "bdcatalog")


@dataclasses.dataclass
class CastNode:
    """bdcast(inner, dest_name, dest_schema, dest_island)."""
    child: "IslandQueryNode"
    dest_name: str
    dest_schema: str
    dest_island: str


@dataclasses.dataclass
class IslandQueryNode:
    """An intra-island query; nested casts appear as name references."""
    island: str
    query: str                       # island-language text, casts replaced
    casts: List[CastNode] = dataclasses.field(default_factory=list)

    def walk(self):
        """Post-order traversal of the plan tree."""
        for cast in self.casts:
            yield from cast.child.walk()
            yield cast
        yield self


@dataclasses.dataclass
class CatalogQueryNode:
    query: str


def _find_token(s: str, start: int = 0) -> Optional[Tuple[str, int]]:
    """Earliest BQL token at/after ``start``; returns (token, index)."""
    best: Optional[Tuple[str, int]] = None
    for tok in ALL_TOKENS:
        i = s.find(tok + "(", start)
        if i >= 0 and (best is None or i < best[1]):
            best = (tok, i)
    return best


def _balanced_body(s: str, open_idx: int) -> Tuple[str, int]:
    """Given index of '(' return (body, index-after-closing-paren)."""
    depth = 0
    for j in range(open_idx, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return s[open_idx + 1:j], j + 1
    raise ValueError(f"unbalanced parentheses in BQL: {s!r}")


def _split_top_commas(s: str) -> List[str]:
    parts, depth, quote, cur = [], 0, None, []
    for ch in s:
        if quote:
            cur.append(ch)
            if ch == quote:
                quote = None
            continue
        if ch in ("'", '"'):
            quote = ch
            cur.append(ch)
            continue
        if ch == "(" or ch == "[" or ch == "{":
            depth += 1
        elif ch == ")" or ch == "]" or ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def parse(query: str):
    """Parse a full BQL string into a plan tree root."""
    q = query.strip()
    found = _find_token(q)
    if not found or q[:found[1]].strip():
        raise ValueError(f"not a BQL query: {query!r}")
    tok, idx = found
    body, end = _balanced_body(q, idx + len(tok))
    if q[end:].strip():
        raise ValueError(f"trailing input after BQL query: {q[end:]!r}")
    if tok == "bdcatalog":
        return CatalogQueryNode(body.strip())
    if tok == "bdcast":
        raise ValueError("bdcast must be nested inside an island query")
    return _parse_island(ISLAND_TOKENS[tok], body)


def _parse_island(island: str, body: str) -> IslandQueryNode:
    """Replace nested bdcast(...) occurrences with their dest names."""
    casts: List[CastNode] = []
    out = []
    pos = 0
    while True:
        i = body.find("bdcast(", pos)
        if i < 0:
            out.append(body[pos:])
            break
        out.append(body[pos:i])
        cast_body, after = _balanced_body(body, i + len("bdcast"))
        parts = _split_top_commas(cast_body)
        if len(parts) < 3:
            raise ValueError(f"bdcast needs (query, name, schema[, island]): "
                             f"{cast_body!r}")
        inner_q = parts[0]
        dest_name = parts[1].strip()
        dest_schema = parts[2].strip().strip("'\"")
        dest_island = parts[3].strip() if len(parts) > 3 else island
        inner = parse(inner_q)
        if not isinstance(inner, IslandQueryNode):
            raise ValueError("bdcast inner query must be an island query")
        casts.append(CastNode(inner, dest_name, dest_schema, dest_island))
        out.append(dest_name)
        pos = after
    text = "".join(out).strip()
    return IslandQueryNode(island=island, query=text, casts=casts)
