"""The BigDAWG Catalog (paper §V.A): metadata about engines, databases,
objects, shims and casts.  The Planner, Migrator and Executor all rely on
the Catalog for "awareness" of the polystore components.

The paper backs the catalog with a PostgreSQL instance; here it is an
in-process columnar store with JSON persistence — same five tables, same
fields (Fig. 4), queryable through ``bdcatalog(...)`` with a SQL subset.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class EngineRow:
    eid: int
    name: str
    host: str
    port: int
    connection_properties: str


@dataclasses.dataclass
class DatabaseRow:
    dbid: int
    engine_id: int
    name: str
    userid: str = "repro"
    password: str = "test"


@dataclasses.dataclass
class ObjectRow:
    oid: int
    name: str
    fields: str              # comma-separated field names
    logical_db: int
    physical_db: int


@dataclasses.dataclass
class ShimRow:
    shim_id: int
    island_id: int
    engine_id: int
    access_method: str = "N/A"


@dataclasses.dataclass
class CastRow:
    cast_id: int
    src_eid: int
    dst_eid: int
    method: str              # binary | staged | quant


@dataclasses.dataclass
class IslandRow:
    iid: int
    name: str                # relational | array | text


class Catalog:
    """Thread-safe in-process catalog with the paper's table schema."""

    TABLES = ("engines", "databases", "objects", "shims", "casts", "islands")

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self.engines: Dict[int, EngineRow] = {}
        self.databases: Dict[int, DatabaseRow] = {}
        self.objects: Dict[int, ObjectRow] = {}
        self.shims: Dict[int, ShimRow] = {}
        self.casts: Dict[int, CastRow] = {}
        self.islands: Dict[int, IslandRow] = {}
        self._next_id = {t: 0 for t in self.TABLES}

    def _nid(self, table: str) -> int:
        nid = self._next_id[table]
        self._next_id[table] += 1
        return nid

    # -- writers ------------------------------------------------------------
    def add_island(self, name: str) -> IslandRow:
        with self._lock:
            row = IslandRow(self._nid("islands"), name)
            self.islands[row.iid] = row
            return row

    def add_engine(self, name: str, host: str = "local", port: int = 0,
                   connection_properties: str = "") -> EngineRow:
        with self._lock:
            row = EngineRow(self._nid("engines"), name, host, port,
                            connection_properties)
            self.engines[row.eid] = row
            return row

    def add_database(self, engine_id: int, name: str) -> DatabaseRow:
        with self._lock:
            row = DatabaseRow(self._nid("databases"), engine_id, name)
            self.databases[row.dbid] = row
            return row

    def add_object(self, name: str, fields: Sequence[str], logical_db: int,
                   physical_db: int) -> ObjectRow:
        with self._lock:
            row = ObjectRow(self._nid("objects"), name, ",".join(fields),
                            logical_db, physical_db)
            self.objects[row.oid] = row
            return row

    def add_shim(self, island_id: int, engine_id: int,
                 access_method: str = "N/A") -> ShimRow:
        with self._lock:
            row = ShimRow(self._nid("shims"), island_id, engine_id,
                          access_method)
            self.shims[row.shim_id] = row
            return row

    def add_cast(self, src_eid: int, dst_eid: int, method: str) -> CastRow:
        with self._lock:
            row = CastRow(self._nid("casts"), src_eid, dst_eid, method)
            self.casts[row.cast_id] = row
            return row

    def relocate_object(self, obj_name: str,
                        engine_name: str) -> ObjectRow:
        """Re-home an object's logical/physical database onto another
        engine's database (live stream-shard migration keeps the catalog
        truthful about where each shard's ring buffer lives)."""
        with self._lock:
            obj = self.object_by_name(obj_name)
            if obj is None:
                raise ValueError(f"unknown catalog object {obj_name!r}")
            engine = self.engine_by_name(engine_name)
            if engine is None:
                raise ValueError(f"unknown catalog engine {engine_name!r}")
            db = next((d for d in self.databases.values()
                       if d.engine_id == engine.eid), None)
            if db is None:
                raise ValueError(f"engine {engine_name!r} has no database")
            obj.logical_db = db.dbid
            obj.physical_db = db.dbid
            return obj

    # -- readers ------------------------------------------------------------
    def engine_by_name(self, name: str) -> Optional[EngineRow]:
        for row in self.engines.values():
            if row.name == name:
                return row
        return None

    def island_by_name(self, name: str) -> Optional[IslandRow]:
        for row in self.islands.values():
            if row.name == name:
                return row
        return None

    def database_by_name(self, name: str) -> Optional[DatabaseRow]:
        for row in self.databases.values():
            if row.name == name:
                return row
        return None

    def object_by_name(self, name: str) -> Optional[ObjectRow]:
        for row in self.objects.values():
            if row.name == name:
                return row
        return None

    def engines_for_island(self, island_name: str) -> List[EngineRow]:
        isl = self.island_by_name(island_name)
        if isl is None:
            return []
        eids = [s.engine_id for s in self.shims.values()
                if s.island_id == isl.iid]
        return [self.engines[e] for e in eids if e in self.engines]

    def engine_for_object(self, obj_name: str) -> Optional[EngineRow]:
        obj = self.object_by_name(obj_name)
        if obj is None:
            return None
        db = self.databases.get(obj.physical_db)
        if db is None:
            return None
        return self.engines.get(db.engine_id)

    def casts_between(self, src_eid: int, dst_eid: int) -> List[CastRow]:
        return [c for c in self.casts.values()
                if c.src_eid == src_eid and c.dst_eid == dst_eid]

    # -- bdcatalog(...) SQL subset -------------------------------------------
    _SELECT_RE = re.compile(
        r"^\s*select\s+(?P<cols>\*|[\w,\s]+)\s+from\s+(?P<table>\w+)"
        r"(?:\s+where\s+(?P<col>\w+)\s*=\s*'?(?P<val>[\w\.\-]+)'?)?\s*;?\s*$",
        re.IGNORECASE)

    def query(self, sql: str) -> List[Dict[str, Any]]:
        m = self._SELECT_RE.match(sql)
        if not m:
            raise ValueError(f"unsupported catalog query: {sql!r}")
        table = m.group("table").lower()
        if table not in self.TABLES:
            raise ValueError(f"unknown catalog table: {table}")
        rows = [dataclasses.asdict(r) for r in getattr(self, table).values()]
        col, val = m.group("col"), m.group("val")
        if col:
            def _match(r):
                got = r.get(col.lower())
                return str(got) == val
            rows = [r for r in rows if _match(r)]
        cols = m.group("cols").strip()
        if cols != "*":
            names = [c.strip() for c in cols.split(",")]
            rows = [{n: r[n] for n in names} for r in rows]
        return rows

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> str:
        payload = {t: [dataclasses.asdict(r)
                       for r in getattr(self, t).values()]
                   for t in self.TABLES}
        payload["_next_id"] = self._next_id
        return json.dumps(payload, indent=1)

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.to_json())
        os.replace(tmp, path)          # atomic promote

    @classmethod
    def load(cls, path: str) -> "Catalog":
        with open(path) as f:
            payload = json.load(f)
        cat = cls()
        ctors = {"engines": EngineRow, "databases": DatabaseRow,
                 "objects": ObjectRow, "shims": ShimRow, "casts": CastRow,
                 "islands": IslandRow}
        keyfields = {"engines": "eid", "databases": "dbid", "objects": "oid",
                     "shims": "shim_id", "casts": "cast_id",
                     "islands": "iid"}
        for t, ctor in ctors.items():
            for rowdict in payload.get(t, []):
                row = ctor(**rowdict)
                getattr(cat, t)[getattr(row, keyfields[t])] = row
        cat._next_id = payload.get("_next_id", cat._next_id)
        return cat
