"""Island data models: relational Table, array-island ArrayObject, and
text-island KVTable — the three data models of BigDAWG v0.1 (§VI.A).

These are real, executable implementations on jnp arrays (CPU today, TPU
sharded under a mesh): the relational model backs the data pipeline, the
array model backs tensor state, and the KV model backs the serving cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Relational island: Table (columnar, 1-D columns of equal length)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Table:
    columns: Dict[str, jax.Array]          # name -> (N,) array

    def __post_init__(self):
        lens = {v.shape[0] for v in self.columns.values()}
        assert len(lens) <= 1, f"ragged table: {lens}"

    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return next(iter(self.columns.values())).shape[0]

    @property
    def fields(self) -> Tuple[str, ...]:
        return tuple(self.columns)

    def nbytes(self) -> int:
        return int(sum(np.asarray(v).nbytes for v in self.columns.values()))

    def project(self, names: Sequence[str]) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def filter(self, mask: jax.Array) -> "Table":
        idx = jnp.nonzero(mask)[0]
        return Table({n: v[idx] for n, v in self.columns.items()})

    def sort_by(self, name: str, descending: bool = False) -> "Table":
        order = jnp.argsort(self.columns[name])
        if descending:
            order = order[::-1]
        return Table({n: v[order] for n, v in self.columns.items()})

    def limit(self, n: int) -> "Table":
        return Table({k: v[:n] for k, v in self.columns.items()})

    def join(self, other: "Table", left_on: str, right_on: str) -> "Table":
        """Hash-free sort-merge-ish join via broadcast equality (small N)."""
        lk = self.columns[left_on]
        rk = other.columns[right_on]
        eq = lk[:, None] == rk[None, :]
        li, ri = jnp.nonzero(eq)
        out = {n: v[li] for n, v in self.columns.items()}
        for n, v in other.columns.items():
            out[n if n not in out else f"r_{n}"] = v[ri]
        return Table(out)

    def group_agg(self, by: str, agg: str, target: str) -> "Table":
        keys = self.columns[by]
        uniq = jnp.unique(keys)
        vals = self.columns[target]
        def one(k):
            m = (keys == k)
            cnt = jnp.maximum(m.sum(), 1)
            if agg == "count":
                return m.sum()
            if agg == "sum":
                return jnp.where(m, vals, 0).sum()
            if agg == "avg":
                return jnp.where(m, vals, 0).sum() / cnt
            if agg == "min":
                return jnp.where(m, vals, jnp.inf).min()
            if agg == "max":
                return jnp.where(m, vals, -jnp.inf).max()
            raise ValueError(agg)
        agged = jax.vmap(one)(uniq)
        return Table({by: uniq, f"{agg}_{target}": agged})


# ---------------------------------------------------------------------------
# Array island: ArrayObject (dims + attributes), SciDB-flavoured
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ArrayObject:
    attrs: Dict[str, jax.Array]            # name -> array of shape dims_shape
    dim_names: Tuple[str, ...]
    valid: Optional[jax.Array] = None      # bool mask (sparse-cell emulation)

    @property
    def shape(self) -> Tuple[int, ...]:
        return next(iter(self.attrs.values())).shape

    def nbytes(self) -> int:
        return int(sum(np.asarray(v).nbytes for v in self.attrs.values()))

    def mask(self) -> jax.Array:
        if self.valid is None:
            return jnp.ones(self.shape, bool)
        return self.valid

    def dim_grid(self, name: str) -> jax.Array:
        axis = self.dim_names.index(name)
        n = self.shape[axis]
        grid = jnp.arange(n)
        reshape = [1] * len(self.shape)
        reshape[axis] = n
        return jnp.broadcast_to(grid.reshape(reshape), self.shape)

    def project(self, names: Sequence[str]) -> "ArrayObject":
        return ArrayObject({n: self.attrs[n] for n in names},
                           self.dim_names, self.valid)

    def filter(self, pred: Callable[["ArrayObject"], jax.Array]
               ) -> "ArrayObject":
        new_mask = self.mask() & pred(self)
        return ArrayObject(dict(self.attrs), self.dim_names, new_mask)

    def aggregate(self, agg: str, attr: str) -> "ArrayObject":
        v = self.attrs[attr]
        m = self.mask()
        cnt = jnp.maximum(m.sum(), 1)
        if agg == "count":
            out = m.sum()
        elif agg == "sum":
            out = jnp.where(m, v, 0).sum()
        elif agg == "avg":
            out = jnp.where(m, v, 0).sum() / cnt
        elif agg == "min":
            out = jnp.where(m, v, jnp.inf).min()
        elif agg == "max":
            out = jnp.where(m, v, -jnp.inf).max()
        else:
            raise ValueError(agg)
        return ArrayObject({f"{agg}_{attr}": out[None]}, ("i",))

    def redimension(self, new_shape: Tuple[int, ...],
                    new_dims: Tuple[str, ...]) -> "ArrayObject":
        attrs = {n: v.reshape(new_shape) for n, v in self.attrs.items()}
        valid = None if self.valid is None else self.valid.reshape(new_shape)
        return ArrayObject(attrs, new_dims, valid)

    def sort(self, attr: str) -> "ArrayObject":
        flat = self.attrs[attr].reshape(-1)
        order = jnp.argsort(flat)
        attrs = {n: v.reshape(-1)[order] for n, v in self.attrs.items()}
        valid = None if self.valid is None \
            else self.valid.reshape(-1)[order]
        return ArrayObject(attrs, ("i",), valid)

    def cross_join(self, other: "ArrayObject") -> "ArrayObject":
        """Cartesian combine over flattened cells (small arrays only)."""
        a = {n: v.reshape(-1) for n, v in self.attrs.items()}
        b = {n: v.reshape(-1) for n, v in other.attrs.items()}
        na = next(iter(a.values())).shape[0]
        nb = next(iter(b.values())).shape[0]
        out = {n: jnp.repeat(v, nb) for n, v in a.items()}
        for n, v in b.items():
            out[n if n not in out else f"r_{n}"] = jnp.tile(v, na)
        return ArrayObject(out, ("i",))


# ---------------------------------------------------------------------------
# Text island: KVTable (Accumulo-flavoured sorted key-value rows)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class KVTable:
    """Rows sorted by key = (row, colfam, colqual); values = payloads.

    Payloads may be python strings (log-style data) or jnp arrays (KV-cache
    pages) — the engine treats them opaquely; range scans are key-based.
    """
    keys: List[Tuple[str, str, str]]
    values: List[Any]

    def __post_init__(self):
        order = sorted(range(len(self.keys)), key=lambda i: self.keys[i])
        self.keys = [self.keys[i] for i in order]
        self.values = [self.values[i] for i in order]

    @property
    def num_rows(self) -> int:
        return len(self.keys)

    def nbytes(self) -> int:
        total = 0
        for v in self.values:
            if isinstance(v, (jax.Array, np.ndarray)):
                total += int(np.asarray(v).nbytes)
            else:
                total += len(str(v))
        return total

    def scan(self) -> List[Tuple[Tuple[str, str, str], Any]]:
        return list(zip(self.keys, self.values))

    def range(self, start: Tuple[str, str, str], end: Tuple[str, str, str]
              ) -> List[Tuple[Tuple[str, str, str], Any]]:
        out = []
        for k, v in zip(self.keys, self.values):
            if (k[0] >= start[0] and k[0] <= end[0]
                    and (not start[1] or k[1] >= start[1])
                    and (not end[1] or k[1] <= end[1])):
                out.append((k, v))
        return out

    def put(self, key: Tuple[str, str, str], value: Any) -> None:
        self.keys.append(key)
        self.values.append(value)
        self.__post_init__()


def object_kind(obj: Any) -> str:
    if isinstance(obj, Table):
        return "table"
    if isinstance(obj, ArrayObject):
        return "array"
    if isinstance(obj, KVTable):
        return "kvtable"
    if isinstance(obj, (jax.Array, np.ndarray)):
        return "tensor"
    return "pytree"


def object_nbytes(obj: Any) -> int:
    if hasattr(obj, "nbytes") and callable(getattr(obj, "nbytes")):
        return int(obj.nbytes())
    if isinstance(obj, (jax.Array, np.ndarray)):
        return int(np.asarray(obj).nbytes) if isinstance(obj, np.ndarray) \
            else int(obj.size * obj.dtype.itemsize)
    leaves = jax.tree.leaves(obj)
    return int(sum(l.size * l.dtype.itemsize for l in leaves
                   if hasattr(l, "size")))
