"""Tensor storage engines — the heterogeneous "database engines" of the
polystore (DESIGN.md §2 table):

  DenseHBMEngine   (SciDB analog)      device-HBM sharded arrays, MXU ops
  HostStoreEngine  (PostgreSQL analog) host-DRAM tables / fp32 master state
  KVStoreEngine    (Accumulo analog)   paged KV store, optional int8 codec
  ReplicatedEngine                     small replicated tensors

All engines share the Engine interface: named-object storage, binary/staged
import & export (the Migrator moves data through these), and per-op metrics
(fed to the Monitor).  "Integration" in the paper's sense = all engines are
registered in one Catalog and reachable through islands + casts.
"""
from __future__ import annotations

import collections
import io
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm


class Engine:
    kind = "abstract"
    islands: Tuple[str, ...] = ()
    # op_log ring-buffer capacity: continuous ingest (streaming island)
    # would otherwise grow the log without bound — a slow leak; Monitor
    # feeds only ever read the recent tail, so old entries are droppable
    OP_LOG_LIMIT = 4096

    def __init__(self, name: str, mesh=None, rules=None) -> None:
        self.name = name
        self.mesh = mesh
        self.rules = rules
        self._objects: Dict[str, Any] = {}
        self.op_log: Deque[Tuple[str, float]] = \
            collections.deque(maxlen=self.OP_LOG_LIMIT)  # (op, seconds)
        self.ops_recorded = 0             # lifetime count (log may be cut)

    # -- object store --------------------------------------------------------
    def put(self, name: str, obj: Any) -> None:
        self._objects[name] = self._place(obj)

    def get(self, name: str) -> Any:
        return self._objects[name]

    def has(self, name: str) -> bool:
        return name in self._objects

    def delete(self, name: str) -> None:
        self._objects.pop(name, None)

    def list_objects(self) -> List[str]:
        return sorted(self._objects)

    def _place(self, obj: Any) -> Any:
        return obj

    def record(self, op: str, seconds: float) -> None:
        self.op_log.append((op, seconds))
        self.ops_recorded += 1

    def recent_ops(self, n: int = 8) -> List[Tuple[str, float]]:
        """Last ``n`` logged ops (deques don't slice; Monitor feeds use
        this instead of ``op_log[-n:]``)."""
        if n <= 0:
            return []
        return list(self.op_log)[-n:]

    def reset_op_log(self) -> int:
        """Clear the bounded op log; returns how many entries were
        dropped (lifetime ``ops_recorded`` is preserved)."""
        dropped = len(self.op_log)
        self.op_log.clear()
        return dropped

    # -- migration formats ----------------------------------------------------
    def export_binary(self, name: str) -> Tuple[Any, Dict[str, Any]]:
        """Zero-copy handoff: (payload, schema). Fast path of the Migrator."""
        obj = self._objects[name]
        return obj, {"kind": dm.object_kind(obj)}

    def import_binary(self, name: str, payload: Any,
                      schema: Dict[str, Any]) -> None:
        self.put(name, payload)

    def export_staged(self, name: str) -> Tuple[bytes, Dict[str, Any]]:
        """Format-translating slow path (the paper's CSV-style migration)."""
        obj = self._objects[name]
        kind = dm.object_kind(obj)
        buf = io.StringIO()
        if kind == "table":
            cols = list(obj.columns)
            buf.write(",".join(cols) + "\n")
            mat = np.stack([np.asarray(obj.columns[c], dtype=np.float64)
                            for c in cols], axis=1)
            np.savetxt(buf, mat, delimiter=",", fmt="%.17g")
            return buf.getvalue().encode(), {"kind": kind, "columns": cols}
        if kind == "array":
            names = list(obj.attrs)
            shape = obj.shape
            mat = np.stack([np.asarray(obj.attrs[n], dtype=np.float64
                                       ).reshape(-1) for n in names], axis=1)
            np.savetxt(buf, mat, delimiter=",", fmt="%.17g")
            return buf.getvalue().encode(), {
                "kind": kind, "attrs": names, "shape": list(shape),
                "dims": list(obj.dim_names)}
        if kind == "tensor":
            arr = np.asarray(obj, dtype=np.float64).reshape(-1)
            np.savetxt(buf, arr[:, None], delimiter=",", fmt="%.17g")
            return buf.getvalue().encode(), {
                "kind": kind, "shape": list(np.asarray(obj).shape),
                "dtype": str(obj.dtype)}
        if kind == "kvtable":
            lines = []
            for k, v in obj.scan():
                sval = (np.asarray(v).tolist() if isinstance(
                    v, (jax.Array, np.ndarray)) else v)
                lines.append(repr((k, sval)))
            return "\n".join(lines).encode(), {"kind": kind}
        raise ValueError(f"staged export unsupported for {kind}")

    def import_staged(self, name: str, payload: bytes,
                      schema: Dict[str, Any]) -> None:
        kind = schema["kind"]
        text = payload.decode()
        if kind == "table":
            lines = text.strip().splitlines()
            cols = lines[0].split(",")
            mat = np.loadtxt(io.StringIO("\n".join(lines[1:])),
                             delimiter=",", ndmin=2)
            table = dm.Table({c: jnp.asarray(mat[:, i])
                              for i, c in enumerate(cols)})
            self.put(name, self.coerce(table, schema))
            return
        if kind == "array":
            mat = np.loadtxt(io.StringIO(text), delimiter=",", ndmin=2)
            shape = tuple(schema["shape"])
            attrs = {n: jnp.asarray(mat[:, i]).reshape(shape)
                     for i, n in enumerate(schema["attrs"])}
            arr = dm.ArrayObject(attrs, tuple(schema["dims"]))
            self.put(name, self.coerce(arr, schema))
            return
        if kind == "tensor":
            vec = np.loadtxt(io.StringIO(text), delimiter=",")
            arr = jnp.asarray(vec, dtype=schema.get("dtype", "float32")
                              ).reshape(tuple(schema["shape"]))
            self.put(name, arr)
            return
        if kind == "kvtable":
            import ast
            keys, values = [], []
            for line in text.splitlines():
                k, v = ast.literal_eval(line)
                keys.append(tuple(k))
                values.append(jnp.asarray(v) if isinstance(v, list) else v)
            self.put(name, dm.KVTable(keys, values))
            return
        raise ValueError(f"staged import unsupported for {kind}")

    def coerce(self, obj: Any, schema: Dict[str, Any]) -> Any:
        """Translate a foreign data-model object into this engine's model."""
        return obj


class DenseHBMEngine(Engine):
    """SciDB analog: dense sharded arrays resident in device HBM."""
    kind = "dense_hbm"
    islands = ("array",)

    def _place(self, obj: Any) -> Any:
        if self.mesh is None or self.rules is None:
            return obj
        # tensors / pytrees get device placement with logical-axis shardings
        return obj

    def coerce(self, obj: Any, schema: Dict[str, Any]) -> Any:
        if isinstance(obj, dm.Table):
            # relational -> array: columns become attributes; the cast's
            # destination schema names which column is the dimension
            # (paper §VI.A-e: the user supplies the target schema to
            # resolve cross-model ambiguity).
            dest = schema.get("dest_schema", "")
            dim_names = ("i",)
            cols = dict(obj.columns)
            if dest and "[" in dest:
                from repro.core.shims import _parse_scidb_schema
                _, names = _parse_scidb_schema(dest)
                if len(names) == 1 and names[0] in cols:
                    order = jnp.argsort(cols[names[0]])
                    cols = {n: v[order] for n, v in cols.items()
                            if n != names[0]}
                    dim_names = (names[0],)
            attrs = {n: jnp.asarray(v) for n, v in cols.items()}
            return dm.ArrayObject(attrs, dim_names)
        return obj


class HostStoreEngine(Engine):
    """PostgreSQL analog: host-DRAM rows/columns; fp32 master state."""
    kind = "host_store"
    islands = ("relational",)

    def _place(self, obj: Any) -> Any:
        if isinstance(obj, (jax.Array,)):
            return np.asarray(obj)          # host residency
        return obj

    def coerce(self, obj: Any, schema: Dict[str, Any]) -> Any:
        if isinstance(obj, dm.ArrayObject):
            cols = {n: jnp.asarray(v).reshape(-1)
                    for n, v in obj.attrs.items()}
            for d in obj.dim_names:
                if d not in cols:
                    cols[d] = obj.dim_grid(d).reshape(-1)
            return dm.Table(cols)
        return obj


class KVStoreEngine(Engine):
    """Accumulo analog: sorted KV rows; payloads may be int8-quantized."""
    kind = "kv_store"
    islands = ("text",)

    def coerce(self, obj: Any, schema: Dict[str, Any]) -> Any:
        if isinstance(obj, dm.Table):
            keys, values = [], []
            cols = list(obj.columns)
            n = obj.num_rows
            first = cols[0]
            for i in range(n):
                row = f"r_{i:08d}"
                for c in cols:
                    keys.append((row, "col", c))
                    values.append(str(np.asarray(obj.columns[c][i])))
            return dm.KVTable(keys, values)
        if isinstance(obj, dm.ArrayObject):
            keys, values = [], []
            for aname, v in obj.attrs.items():
                flat = v.reshape(-1)
                # page into 1k-cell chunks (Accumulo-style tablet rows)
                for p in range(0, flat.shape[0], 1024):
                    keys.append((f"r_{p:010d}", "attr", aname))
                    values.append(flat[p:p + 1024])
            return dm.KVTable(keys, values)
        return obj


class ReplicatedEngine(Engine):
    """Small tensors replicated across the mesh (norm scales, biases).
    Storage-only: it backs no island query language (islands=())."""
    kind = "replicated"
    islands = ()


ENGINE_KINDS = {
    "dense_hbm": DenseHBMEngine,
    "host_store": HostStoreEngine,
    "kv_store": KVStoreEngine,
    "replicated": ReplicatedEngine,
}
