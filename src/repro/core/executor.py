"""The Executor (paper §V.D): executes QueryExecutionPlans — sub-queries
issued to their engines in dependency order, Migrator invoked on cast edges,
per-stage timings recorded (these timings are the Fig-5 reproduction data).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

from repro.core import bql
from repro.core.engines import Engine
from repro.core.migrator import MigrationParams, Migrator


class LocalQueryExecutionException(Exception):
    pass


@dataclasses.dataclass
class QueryExecutionPlan:
    """One concrete choice of engines + cast methods for a parsed query."""
    root: bql.IslandQueryNode
    node_engines: Dict[int, str]       # node_id -> engine name
    cast_methods: Dict[int, str]       # cast_id -> binary|staged|quant

    @property
    def qep_id(self) -> str:
        eng = ",".join(f"{k}:{v}" for k, v in sorted(
            self.node_engines.items()))
        casts = ",".join(f"{k}:{v}" for k, v in sorted(
            self.cast_methods.items()))
        return f"engines[{eng}]|casts[{casts}]"


@dataclasses.dataclass
class QueryResult:
    value: Any
    qep_id: str
    stages: List[Tuple[str, float]]

    @property
    def seconds(self) -> float:
        return sum(s for _, s in self.stages)


def assign_ids(root: bql.IslandQueryNode
               ) -> Tuple[Dict[int, bql.IslandQueryNode],
                          Dict[int, bql.CastNode]]:
    """Stable post-order ids for island nodes and cast edges."""
    nodes: Dict[int, bql.IslandQueryNode] = {}
    casts: Dict[int, bql.CastNode] = {}

    def visit(node: bql.IslandQueryNode):
        for cast in node.casts:
            visit(cast.child)
            casts[len(casts)] = cast
        nodes[len(nodes)] = node

    visit(root)
    return nodes, casts


class Executor:
    """Mirrors the paper's Executor: static-style executePlan entrypoints."""

    def __init__(self, engines: Dict[str, Engine], migrator: Migrator,
                 monitor=None) -> None:
        self.engines = engines
        self.migrator = migrator
        self.monitor = monitor
        self._pool = ThreadPoolExecutor(max_workers=4)

    def execute_plan(self, plan: QueryExecutionPlan) -> QueryResult:
        from repro.core import shims
        stages: List[Tuple[str, float]] = []
        nodes, casts = assign_ids(plan.root)
        node_ids = {id(n): nid for nid, n in nodes.items()}
        cast_ids = {id(c): cid for cid, c in casts.items()}
        tmp_counter = [0]

        def run_node(node: bql.IslandQueryNode) -> Any:
            nid = node_ids[id(node)]
            engine = self.engines[plan.node_engines[nid]]
            # resolve casts feeding this node first
            for cast in node.casts:
                child_val = run_node(cast.child)
                child_nid = node_ids[id(cast.child)]
                child_engine = self.engines[plan.node_engines[child_nid]]
                tmp = f"__tmp_{tmp_counter[0]}"
                tmp_counter[0] += 1
                child_engine.put(tmp, child_val)
                cid = cast_ids[id(cast)]
                method = plan.cast_methods.get(cid, "binary")
                t0 = time.perf_counter()
                result = self.migrator.migrate(
                    child_engine, tmp, engine, cast.dest_name,
                    MigrationParams(method=method,
                                    dest_schema=cast.dest_schema))
                stages.append(("Migrator dispatch",
                               result.dispatch_seconds))
                stages.append((f"Migration ({method})",
                               result.transfer_seconds))
                child_engine.delete(tmp)
            t0 = time.perf_counter()
            try:
                value = shims.execute(node.island, engine, node.query)
            except Exception as exc:                         # noqa: BLE001
                raise LocalQueryExecutionException(
                    f"{node.island} query failed on {engine.name}: "
                    f"{node.query!r}: {exc}") from exc
            dt = time.perf_counter() - t0
            stages.append((f"{node.island} query ({engine.name})", dt))
            engine.record(f"{node.island}_query", dt)
            if self.monitor is not None:
                self.monitor.observe_engine(engine.name, dt)
            # clean up materialized cast outputs
            for cast in node.casts:
                engine.delete(cast.dest_name)
            return value

        value = run_node(plan.root)
        return QueryResult(value=value, qep_id=plan.qep_id, stages=stages)

    def execute_plan_async(self, plan: QueryExecutionPlan
                           ) -> "Future[QueryResult]":
        return self._pool.submit(self.execute_plan, plan)
