"""The Executor (paper §V.D): executes QueryExecutionPlans — sub-queries
issued to their engines in dependency order, Migrator invoked on cast edges,
per-stage timings recorded (these timings are the Fig-5 reproduction data).

Execution is a dependency-aware concurrent scheduler: the stage DAG is
built from ``assign_ids`` (one task per island sub-query, one per cast
migration), and independent tasks are submitted to a ThreadPoolExecutor as
their dependencies resolve.  Cross-engine plans therefore pay the DAG's
critical path rather than the sum of all engine latencies (Polystore++'s
inter-engine parallelism argument).  Both numbers are recorded on the
result — ``serial_sum_seconds`` (what a serial executor would pay, and the
Fig-5-comparable quantity) and ``critical_path_seconds`` — so the paper
reproduction stays intact while the overlap is measurable.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import re
import time
from concurrent.futures import (FIRST_COMPLETED, Future, ThreadPoolExecutor,
                                wait)
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import bql
from repro.core.engines import Engine
from repro.core.migrator import MigrationParams, Migrator
from repro.obs import trace


class LocalQueryExecutionException(Exception):
    pass


class DataUnavailableException(Exception):
    """Marker base for *data-dependent, transient* island errors (e.g. a
    stream window that isn't materializable yet): the plan itself is
    valid and re-running it later may succeed, so the Planner must not
    evict a cached plan when one of these (or a LocalQueryExecution-
    Exception caused by one) surfaces.  Island shims raise subclasses —
    see repro.stream.engine.StreamException."""


class PlanAbortedException(Exception):
    """Raised when a plan execution is cancelled (training-mode early
    cancel: the plan is already slower than the best finished one)."""


@dataclasses.dataclass(frozen=True)
class ExecutorConfig:
    """Concurrency knobs (threaded through api.BigDawg / serve.engine).

    ``max_workers`` defaults from ``REPRO_MAX_WORKERS`` so whole test
    runs can be re-executed under a different thread budget without code
    changes (CI's flake-hunter job runs the stream/executor suites at 8
    workers to shake out lock-order and watermark races)."""
    mode: str = "concurrent"           # "concurrent" | "serial"
    max_workers: int = dataclasses.field(
        default_factory=lambda: int(
            os.environ.get("REPRO_MAX_WORKERS", "4")))


# unique temp-object ids, shared process-wide so concurrently executing
# plans never collide on scratch names
_TMP_IDS = itertools.count()
# unique scopes for execute_plan_async (concurrent async plans must not
# collide on materialized cast dest names either)
_ASYNC_SCOPE_IDS = itertools.count()


@dataclasses.dataclass
class QueryExecutionPlan:
    """One concrete choice of engines + cast methods for a parsed query."""
    root: bql.IslandQueryNode
    node_engines: Dict[int, str]       # node_id -> engine name
    cast_methods: Dict[int, str]       # cast_id -> binary|staged|quant

    @property
    def qep_id(self) -> str:
        eng = ",".join(f"{k}:{v}" for k, v in sorted(
            self.node_engines.items()))
        casts = ",".join(f"{k}:{v}" for k, v in sorted(
            self.cast_methods.items()))
        return f"engines[{eng}]|casts[{casts}]"


@dataclasses.dataclass
class QueryResult:
    value: Any
    qep_id: str
    stages: List[Tuple[str, float]]
    wall_seconds: float = 0.0
    critical_path_seconds: float = 0.0

    @property
    def seconds(self) -> float:
        """Serial-sum of all stage durations (the Fig-5 quantity)."""
        return sum(s for _, s in self.stages)

    @property
    def serial_sum_seconds(self) -> float:
        return self.seconds


def assign_ids(root: bql.IslandQueryNode
               ) -> Tuple[Dict[int, bql.IslandQueryNode],
                          Dict[int, bql.CastNode]]:
    """Stable post-order ids for island nodes and cast edges."""
    nodes: Dict[int, bql.IslandQueryNode] = {}
    casts: Dict[int, bql.CastNode] = {}

    def visit(node: bql.IslandQueryNode):
        for cast in node.casts:
            visit(cast.child)
            casts[len(casts)] = cast
        nodes[len(nodes)] = node

    visit(root)
    return nodes, casts


def cast_parents(nodes: Dict[int, bql.IslandQueryNode]
                 ) -> Dict[int, int]:
    """id(cast) -> parent node id.  Keyed by identity: dataclass equality
    would conflate structurally identical cast subtrees under different
    parents."""
    return {id(c): nid for nid, n in nodes.items() for c in n.casts}


def build_task_graph(nodes: Dict[int, bql.IslandQueryNode],
                     casts: Dict[int, bql.CastNode]
                     ) -> Dict[Tuple[str, int], List[Tuple[str, int]]]:
    """The stage DAG: task -> list of tasks it depends on.

    Tasks are ("node", nid) — run the island sub-query — and
    ("cast", cid) — migrate a child result to the parent's engine.  A cast
    depends on its child node; a node depends on all casts feeding it.
    Sibling subtrees share no edges, so they run concurrently.
    """
    node_ids = {id(n): nid for nid, n in nodes.items()}
    cast_ids = {id(c): cid for cid, c in casts.items()}
    deps: Dict[Tuple[str, int], List[Tuple[str, int]]] = {}
    for nid, node in nodes.items():
        deps[("node", nid)] = [("cast", cast_ids[id(c)])
                               for c in node.casts]
    for cid, cast in casts.items():
        deps[("cast", cid)] = [("node", node_ids[id(cast.child)])]
    return deps


def critical_path_seconds(
        deps: Dict[Tuple[str, int], List[Tuple[str, int]]],
        durations: Dict[Tuple[str, int], float]) -> float:
    """Longest dependency chain through the DAG, weighted by task time."""
    memo: Dict[Tuple[str, int], float] = {}

    def longest(task: Tuple[str, int]) -> float:
        if task not in memo:
            below = max((longest(d) for d in deps.get(task, ())),
                        default=0.0)
            memo[task] = durations.get(task, 0.0) + below
        return memo[task]

    return max((longest(t) for t in deps), default=0.0)


def _scoped_query(query: str, renames: Dict[str, str]) -> str:
    """Rewrite cast dest-name references in island query text.

    Only word-boundary occurrences outside quoted literals are rewritten,
    so a predicate like ``where label = 'c'`` survives a cast named ``c``.
    (A bare column sharing a dest name is ambiguous in the source language
    itself — dest names shadow — and is rewritten like any reference.)
    """
    # split on quoted spans; even indices are code, odd are literals
    parts = re.split(r"('[^']*'|\"[^\"]*\")", query)
    for old, new in renames.items():
        pat = re.compile(rf"\b{re.escape(old)}\b")
        for i in range(0, len(parts), 2):
            parts[i] = pat.sub(new, parts[i])
    return "".join(parts)


class Executor:
    """Mirrors the paper's Executor: static-style executePlan entrypoints."""

    def __init__(self, engines: Dict[str, Engine], migrator: Migrator,
                 monitor=None,
                 config: Optional[ExecutorConfig] = None) -> None:
        self.engines = engines
        self.migrator = migrator
        self.monitor = monitor
        self.config = config or ExecutorConfig()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, self.config.max_workers))

    def execute_plan(self, plan: QueryExecutionPlan,
                     mode: Optional[str] = None,
                     should_abort: Optional[Callable[[], bool]] = None,
                     scope: str = "") -> QueryResult:
        """Execute one QEP.

        ``mode`` overrides the configured scheduler ("concurrent" or
        "serial"); ``should_abort`` is polled before each task starts
        (training-mode early cancel); ``scope`` suffixes cast dest names so
        concurrently executing plans never collide on materialized objects.
        """
        from repro.core import shims
        mode = mode or self.config.mode
        nodes, casts = assign_ids(plan.root)
        node_ids = {id(n): nid for nid, n in nodes.items()}
        cast_ids = {id(c): cid for cid, c in casts.items()}
        deps = build_task_graph(nodes, casts)

        # scoped names for materialized cast outputs
        dest_names = {cid: (f"{c.dest_name}__{scope}" if scope
                            else c.dest_name)
                      for cid, c in casts.items()}
        cast_parent = cast_parents(nodes)

        # per-task outputs, written once each — no lock needed
        values: Dict[int, Any] = {}                       # nid -> value
        task_stages: Dict[Tuple[str, int],
                          List[Tuple[str, float]]] = {}

        def run_cast(cid: int) -> None:
            cast = casts[cid]
            child_nid = node_ids[id(cast.child)]
            parent_nid = cast_parent[id(cast)]
            child_engine = self.engines[plan.node_engines[child_nid]]
            engine = self.engines[plan.node_engines[parent_nid]]
            method = plan.cast_methods.get(cid, "binary")
            tmp = f"__tmp_{next(_TMP_IDS)}"
            with trace.span("executor/cast", method=method,
                            src=child_engine.name, dst=engine.name):
                child_engine.put(tmp, values[child_nid])
                try:
                    result = self.migrator.migrate(
                        child_engine, tmp, engine, dest_names[cid],
                        MigrationParams(method=method,
                                        dest_schema=cast.dest_schema))
                finally:
                    child_engine.delete(tmp)
            task_stages[("cast", cid)] = [
                ("Migrator dispatch", result.dispatch_seconds),
                (f"Migration ({method})", result.transfer_seconds)]

        def run_node(nid: int) -> None:
            node = nodes[nid]
            engine = self.engines[plan.node_engines[nid]]
            renames = {c.dest_name: dest_names[cast_ids[id(c)]]
                       for c in node.casts
                       if c.dest_name != dest_names[cast_ids[id(c)]]}
            query = _scoped_query(node.query, renames) if renames \
                else node.query
            t0 = time.perf_counter()
            with trace.span("executor/node", island=node.island,
                            engine=engine.name):
                try:
                    value = shims.execute(node.island, engine, query)
                except Exception as exc:                 # noqa: BLE001
                    raise LocalQueryExecutionException(
                        f"{node.island} query failed on {engine.name}: "
                        f"{node.query!r}: {exc}") from exc
            dt = time.perf_counter() - t0
            task_stages[("node", nid)] = [
                (f"{node.island} query ({engine.name})", dt)]
            engine.record(f"{node.island}_query", dt)
            if self.monitor is not None:
                self.monitor.observe_engine(engine.name, dt)
            values[nid] = value
            # clean up materialized cast outputs
            for c in node.casts:
                engine.delete(dest_names[cast_ids[id(c)]])

        def run_task(task: Tuple[str, int]) -> None:
            if should_abort is not None and should_abort():
                raise PlanAbortedException(plan.qep_id)
            if task[0] == "cast":
                run_cast(task[1])
            else:
                run_node(task[1])

        # single-task DAGs (no casts) gain nothing from a pool — skip the
        # per-call thread spawn/teardown on the lean-mode hot path
        if len(deps) <= 1:
            mode = "serial"
        wall0 = time.perf_counter()
        with trace.span("executor/plan", mode=mode, tasks=len(deps)):
            try:
                if mode == "serial":
                    for task in self._topo_order(nodes, casts, node_ids,
                                                 cast_ids):
                        run_task(task)
                else:
                    self._run_concurrent(deps, run_task)
            except BaseException:
                # an aborted/failed plan never reaches the parent-node
                # cleanup that deletes materialized cast outputs — sweep
                # them here so cancelled training plans don't leak
                # scoped objects
                for cid, cast in casts.items():
                    parent = self.engines[
                        plan.node_engines[cast_parent[id(cast)]]]
                    parent.delete(dest_names[cid])
                raise
        wall = time.perf_counter() - wall0

        # canonical stage order (identical to serial execution order), so
        # results are bit-identical across modes
        stages: List[Tuple[str, float]] = []
        for task in self._topo_order(nodes, casts, node_ids, cast_ids):
            stages.extend(task_stages.get(task, ()))
        durations = {t: sum(s for _, s in ss)
                     for t, ss in task_stages.items()}
        root_nid = node_ids[id(plan.root)]
        return QueryResult(
            value=values[root_nid], qep_id=plan.qep_id, stages=stages,
            wall_seconds=wall,
            critical_path_seconds=critical_path_seconds(deps, durations))

    @staticmethod
    def _topo_order(nodes, casts, node_ids, cast_ids
                    ) -> List[Tuple[str, int]]:
        """Serial execution order: post-order, child before its cast,
        all casts before their parent node (matches the v0.1 executor)."""
        order: List[Tuple[str, int]] = []

        def visit(node: bql.IslandQueryNode):
            for cast in node.casts:
                visit(cast.child)
                order.append(("cast", cast_ids[id(cast)]))
            order.append(("node", node_ids[id(node)]))

        root = nodes[max(nodes)]          # post-order: root has max id
        visit(root)
        return order

    def _run_concurrent(
            self, deps: Dict[Tuple[str, int], List[Tuple[str, int]]],
            run_task: Callable[[Tuple[str, int]], None]) -> None:
        """Submit tasks as their dependencies resolve; propagate the first
        failure after letting in-flight tasks drain (no orphan threads)."""
        remaining = {t: set(ds) for t, ds in deps.items()}
        dependents: Dict[Tuple[str, int], List[Tuple[str, int]]] = {}
        for t, ds in deps.items():
            for d in ds:
                dependents.setdefault(d, []).append(t)
        first_exc: Optional[BaseException] = None
        workers = max(1, self.config.max_workers)
        # worker threads inherit the scheduling thread's active span, so
        # node/cast spans parent-link across the pool hop
        run_task = trace.bind(run_task)
        with ThreadPoolExecutor(max_workers=workers) as pool:
            futures: Dict[Future, Tuple[str, int]] = {}
            for t in sorted(remaining):
                if not remaining[t]:
                    futures[pool.submit(run_task, t)] = t
            while futures:
                done, _ = wait(futures, return_when=FIRST_COMPLETED)
                for f in done:
                    t = futures.pop(f)
                    exc = f.exception()
                    if exc is not None:
                        if first_exc is None:
                            first_exc = exc
                        continue
                    if first_exc is not None:
                        continue          # stop scheduling after a failure
                    for dep in dependents.get(t, ()):
                        remaining[dep].discard(t)
                        if not remaining[dep]:
                            futures[pool.submit(run_task, dep)] = dep
        if first_exc is not None:
            raise first_exc

    def execute_plan_async(self, plan: QueryExecutionPlan
                           ) -> "Future[QueryResult]":
        return self._pool.submit(trace.bind(self.execute_plan), plan,
                                 scope=f"async{next(_ASYNC_SCOPE_IDS)}")
