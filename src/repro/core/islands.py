"""Islands (paper §III): data model + operations + candidate engines.
An island provides location independence among its engines; the engine-
native escape hatch (semantic completeness) is ``Engine.get``/``put`` plus
each engine's own methods.

Beyond the v0.1 release's three islands, this reproduction adds the
``streaming`` island the architecture papers call for (arXiv:1609.07548,
arXiv:1602.08791: S-Store as a polystore member): bounded ring-buffer
streams whose window views materialize as relational/array objects —
see ``repro.stream``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

from repro.core import datamodel as dm


@dataclasses.dataclass(frozen=True)
class Island:
    name: str
    data_model: str
    operations: Tuple[str, ...]
    # a type, or a tuple of types (isinstance-compatible)
    result_type: Union[type, Tuple[type, ...]]


ISLANDS = {
    "relational": Island(
        name="relational", data_model="tables of tuples",
        operations=("select", "project", "filter", "join", "aggregate",
                    "group", "sort", "limit", "distinct"),
        result_type=dm.Table),
    "array": Island(
        name="array", data_model="multi-dimensional arrays",
        operations=("scan", "filter", "project", "aggregate", "cross_join",
                    "redimension", "sort"),
        result_type=dm.ArrayObject),
    "text": Island(
        name="text", data_model="sorted key-value rows",
        operations=("scan", "range"),
        result_type=list),
    "streaming": Island(
        name="streaming", data_model="append-only bounded row streams",
        operations=("append", "window", "ewindow", "join", "aggregate",
                    "rate", "snapshot", "watermark", "flush"),
        # windows materialize as arrays; snapshots/rates/joins as tables
        result_type=(dm.ArrayObject, dm.Table)),
    "ml": Island(
        name="ml", data_model="model-scored stream windows",
        operations=("infer",),
        # per-window score rows
        result_type=dm.Table),
}


def validate_result(island_name: str, value) -> bool:
    island = ISLANDS[island_name]
    return isinstance(value, island.result_type)
