"""Islands (paper §III): data model + operations + candidate engines.
An island provides location independence among its engines; the engine-
native escape hatch (semantic completeness) is ``Engine.get``/``put`` plus
each engine's own methods.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core import datamodel as dm


@dataclasses.dataclass(frozen=True)
class Island:
    name: str
    data_model: str
    operations: Tuple[str, ...]
    result_type: type


ISLANDS = {
    "relational": Island(
        name="relational", data_model="tables of tuples",
        operations=("select", "project", "filter", "join", "aggregate",
                    "group", "sort", "limit", "distinct"),
        result_type=dm.Table),
    "array": Island(
        name="array", data_model="multi-dimensional arrays",
        operations=("scan", "filter", "project", "aggregate", "cross_join",
                    "redimension", "sort"),
        result_type=dm.ArrayObject),
    "text": Island(
        name="text", data_model="sorted key-value rows",
        operations=("scan", "range"),
        result_type=list),
}


def validate_result(island_name: str, value) -> bool:
    island = ISLANDS[island_name]
    return isinstance(value, island.result_type)
