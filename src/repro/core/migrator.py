"""The Migrator (paper §V.C): moves objects between engines.

Routes:
  binary — zero-copy/native handoff (the paper's PostgreSQL<->SciDB binary
           migration); cross-model objects are translated via the
           destination engine's ``coerce`` using the cast's target schema.
  staged — format-translating slow path (CSV export -> parse -> load),
           faithful to the paper's observation that cross-island migration
           pays format translation + dispatch costs.
  quant  — binary + int8 re-coding through the quant_cast Pallas kernel
           (KV-cache pages, gradient compression) — a beyond-paper cast.
  stream — live stream-state *move* between StreamEngines: the ring
           buffer's full state (data, cumulative rings, seq watermark,
           drop counters, rate history — and for event-time streams the
           insertion buffer, low watermark, and late-row counters, so
           pending out-of-order rows are neither lost nor double-
           counted) is deep-copied onto the destination and the source
           copy deleted, so a shard can be rebalanced under a running
           standing query without losing continuity.  Unlike the other
           routes this one moves rather than copies by default — two
           *writable* replicas of one append-ordered buffer would fork
           the seq space.  ``MigrationParams(copy=True)`` instead
           builds a **read replica**: the source stays live and the
           destination gets a detached, renamed deep copy for fan-out
           reads (the serving front door's hot-read path); a durable
           source's replica carries the segment-log positions captured
           at the copy instant, so ``durability.catch_up`` can replay
           it forward incrementally without a seq fork.

On a TPU mesh the binary route between DenseHBM shardings is a resharding
collective (device_put to a new NamedSharding) — no host round-trip; the
staged route stages through host memory.  Both are exercised by the
benchmarks to reproduce the paper's migration-cost structure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm
from repro.core.engines import Engine
from repro.obs import metrics, trace


class MigrationException(Exception):
    pass


@dataclasses.dataclass
class MigrationParams:
    method: Optional[str] = None        # None -> negotiate from catalog
    dest_schema: str = ""
    quant_block: int = 128
    copy: bool = False                  # stream route: replica, not move


@dataclasses.dataclass
class MigrationResult:
    object_from: str
    object_to: str
    engine_from: str
    engine_to: str
    method: str
    bytes_moved: int
    rows: int
    dispatch_seconds: float
    transfer_seconds: float

    @property
    def seconds(self) -> float:
        return self.dispatch_seconds + self.transfer_seconds


class Migrator:
    """Single static-style interface, mirroring the paper's Migrator class."""

    def __init__(self, catalog=None) -> None:
        self.catalog = catalog
        self.log: list[MigrationResult] = []

    def migrate(self, engine_from: Engine, object_from: str,
                engine_to: Engine, object_to: str,
                params: Optional[MigrationParams] = None) -> MigrationResult:
        params = params or MigrationParams()
        with trace.span("migrator/route", src=engine_from.name,
                        dst=engine_to.name) as sp:
            t0 = time.perf_counter()
            if not engine_from.has(object_from):
                raise MigrationException(
                    f"{engine_from.name} has no object {object_from!r}")
            method = params.method or self._negotiate(engine_from,
                                                      engine_to)
            sp.set(method=method)
            t1 = time.perf_counter()

            obj = engine_from.get(object_from)
            nbytes = dm.object_nbytes(obj)
            rows = getattr(obj, "num_rows", 0) or (
                int(np.prod(obj.shape))
                if isinstance(obj, dm.ArrayObject) else 0)

            if method == "binary":
                payload, schema = engine_from.export_binary(object_from)
                schema["dest_schema"] = params.dest_schema
                coerced = engine_to.coerce(payload, schema)
                engine_to.import_binary(object_to, coerced, schema)
            elif method == "staged":
                payload, schema = engine_from.export_staged(object_from)
                schema["dest_schema"] = params.dest_schema
                engine_to.import_staged(object_to, payload, schema)
            elif method == "quant":
                self._quant_migrate(engine_from, object_from, engine_to,
                                    object_to, params)
            elif method == "stream":
                self._stream_migrate(engine_from, object_from, engine_to,
                                     object_to, copy=params.copy)
            else:
                raise MigrationException(f"unknown cast method {method!r}")
            t2 = time.perf_counter()

        result = MigrationResult(
            object_from=object_from, object_to=object_to,
            engine_from=engine_from.name, engine_to=engine_to.name,
            method=method, bytes_moved=nbytes, rows=int(rows),
            dispatch_seconds=t1 - t0, transfer_seconds=t2 - t1)
        self.log.append(result)
        metrics.counter("repro_migrations_total",
                        "Migrator routes executed",
                        method=method).inc()
        metrics.counter("repro_migration_bytes_total",
                        "bytes moved between engines",
                        method=method).inc(nbytes)
        metrics.histogram("repro_migration_seconds",
                          "dispatch + transfer time per migration",
                          method=method).observe(result.seconds)
        engine_from.record(f"migrate_out:{method}", result.seconds)
        engine_to.record(f"migrate_in:{method}", result.seconds)
        return result

    def _negotiate(self, engine_from: Engine, engine_to: Engine) -> str:
        """Pick the cast route: catalog-registered, else binary."""
        if self.catalog is not None:
            src = self.catalog.engine_by_name(engine_from.name)
            dst = self.catalog.engine_by_name(engine_to.name)
            if src and dst:
                casts = self.catalog.casts_between(src.eid, dst.eid)
                if casts:
                    # prefer binary > quant > staged
                    order = {"binary": 0, "quant": 1, "staged": 2}
                    return sorted(casts,
                                  key=lambda c: order.get(c.method, 9)
                                  )[0].method
        return "binary"

    def _stream_migrate(self, engine_from: Engine, object_from: str,
                        engine_to: Engine, object_to: str,
                        copy: bool = False) -> None:
        """Move a live ring buffer between StreamEngines (see module
        docstring: this route moves by default; ``copy=True`` builds a
        detached read replica instead and leaves the source live).

        Shard moves are safe under concurrent producers:
        ``ShardedStream.migrate_shard`` pauses the shard's ordered
        committer, so every seq block reserved before the move drains
        into the exported state and blocks reserved during it publish
        to the new ring afterwards — in-flight reservations are carried,
        never lost.  ``Stream.export_state`` likewise drains its own
        committer first.  Only a *direct* move of an unsharded stream
        still needs external serialization: a block reserved after the
        export but before the delete below lands in the doomed source
        object (pause the feed, or move between ticks)."""
        from repro.stream.engine import (ShardedStream, Stream,
                                         StreamEngine)
        obj = engine_from.get(object_from)
        allowed = (Stream, ShardedStream) if copy else (Stream,)
        if not isinstance(obj, allowed):
            raise MigrationException(
                f"stream cast needs a "
                f"{' or '.join(c.__name__ for c in allowed)} source, "
                f"got {type(obj).__name__} for {object_from!r}")
        if not isinstance(engine_to, StreamEngine):
            raise MigrationException(
                f"stream cast needs a StreamEngine destination, "
                f"{engine_to.name} is {engine_to.kind}")
        if engine_to is engine_from and object_to == object_from:
            # moving: put + delete source would delete the freshly
            # imported copy; copying: put would overwrite the primary
            raise MigrationException(
                f"stream cast cannot {'copy' if copy else 'move'} "
                f"{object_from!r} onto itself on {engine_from.name}")
        if copy:
            durable = getattr(obj, "_durable", None)
            if durable is not None:
                # capture (state, per-lane log positions) at one
                # coherent instant so durability.catch_up can replay
                # the replica forward from exactly where the copy ends
                state, lsns = obj._checkpoint_snapshot(
                    durable.lane_lsns)
                replica = obj.clone(object_to, state=state)
                replica._replica_lsns = lsns
            else:
                replica = obj.clone(object_to)
            engine_to.put(object_to, replica)
            return
        state = obj.export_state()
        engine_to.put(object_to, Stream.from_state(state))
        engine_from.delete(object_from)
        # a move changes physical placement, so the catalog must follow
        # (copy routes leave the source object untouched and don't)
        if (self.catalog is not None
                and self.catalog.object_by_name(object_to) is not None
                and self.catalog.engine_by_name(engine_to.name)
                is not None):
            self.catalog.relocate_object(object_to, engine_to.name)

    def _quant_migrate(self, engine_from: Engine, object_from: str,
                       engine_to: Engine, object_to: str,
                       params: MigrationParams) -> None:
        from repro.kernels.quant_cast import ops as qops
        obj = engine_from.get(object_from)
        if isinstance(obj, dm.KVTable):
            keys, vals = [], []
            for k, v in obj.scan():
                if isinstance(v, (jax.Array, np.ndarray)):
                    q, scale = qops.quantize(jnp.asarray(v, jnp.float32),
                                             block=params.quant_block)
                    vals.append({"q": q, "scale": scale})
                else:
                    vals.append(v)
                keys.append(k)
            engine_to.import_binary(object_to, dm.KVTable(keys, vals),
                                    {"kind": "kvtable", "codec": "int8"})
            return
        if isinstance(obj, (jax.Array, np.ndarray)):
            q, scale = qops.quantize(jnp.asarray(obj, jnp.float32),
                                     block=params.quant_block)
            engine_to.import_binary(object_to, {"q": q, "scale": scale},
                                    {"kind": "tensor", "codec": "int8",
                                     "shape": list(np.asarray(obj).shape)})
            return
        if isinstance(obj, (dm.ArrayObject, dm.Table)):
            fields = obj.attrs if isinstance(obj, dm.ArrayObject) \
                else obj.columns
            quantized = {
                n: dict(zip(("q", "scale"),
                            qops.quantize(jnp.asarray(v, jnp.float32),
                                          block=params.quant_block)))
                for n, v in fields.items()}
            engine_to.import_binary(
                object_to, quantized,
                {"kind": dm.object_kind(obj), "codec": "int8"})
            return
        # pytree of tensors (model state objects)
        quantized = jax.tree.map(
            lambda leaf: dict(zip(("q", "scale"),
                                  qops.quantize(jnp.asarray(
                                      leaf, jnp.float32),
                                      block=params.quant_block))), obj)
        engine_to.import_binary(object_to, quantized,
                                {"kind": "pytree", "codec": "int8"})


def reshard(array: jax.Array, sharding) -> jax.Array:
    """Device-to-device binary cast between shardings (no host round-trip).

    This is the TPU-native reading of the paper's binary migration: on a
    mesh, ``device_put`` onto a new NamedSharding lowers to all-to-all /
    collective-permute traffic only.
    """
    return jax.device_put(array, sharding)
