"""The Monitor (paper §V.E): records query/QEP performance, serves the best
plan for a signature, finds the closest benchmarked signature for new
queries, and — in this system — doubles as the distributed-runtime health
tracker (per-engine latency EWMAs -> straggler detection, feeding the
Planner's engine avoidance; DESIGN.md §5).

Two metric sources:
  * measured wall-clock (executable CPU/TPU paths), via add_measurement();
  * AOT cost models (dry-run ``cost_analysis`` roofline seconds), via
    add_cost_model() — lets plans be ranked before first execution.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.signatures import Signature
from repro.obs import metrics


@dataclasses.dataclass
class QEPRecord:
    qep_id: str
    durations: List[float] = dataclasses.field(default_factory=list)
    cost_model_seconds: Optional[float] = None

    def best_estimate(self) -> float:
        if self.durations:
            return sum(self.durations) / len(self.durations)
        if self.cost_model_seconds is not None:
            return self.cost_model_seconds
        return float("inf")


class Monitor:
    EWMA_ALPHA = 0.3

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._benchmarks: Dict[str, Tuple[Signature, Dict[str, QEPRecord]]] \
            = {}
        # bumped on every new measurement/cost-model for a signature; the
        # Planner's plan cache uses this to detect stale cached plans
        self._versions: Dict[str, int] = {}
        self.engine_ewma: Dict[str, float] = {}
        self.engine_ops: Dict[str, int] = {}
        # per-continuous-query tick health (repro.stream.continuous)
        self.stream_ewma: Dict[str, float] = {}
        self.stream_stats: Dict[str, Dict[str, int]] = {}
        # latest per-shard ingest/drop snapshot of each sharded stream
        # (StreamRuntime.tick feeds this; the admin rebalance hook reads
        # it to spot lopsided placements)
        self.shard_stats: Dict[str, Dict[int, Dict[str, float]]] = {}
        # per-tick EWMA of each shard's ingest load (appended + 2x
        # dropped *deltas* between snapshots): the rebalance signal
        # tracks *current* load, so late-onset skew on a long-balanced
        # stream surfaces within a few ticks and a donor engine stops
        # being charged for historical ingest after a move
        self.shard_ewma: Dict[str, Dict[int, float]] = {}
        self._shard_prev: Dict[str, Dict[int, Tuple[float, float]]] = {}
        # per-stream event-time health: low watermark + late/pending
        # counters (StreamRuntime.tick feeds this for ts streams)
        self.stream_watermarks: Dict[str, Dict[str, Any]] = {}
        # per-stream multi-producer ingest health: open/peak producer
        # handles, seq blocks reserved, rows in flight, ordered-commit
        # waits (StreamRuntime.tick feeds this from
        # stream.ingest_concurrency(); admin.status()["streams"] shows it)
        self.ingest_stats: Dict[str, Dict[str, int]] = {}
        # compiled-query-path health: the stream/compile stats() block
        # (backend, compiles, cache hits, fallbacks + reasons).  One
        # process-wide dict, not per-stream — the jit plan cache is keyed
        # by stream identity internally but its counters are global.
        self.jit_stats: Dict[str, Any] = {}
        # per-stream durability health: segment-log/checkpoint counters
        # (StreamRuntime.tick feeds this from StreamDurability.stats())
        # and the last recover_stream outcome
        self.durability_stats: Dict[str, Dict[str, Any]] = {}
        self.recoveries: Dict[str, Dict[str, Any]] = {}
        # serving front-door health (repro.serve.frontdoor feeds this:
        # tenants, subscriptions, shared queries, admission rejects,
        # delivered/dropped results, replica counts)
        self.serve_stats: Dict[str, Any] = {}
        # ml-island inference health: the repro.stream.ml.stats() block
        # (models loaded, waves, windows scored, params-cache hits,
        # fallbacks).  Process-wide like the jit stats — the model/param
        # caches are keyed per arch internally but the counters are
        # global.
        self.ml_stats: Dict[str, Any] = {}

    # -- benchmark API (paper naming) ----------------------------------------
    def add_benchmarks(self, signature: Signature, lean: bool,
                       qep_ids: Optional[List[str]] = None,
                       runner: Optional[Callable[[str], float]] = None
                       ) -> bool:
        """Register QEPs for a signature; if not ``lean``, run them all now
        through ``runner`` (qep_id -> seconds) and record the timings."""
        with self._lock:
            sig, records = self._benchmarks.setdefault(
                signature.key(), (signature, {}))
            for qid in (qep_ids or []):
                records.setdefault(qid, QEPRecord(qid))
            if not lean and runner is not None:
                for qid in list(records):
                    seconds = runner(qid)
                    records[qid].durations.append(seconds)
            return True

    def add_measurement(self, signature: Signature, qep_id: str,
                        seconds: float) -> None:
        with self._lock:
            _, records = self._benchmarks.setdefault(
                signature.key(), (signature, {}))
            records.setdefault(qep_id, QEPRecord(qep_id)
                               ).durations.append(seconds)
            self._bump(signature.key())

    def add_cost_model(self, signature: Signature, qep_id: str,
                       seconds: float) -> None:
        with self._lock:
            _, records = self._benchmarks.setdefault(
                signature.key(), (signature, {}))
            rec = records.setdefault(qep_id, QEPRecord(qep_id))
            rec.cost_model_seconds = seconds
            self._bump(signature.key())

    def _bump(self, key: str) -> None:
        self._versions[key] = self._versions.get(key, 0) + 1

    def signature_version(self, signature: Signature) -> int:
        """Monotone counter of measurements for a signature (plan-cache
        staleness checks compare this against the version at insert)."""
        with self._lock:
            return self._versions.get(signature.key(), 0)

    def get_benchmark_performance(self, signature: Signature
                                  ) -> Dict[str, List[float]]:
        with self._lock:
            entry = self._benchmarks.get(signature.key())
            if entry is None:
                return {}
            return {qid: list(rec.durations)
                    for qid, rec in entry[1].items()}

    def get_closest_signature(self, signature: Signature
                              ) -> Optional[Signature]:
        """Nearest benchmarked signature; exact key match wins; None if the
        store is empty (caller then adds this signature as new — §V.E)."""
        with self._lock:
            if signature.key() in self._benchmarks:
                return self._benchmarks[signature.key()][0]
            best, best_d = None, float("inf")
            for sig, _ in self._benchmarks.values():
                d = signature.distance(sig)
                if d < best_d:
                    best, best_d = sig, d
            return best

    def estimate_seconds(self, signature: Signature, qep_id: str) -> float:
        """Pre-execution serial-sum estimate for one QEP of a signature:
        mean of measured durations, else the AOT cost model, else — via
        the closest benchmarked signature (QEP ids name engine/cast
        combos, so they transfer across signatures) — the same; inf when
        the Monitor has no history at all (the Planner's cost-model
        early-cancel then falls back to wall-clock cancel)."""
        with self._lock:
            entry = self._benchmarks.get(signature.key())
            if entry is not None and qep_id in entry[1]:
                return entry[1][qep_id].best_estimate()
            closest = self.get_closest_signature(signature)
            if closest is not None:
                entry = self._benchmarks.get(closest.key())
                if entry is not None and qep_id in entry[1]:
                    return entry[1][qep_id].best_estimate()
            return float("inf")

    def best_qep(self, signature: Signature) -> Optional[str]:
        with self._lock:
            entry = self._benchmarks.get(signature.key())
            if entry is None:
                closest = self.get_closest_signature(signature)
                if closest is None:
                    return None
                entry = self._benchmarks.get(closest.key())
                if entry is None:
                    return None
            records = entry[1]
            if not records:
                return None
            return min(records.values(),
                       key=lambda r: r.best_estimate()).qep_id

    # -- engine health (straggler detection) ----------------------------------
    def observe_engine(self, engine_name: str, seconds: float) -> None:
        with self._lock:
            prev = self.engine_ewma.get(engine_name)
            self.engine_ewma[engine_name] = (
                seconds if prev is None
                else self.EWMA_ALPHA * seconds
                + (1 - self.EWMA_ALPHA) * prev)
            self.engine_ops[engine_name] = \
                self.engine_ops.get(engine_name, 0) + 1
            ewma = self.engine_ewma[engine_name]
        metrics.gauge("repro_engine_latency_ewma_seconds",
                      "per-engine query latency EWMA",
                      engine=engine_name).set(ewma)
        metrics.counter("repro_engine_ops_total",
                        "island sub-queries executed per engine",
                        engine=engine_name).inc()
        metrics.histogram("repro_engine_query_seconds",
                          "island sub-query latency",
                          engine=engine_name).observe(seconds)

    # -- continuous-query health (streaming island) ---------------------------
    def observe_stream(self, name: str, latency_seconds: float,
                       dropped: int = 0, lagging: bool = False,
                       late: int = 0) -> None:
        """Record one standing-query tick: execution latency EWMA plus
        cumulative drop/late/backpressure counters (repro.stream feeds
        this)."""
        with self._lock:
            prev = self.stream_ewma.get(name)
            self.stream_ewma[name] = (
                latency_seconds if prev is None
                else self.EWMA_ALPHA * latency_seconds
                + (1 - self.EWMA_ALPHA) * prev)
            stats = self.stream_stats.setdefault(
                name, {"ticks": 0, "dropped": 0, "backpressure": 0,
                       "late": 0})
            stats["ticks"] += 1
            stats["dropped"] += int(dropped)
            stats["backpressure"] += int(bool(lagging))
            stats["late"] += int(late)
            stats_now = dict(stats)
        metrics.histogram("repro_stream_query_seconds",
                          "standing-query tick latency",
                          query=name).observe(latency_seconds)
        for key, mname in (("ticks", "repro_stream_query_ticks_total"),
                           ("dropped", "repro_stream_query_drops_total"),
                           ("backpressure",
                            "repro_stream_query_backpressure_total"),
                           ("late", "repro_stream_query_late_total")):
            metrics.counter(mname, f"standing-query cumulative {key}",
                            query=name).set_total(stats_now[key])

    def observe_watermark(self, stream_name: str, watermark: float,
                          late: int = 0, pending: int = 0) -> None:
        """Record an event-time stream's low watermark (min across
        shards for key-hashed sharded streams) plus its late-row and
        insertion-buffer counters.  JSON-safe: a watermark that has not
        started is stored as None."""
        with self._lock:
            self.stream_watermarks[stream_name] = {
                "watermark": (None if watermark == float("-inf")
                              else float(watermark)),
                "late": int(late), "pending": int(pending)}
        if watermark != float("-inf"):
            metrics.gauge("repro_stream_watermark",
                          "event-time low watermark",
                          stream=stream_name).set(float(watermark))
        metrics.counter("repro_stream_late_rows_dropped_total",
                        "rows arrived below the watermark (dropped)",
                        stream=stream_name).set_total(int(late))
        metrics.gauge("repro_stream_pending_rows",
                      "insertion-buffer rows above the watermark",
                      stream=stream_name).set(int(pending))

    def observe_serve(self, stats: Dict[str, Any]) -> None:
        """Record the serving front door's health block (one per
        process — the front door is a singleton tier over the
        deployment, like the jit stats)."""
        with self._lock:
            self.serve_stats = dict(stats)
        for key in ("tenants", "subscriptions", "shared_queries",
                    "replicas"):
            if key in stats:
                metrics.gauge(f"repro_serve_{key}",
                              f"serving front door: {key}").set(
                    stats[key])
        for key in ("admission_rejects", "results_delivered",
                    "results_dropped"):
            if key in stats:
                metrics.counter(
                    f"repro_serve_{key}_total",
                    f"serving front door: {key}").set_total(stats[key])

    def observe_ingest(self, stream_name: str,
                       stats: Dict[str, int]) -> None:
        """Record a stream's multi-producer ingest counters (the
        ``ingest_concurrency()`` block: producers open/peak, blocks and
        rows reserved, in-flight rows, ordered-commit waits)."""
        with self._lock:
            self.ingest_stats[stream_name] = dict(stats)
        for key, kind in (("producers_open", "gauge"),
                          ("in_flight_rows", "gauge"),
                          ("blocks_reserved", "counter"),
                          ("rows_reserved", "counter"),
                          ("commit_waits", "counter"),
                          ("commit_steals", "counter")):
            if key not in stats:
                continue
            name = f"repro_stream_ingest_{key}" + (
                "_total" if kind == "counter" else "")
            if kind == "gauge":
                metrics.gauge(name, f"multi-producer ingest {key}",
                              stream=stream_name).set(stats[key])
            else:
                metrics.counter(name, f"multi-producer ingest {key}",
                                stream=stream_name).set_total(stats[key])

    def observe_jit(self, stats: Dict[str, Any]) -> None:
        """Record the compiled standing-query path's counters (the
        ``repro.stream.compile.stats()`` block: active backend, plan
        compiles/cache hits/executions, interpreter fallbacks and their
        reasons).  StreamRuntime.tick feeds this once per tick;
        admin.status()["streams"]["query_backend"] shows it."""
        with self._lock:
            self.jit_stats = dict(stats)

    def observe_ml(self, stats: Dict[str, Any]) -> None:
        """Record the ml island's inference counters (the
        ``repro.stream.ml.stats()`` block: models loaded, waves,
        standing infer executions, windows scored, params-cache
        hits/misses, jax-absent fallbacks).  StreamRuntime.tick feeds
        this once per tick next to the jit stats;
        admin.status()["ml"] shows it."""
        with self._lock:
            self.ml_stats = dict(stats)

    def observe_durability(self, stream_name: str,
                           stats: Dict[str, Any]) -> None:
        """Record a durable stream's segment-log/checkpoint counters
        (the ``StreamDurability.stats()`` block).  StreamRuntime.tick
        feeds this; admin.status()["streams"]["durability"] shows it."""
        with self._lock:
            self.durability_stats[stream_name] = dict(stats)
        metrics.gauge("repro_stream_log_bytes",
                      "segment-log bytes on disk",
                      stream=stream_name).set(stats.get("log_bytes", 0))
        metrics.gauge("repro_stream_log_segments",
                      "segment files in the wal",
                      stream=stream_name).set(stats.get("segments", 0))

    def observe_recovery(self, stream_name: str, rows: int,
                         seconds: float) -> None:
        """Record a recover_stream outcome (rows replayed from the
        segment log and the wall-clock rebuild time)."""
        with self._lock:
            self.recoveries[stream_name] = {
                "rows_replayed": int(rows), "seconds": float(seconds)}

    @staticmethod
    def shard_load(stats: Dict[str, float]) -> float:
        """One shard's *lifetime* ingest load: appended rows, weighted up
        by drops (a dropping shard is oversubscribed even at a middling
        rate).  The seed/fallback for the per-tick EWMA below — current
        load decisions go through ``shard_loads``."""
        return (float(stats.get("appended", 0))
                + 2.0 * float(stats.get("dropped", 0)))

    def observe_shards(self, stream_name: str,
                       shard_stats: Dict[int, Dict[str, float]]) -> None:
        """Record the latest per-shard ingest/drop snapshot of a sharded
        stream (appended/dropped/rows/engine per shard) and fold the
        per-tick load *delta* into each shard's EWMA.  The first snapshot
        seeds the EWMA with the lifetime load; from then on only new
        ingest moves it, so a shard that goes quiet decays toward zero
        within a few ticks instead of carrying its history forever."""
        with self._lock:
            snap = {int(i): dict(st) for i, st in shard_stats.items()}
            prev = self._shard_prev.get(stream_name, {})
            ewma = self.shard_ewma.setdefault(stream_name, {})
            for i, st in snap.items():
                appended = float(st.get("appended", 0))
                dropped = float(st.get("dropped", 0))
                pa, pd = prev.get(i, (0.0, 0.0))
                # max() guards counter resets (a shard recreated fresh)
                delta = (max(0.0, appended - pa)
                         + 2.0 * max(0.0, dropped - pd))
                old = ewma.get(i)
                ewma[i] = (delta if old is None
                           else self.EWMA_ALPHA * delta
                           + (1 - self.EWMA_ALPHA) * old)
            self._shard_prev[stream_name] = {
                i: (float(st.get("appended", 0)),
                    float(st.get("dropped", 0)))
                for i, st in snap.items()}
            self.shard_stats[stream_name] = snap
            ewma_now = dict(ewma)
        for i, st in snap.items():
            metrics.counter("repro_stream_shard_appended_total",
                            "rows appended per shard",
                            stream=stream_name, shard=i
                            ).set_total(float(st.get("appended", 0)))
            metrics.counter("repro_stream_shard_dropped_total",
                            "rows overwritten per shard",
                            stream=stream_name, shard=i
                            ).set_total(float(st.get("dropped", 0)))
            metrics.gauge("repro_stream_shard_load_ewma",
                          "per-tick shard ingest-load EWMA",
                          stream=stream_name, shard=i
                          ).set(ewma_now.get(i, 0.0))

    def shard_loads(self, stream_name: str) -> Dict[int, float]:
        """Current per-shard ingest loads: the per-tick EWMA when
        observations exist, else the lifetime counters of the latest
        snapshot.  Shared by lopsided_shards and StreamRuntime.rebalance
        so the detector and the mover can never disagree."""
        with self._lock:
            ewma = self.shard_ewma.get(stream_name)
            if ewma:
                return dict(ewma)
            stats = self.shard_stats.get(stream_name, {})
            return {i: self.shard_load(st) for i, st in stats.items()}

    def lopsided_shards(self, stream_name: str, factor: float = 3.0
                        ) -> List[int]:
        """Shards of ``stream_name`` whose *current* ingest load (per-
        tick EWMA of appended rows, weighted up by drops — a shard that
        drops is oversubscribed even if its raw rate is middling)
        exceeds ``factor`` x the median shard's.  Empty when the stream
        is unknown or balanced.  EWMA, not lifetime counters: late-onset
        skew on a long-balanced stream is flagged within a few ticks."""
        with self._lock:
            stats = self.shard_stats.get(stream_name)
            if not stats or len(stats) < 2:
                return []
            loads = self.shard_loads(stream_name)
            vals = sorted(loads.values())
            # lower median: with the upper one, skew becomes invisible
            # whenever half or more of the shards are hot (a 2-shard
            # stream could never trigger the rebalance hook)
            median = vals[(len(vals) - 1) // 2]
            if median <= 0:
                # all load on some shards, none on the median: any shard
                # carrying rows while the median is idle is lopsided
                return sorted(i for i, v in loads.items() if v > 0)
            return sorted(i for i, v in loads.items()
                          if v > factor * median)

    def stragglers(self, factor: float = 3.0) -> List[str]:
        """Engines whose EWMA latency exceeds ``factor`` x fleet median."""
        with self._lock:
            if len(self.engine_ewma) < 2:
                return []
            vals = sorted(self.engine_ewma.values())
            median = vals[len(vals) // 2]
            if median <= 0:
                return []
            return [e for e, v in self.engine_ewma.items()
                    if v > factor * median]

    # -- consistent read view --------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Deep-copied view of every health dict, taken under the
        Monitor lock.  The one sanctioned way to *read* this state from
        another thread: ``admin.status()`` renders it while the
        background MonitoringTask / StreamRuntime tick keep mutating
        the live dicts (iterating those directly races)."""
        with self._lock:
            return {
                "engine_ewma": dict(self.engine_ewma),
                "engine_ops": dict(self.engine_ops),
                "stream_ewma": dict(self.stream_ewma),
                "stream_stats": {k: dict(v)
                                 for k, v in self.stream_stats.items()},
                "stream_watermarks": {
                    k: dict(v)
                    for k, v in self.stream_watermarks.items()},
                "ingest_stats": {k: dict(v)
                                 for k, v in self.ingest_stats.items()},
                "jit_stats": dict(self.jit_stats),
                "ml_stats": dict(self.ml_stats),
                "durability_stats": {
                    k: dict(v)
                    for k, v in self.durability_stats.items()},
                "serve_stats": dict(self.serve_stats),
                "recoveries": {k: dict(v)
                               for k, v in self.recoveries.items()},
                "shard_stats": {
                    name: {i: dict(st) for i, st in shards.items()}
                    for name, shards in self.shard_stats.items()},
                "stragglers": self.stragglers(),
            }

    # -- persistence -----------------------------------------------------------
    def to_json(self) -> str:
        with self._lock:
            payload = {
                "benchmarks": {
                    key: {qid: {"durations": list(rec.durations),
                                "cost_model": rec.cost_model_seconds}
                          for qid, rec in records.items()}
                    for key, (_, records) in self._benchmarks.items()},
                "engine_ewma": dict(self.engine_ewma),
            }
        # dumps outside the lock: the payload is a deep copy, so a
        # concurrent observe_* can't mutate dicts mid-serialization
        return json.dumps(payload, indent=1)


class MonitoringTask:
    """Background daemon re-running benchmarks periodically (paper §V.E).

    Run either as a real daemon thread (``start``) or cooperatively via
    explicit ``tick`` calls (used by tests and the training loop).
    """

    def __init__(self, monitor: Monitor,
                 refresh: Callable[[], None],
                 interval_seconds: float = 30.0) -> None:
        self.monitor = monitor
        self.refresh = refresh
        self.interval = interval_seconds
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.ticks = 0

    def tick(self) -> None:
        self.refresh()
        self.ticks += 1

    def start(self) -> None:
        def loop():
            while not self._stop.wait(self.interval):
                self.tick()
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
