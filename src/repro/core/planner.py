"""The Planner (paper §V.B): coordinates all query execution.

``process_query(userinput, is_training_mode)`` parses the BQL string,
routes catalog queries to the catalog module, builds the
CrossIslandQueryPlan, enumerates semantically-equal QEPs (engine choice per
intra-island sub-query x cast route per migration), and either

  * training mode: runs the enumerated QEPs — concurrently, up to
    ``PlannerConfig.plan_parallelism`` at a time, cost-model-cancelling
    plans the Monitor already estimates as hopeless before any work runs
    and wall-clock-cancelling plans slower than the best finished one —
    records timings in the Monitor, returns the fastest result (paper's
    isTrainingMode=true), or
  * lean mode: consults the signature-keyed plan cache first (LRU +
    monitor-wired staleness eviction); on a hit the query skips plan
    enumeration entirely.  On a miss it asks the Monitor for the best QEP
    of the closest benchmarked signature and runs only that (adding this
    signature as a new benchmark if nothing matches — §V.E).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

from repro.core import bql, signatures
from repro.core.catalog import Catalog
from repro.core.engines import Engine
from repro.core.executor import (DataUnavailableException, Executor,
                                 ExecutorConfig, PlanAbortedException,
                                 QueryExecutionPlan, QueryResult,
                                 assign_ids, cast_parents)
from repro.core.migrator import Migrator
from repro.core.monitor import Monitor
from repro.core.signatures import Signature
from repro.obs import metrics, trace

MAX_ENUMERATED_PLANS = 16
CAST_METHODS = ("binary", "staged")

# unique scopes for concurrently executing training-mode plans (cast dest
# names are suffixed so plans never collide on materialized objects)
_SCOPE_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    """Planner concurrency + caching knobs (threaded through core/api.py)."""
    plan_parallelism: int = 4            # concurrent QEPs in training mode
    early_cancel: bool = True            # cancel plans slower than best
    early_cancel_margin: float = 1.5     # cancel at margin * best_seconds
    # after this many consecutive cost-model cancels a QEP runs once
    # anyway, refreshing its Monitor estimate (stale estimates must not
    # blacklist a plan forever)
    cost_cancel_reprobe: int = 4
    cache_size: int = 128                # plan-cache LRU capacity
    cache_max_age_seconds: float = 600.0  # plan-cache staleness TTL
    executor: ExecutorConfig = dataclasses.field(
        default_factory=ExecutorConfig)


@dataclasses.dataclass
class Response:
    """Query Endpoint response."""
    value: Any
    qep_id: str
    stages: List[Tuple[str, float]]
    signature_key: str
    training_mode: bool
    plans_considered: int
    wall_seconds: float = 0.0
    critical_path_seconds: float = 0.0
    plan_cache_hit: bool = False

    @property
    def seconds(self) -> float:
        return sum(s for _, s in self.stages)


@dataclasses.dataclass
class _CacheEntry:
    qep_id: str
    node_engines: Dict[int, str]
    cast_methods: Dict[int, str]
    monitor_version: int
    inserted_at: float


class PlanCache:
    """Signature-keyed LRU of trained QEPs (the lean-mode fast path).

    Eviction: LRU beyond ``max_size``; staleness via (a) a TTL on entry
    age and (b) the Monitor's per-signature version counter — when new
    measurements arrive and the Monitor's best QEP for the signature no
    longer matches the cached one, the entry is dropped.
    """

    def __init__(self, monitor: Monitor, max_size: int = 128,
                 max_age_seconds: float = 600.0) -> None:
        self.monitor = monitor
        self.max_size = max(1, max_size)
        self.max_age_seconds = max_age_seconds
        self._entries: "collections.OrderedDict[str, Tuple[Signature, _CacheEntry]]" \
            = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_evictions = 0

    def get(self, sig: Signature) -> Optional[_CacheEntry]:
        key = sig.key()
        with self._lock:
            item = self._entries.get(key)
            if item is None:
                self.misses += 1
                return None
            _, entry = item
            if (time.monotonic() - entry.inserted_at
                    > self.max_age_seconds):
                del self._entries[key]
                self.stale_evictions += 1
                self.misses += 1
                return None
            version = self.monitor.signature_version(sig)
            if version != entry.monitor_version:
                # new measurements landed; keep the entry only if it is
                # still the Monitor's best plan for this signature
                best = self.monitor.best_qep(sig)
                if best is not None and best != entry.qep_id:
                    del self._entries[key]
                    self.stale_evictions += 1
                    self.misses += 1
                    return None
                entry.monitor_version = version
            self._entries.move_to_end(key)
            self.hits += 1
            return entry

    def put(self, sig: Signature, plan: QueryExecutionPlan) -> None:
        key = sig.key()
        with self._lock:
            self._entries[key] = (sig, _CacheEntry(
                qep_id=plan.qep_id,
                node_engines=dict(plan.node_engines),
                cast_methods=dict(plan.cast_methods),
                monitor_version=self.monitor.signature_version(sig),
                inserted_at=time.monotonic()))
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_size:
                self._entries.popitem(last=False)
                self.evictions += 1

    def invalidate(self, sig: Signature) -> None:
        with self._lock:
            if self._entries.pop(sig.key(), None) is not None:
                self.evictions += 1

    def refresh_version(self, sig: Signature) -> None:
        """Resync the stored Monitor version after the caller records its
        own measurement for a hit — otherwise every hit's measurement
        bump would force a full best_qep scan on the next lookup."""
        with self._lock:
            item = self._entries.get(sig.key())
            if item is not None:
                item[1].monitor_version = \
                    self.monitor.signature_version(sig)

    def evict_stale(self) -> int:
        """Drop aged/superseded entries (called from the MonitoringTask
        refresh loop so background re-benchmarks invalidate stale plans)."""
        dropped = 0
        with self._lock:
            now = time.monotonic()
            for key in list(self._entries):
                sig, entry = self._entries[key]
                aged = now - entry.inserted_at > self.max_age_seconds
                best = self.monitor.best_qep(sig)
                superseded = best is not None and best != entry.qep_id
                if aged or superseded:
                    del self._entries[key]
                    self.stale_evictions += 1
                    dropped += 1
        return dropped

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"size": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions,
                    "stale_evictions": self.stale_evictions}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class Planner:
    def __init__(self, catalog: Catalog, engines: Dict[str, Engine],
                 monitor: Monitor, migrator: Migrator,
                 config: Optional[PlannerConfig] = None) -> None:
        self.catalog = catalog
        self.engines = engines
        self.monitor = monitor
        self.migrator = migrator
        self.config = config or PlannerConfig()
        self.executor = Executor(engines, migrator, monitor,
                                 config=self.config.executor)
        self.plan_cache = PlanCache(
            monitor, max_size=self.config.cache_size,
            max_age_seconds=self.config.cache_max_age_seconds)
        # QEPs cancelled by the Monitor cost model before any work ran,
        # and per-(signature, qep) consecutive-cancel streaks driving the
        # periodic re-probe (see PlannerConfig.cost_cancel_reprobe)
        self.cost_model_cancels = 0
        self._cancel_streaks: Dict[Tuple[str, str], int] = {}

    # -- plan enumeration -----------------------------------------------------
    def _candidate_engines(self, node: bql.IslandQueryNode) -> List[str]:
        members = [e.name for e in
                   self.catalog.engines_for_island(node.island)]
        members = [m for m in members if m in self.engines]
        # restrict to engines holding the referenced base objects
        cast_names = {c.dest_name for c in node.casts}
        refs = [o for o in signatures._referenced_objects(node)
                if o not in cast_names]
        if refs:
            holding = [m for m in members
                       if all(self.engines[m].has(r) for r in refs)]
            if holding:
                members = holding
        if node.island == "streaming" and len(members) > 1 and refs:
            # a ShardedStream handle lives on every participating
            # StreamEngine, so all placements of a gather read are
            # semantically identical — pin to the referenced handles'
            # home engines instead of enumerating one plan per engine.
            # A single-stream read pins to one engine; a cross-stream
            # join pins to both handles' homes (the only placements
            # where one side's gather is engine-local), so enumeration
            # stays O(streams), not O(engines)
            homes = set()
            for r in refs:
                holder = next((m for m in members
                               if self.engines[m].has(r)), None)
                home = getattr(self.engines[holder].get(r),
                               "home_engine", None) if holder else None
                if home is None:
                    homes = None
                    break
                homes.add(home)
            if homes:
                pinned = [m for m in members if m in homes]
                if pinned:
                    members = pinned
        if node.island == "ml" and refs:
            # an infer node references both the model handle (held only
            # by MLEngines) and the stream it scores (held only by
            # StreamEngines), so the generic all-refs filter above never
            # narrows it — pin to the ml engines holding ANY referenced
            # object, i.e. the model's home, instead of enumerating one
            # plan per island member
            holding = [m for m in members
                       if any(self.engines[m].has(r) for r in refs)]
            if holding:
                members = holding
        # straggler avoidance (Monitor feedback loop, DESIGN.md §5)
        slow = set(self.monitor.stragglers())
        fast = [m for m in members if m not in slow]
        return fast or members

    def _cast_candidates(self, src_engine: str, dst_engine: str
                         ) -> List[str]:
        src = self.catalog.engine_by_name(src_engine)
        dst = self.catalog.engine_by_name(dst_engine)
        if src and dst:
            casts = self.catalog.casts_between(src.eid, dst.eid)
            if casts:
                return [c.method for c in casts]
        return list(CAST_METHODS)

    def enumerate_plans(self, root: bql.IslandQueryNode
                        ) -> List[QueryExecutionPlan]:
        nodes, casts = assign_ids(root)
        node_ids = list(nodes)
        engine_options = [self._candidate_engines(nodes[nid])
                          for nid in node_ids]
        for nid, opts in zip(node_ids, engine_options):
            if not opts:
                raise ValueError(
                    f"no engine serves island {nodes[nid].island!r} "
                    f"with the referenced objects")
        plans: List[QueryExecutionPlan] = []
        parent_by_id = cast_parents(nodes)
        child_of_cast = {}
        parent_of_cast = {}
        for cid, cast in casts.items():
            child_of_cast[cid] = next(
                nid for nid, n in nodes.items() if n is cast.child)
            parent_of_cast[cid] = parent_by_id[id(cast)]
        for combo in itertools.product(*engine_options):
            node_engines = dict(zip(node_ids, combo))
            cast_options = []
            for cid in casts:
                cast_options.append(self._cast_candidates(
                    node_engines[child_of_cast[cid]],
                    node_engines[parent_of_cast[cid]]))
            for cast_combo in itertools.product(*cast_options):
                plans.append(QueryExecutionPlan(
                    root=root, node_engines=node_engines,
                    cast_methods=dict(zip(casts, cast_combo))))
                if len(plans) >= MAX_ENUMERATED_PLANS:
                    return plans
        return plans

    # -- training mode: concurrent QEP exploration ----------------------------
    def _explore_plans(self, sig: Signature,
                       plans: List[QueryExecutionPlan]
                       ) -> List[Tuple[QueryExecutionPlan, QueryResult]]:
        """Run enumerated QEPs with a bounded parallelism budget.

        Two early-cancel tiers (both under ``PlannerConfig.early_cancel``):

        * cost-model cancel — before anything runs, plans whose
          Monitor-estimated serial-sum (measured mean, else AOT cost
          model, else the closest benchmarked signature's record) already
          exceeds ``early_cancel_margin`` x the best *estimate* are
          dropped outright; plans the Monitor has no history for always
          run, so new QEPs still get measured, and after
          ``cost_cancel_reprobe`` consecutive cancels a plan runs once
          anyway so a stale estimate can't blacklist it forever;
        * wall-clock cancel — the fallback when an estimate is *wrong*:
          a running plan whose elapsed wall time exceeds the margin x
          the best finished plan's serial-sum is cancelled before its
          next task starts (partial work discarded, nothing recorded).
          Plans the Monitor has never estimated — and streak re-probes —
          are exempt: they run precisely to be measured once, after
          which the cost-model tier excludes them cheaply; aborting them
          would re-run and re-abort them on every training query without
          ever recording the estimate that ends the cycle.
        """
        cfg = self.config
        # exploration runs are exempt from the wall-clock cancel below:
        # a plan being re-probed after a cancel streak, or one the Monitor
        # has never estimated, runs precisely to *record* a measurement —
        # aborting it would starve the estimate forever (the plan gets
        # re-run and re-aborted on every training query instead of being
        # measured once and cost-model-cancelled from then on)
        measure_exempt = set()
        if cfg.early_cancel and len(plans) > 1:
            estimates = {p.qep_id: self.monitor.estimate_seconds(
                sig, p.qep_id) for p in plans}
            measure_exempt.update(qid for qid, est in estimates.items()
                             if est == float("inf"))
            finite = [v for v in estimates.values() if v < float("inf")]
            if finite:
                cutoff = cfg.early_cancel_margin * min(finite)
                best_plan = min(plans,
                                key=lambda p: estimates[p.qep_id])
                keep = []
                for p in plans:
                    est = estimates[p.qep_id]
                    streak_key = (sig.key(), p.qep_id)
                    if (p is best_plan or est == float("inf")
                            or est <= cutoff):
                        keep.append(p)
                        self._cancel_streaks.pop(streak_key, None)
                        continue
                    streak = self._cancel_streaks.get(streak_key, 0) + 1
                    if streak > cfg.cost_cancel_reprobe:
                        # re-probe: run it once so the estimate refreshes
                        keep.append(p)
                        measure_exempt.add(p.qep_id)
                        self._cancel_streaks.pop(streak_key, None)
                    else:
                        self._cancel_streaks[streak_key] = streak
                        self.cost_model_cancels += 1
                        metrics.counter(
                            "repro_plan_cancels_total",
                            "training-mode plans cancelled early",
                            tier="cost_model").inc()
                plans = keep
        budget = max(1, cfg.plan_parallelism)
        best_lock = threading.Lock()
        best_seconds = [float("inf")]

        def run_one(plan: QueryExecutionPlan
                    ) -> Optional[Tuple[QueryExecutionPlan, QueryResult]]:
            start = time.perf_counter()

            def should_abort() -> bool:
                if not cfg.early_cancel or plan.qep_id in measure_exempt:
                    return False
                with best_lock:
                    best = best_seconds[0]
                return (best < float("inf")
                        and time.perf_counter() - start
                        > cfg.early_cancel_margin * best)

            scope = f"qep{next(_SCOPE_IDS)}" if budget > 1 else ""
            try:
                res = self.executor.execute_plan(
                    plan, should_abort=should_abort, scope=scope)
            except PlanAbortedException:
                metrics.counter("repro_plan_cancels_total",
                                "training-mode plans cancelled early",
                                tier="wall_clock").inc()
                return None
            self.monitor.add_measurement(sig, plan.qep_id, res.seconds)
            with best_lock:
                best_seconds[0] = min(best_seconds[0], res.seconds)
            return plan, res

        if budget == 1 or len(plans) == 1:
            outcomes = [run_one(p) for p in plans]
        else:
            with ThreadPoolExecutor(max_workers=budget) as pool:
                # exploration workers inherit the planner span, so the
                # per-QEP executor spans parent-link across the pool
                outcomes = list(pool.map(trace.bind(run_one), plans))
        # cancellation requires a finite best_seconds, i.e. at least one
        # finished plan — so `finished` is never empty
        return [o for o in outcomes if o is not None]

    # -- entry point (paper's Planner.processQuery) ----------------------------
    def process_query(self, userinput: str,
                      is_training_mode: bool = False) -> Response:
        mode = "training" if is_training_mode else "lean"
        t_query = time.perf_counter()
        with trace.span("planner/query", mode=mode) as sp:
            response = self._process_query(userinput, is_training_mode)
            sp.set(qep=response.qep_id,
                   cache_hit=response.plan_cache_hit)
        metrics.histogram("repro_query_seconds",
                          "end-to-end process_query latency",
                          mode=mode).observe(
            time.perf_counter() - t_query)
        metrics.counter("repro_queries_total",
                        "queries processed", mode=mode).inc()
        cache = self.plan_cache.stats()
        metrics.gauge("repro_plan_cache_size",
                      "signature-keyed plan cache entries"
                      ).set(cache["size"])
        for key in ("hits", "misses", "evictions", "stale_evictions"):
            metrics.counter(f"repro_plan_cache_{key}_total",
                            f"plan cache {key}").set_total(cache[key])
        return response

    def _process_query(self, userinput: str,
                       is_training_mode: bool = False) -> Response:
        t0 = time.perf_counter()
        root = bql.parse(userinput)
        parse_s = time.perf_counter() - t0

        if isinstance(root, bql.CatalogQueryNode):
            t1 = time.perf_counter()
            rows = self.catalog.query(root.query)
            return Response(
                value=rows, qep_id="catalog",
                stages=[("Parse", parse_s),
                        ("Catalog query", time.perf_counter() - t1)],
                signature_key="catalog", training_mode=is_training_mode,
                plans_considered=1)

        sig = signatures.of_query(root)

        # lean mode: the signature-keyed plan cache skips enumeration
        if not is_training_mode:
            t1 = time.perf_counter()
            cached = self.plan_cache.get(sig)
            cache_s = time.perf_counter() - t1
            if cached is not None:
                plan = QueryExecutionPlan(
                    root=root, node_engines=dict(cached.node_engines),
                    cast_methods=dict(cached.cast_methods))
                nodes, _ = assign_ids(root)
                if set(plan.node_engines) == set(nodes):
                    try:
                        res = self.executor.execute_plan(plan)
                    except Exception as exc:              # noqa: BLE001
                        if isinstance(
                                exc, DataUnavailableException
                        ) or isinstance(exc.__cause__,
                                        DataUnavailableException):
                            # transient data-dependent island error (e.g.
                            # a window not complete yet): the cached plan
                            # is still the right one — surface the error
                            # without paying a re-enumeration next tick
                            raise
                        # cached plan no longer executable (object moved,
                        # engine dropped) — evict and fall through
                        self.plan_cache.invalidate(sig)
                    else:
                        self.monitor.add_measurement(sig, plan.qep_id,
                                                     res.seconds)
                        self.plan_cache.refresh_version(sig)
                        return Response(
                            value=res.value, qep_id=plan.qep_id,
                            stages=[("Parse", parse_s),
                                    ("Plan cache hit", cache_s)]
                            + res.stages,
                            signature_key=sig.key(), training_mode=False,
                            plans_considered=1,
                            wall_seconds=res.wall_seconds,
                            critical_path_seconds=res.critical_path_seconds,
                            plan_cache_hit=True)
                else:
                    self.plan_cache.invalidate(sig)

        t1 = time.perf_counter()
        plans = self.enumerate_plans(root)
        plan_s = time.perf_counter() - t1

        if is_training_mode:
            finished = self._explore_plans(sig, plans)
            best_plan, best = min(finished, key=lambda pr: pr[1].seconds)
            self.plan_cache.put(sig, best_plan)
            return Response(
                value=best.value, qep_id=best.qep_id,
                stages=[("Parse", parse_s),
                        ("Plan enumeration", plan_s)] + best.stages,
                signature_key=sig.key(), training_mode=True,
                plans_considered=len(plans),
                wall_seconds=best.wall_seconds,
                critical_path_seconds=best.critical_path_seconds)

        # lean-mode cache miss: consult the Monitor
        t2 = time.perf_counter()
        best_qid = self.monitor.best_qep(sig)
        chosen = next((p for p in plans if p.qep_id == best_qid), plans[0])
        monitor_s = time.perf_counter() - t2
        res = self.executor.execute_plan(chosen)
        self.monitor.add_measurement(sig, chosen.qep_id, res.seconds)
        self.plan_cache.put(sig, chosen)
        return Response(
            value=res.value, qep_id=chosen.qep_id,
            stages=[("Parse", parse_s), ("Plan enumeration", plan_s),
                    ("Monitor lookup", monitor_s)] + res.stages,
            signature_key=sig.key(), training_mode=False,
            plans_considered=len(plans),
            wall_seconds=res.wall_seconds,
            critical_path_seconds=res.critical_path_seconds)
