"""The Planner (paper §V.B): coordinates all query execution.

``process_query(userinput, is_training_mode)`` parses the BQL string,
routes catalog queries to the catalog module, builds the
CrossIslandQueryPlan, enumerates semantically-equal QEPs (engine choice per
intra-island sub-query x cast route per migration), and either

  * training mode: runs every enumerated QEP, records timings in the
    Monitor, returns the fastest result (paper's isTrainingMode=true), or
  * lean mode: asks the Monitor for the best QEP of the closest benchmarked
    signature and runs only that (adding this signature as a new benchmark
    if nothing matches — §V.E).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import bql, signatures
from repro.core.catalog import Catalog
from repro.core.engines import Engine
from repro.core.executor import (Executor, QueryExecutionPlan, QueryResult,
                                 assign_ids)
from repro.core.migrator import Migrator
from repro.core.monitor import Monitor

MAX_ENUMERATED_PLANS = 16
CAST_METHODS = ("binary", "staged")


@dataclasses.dataclass
class Response:
    """Query Endpoint response."""
    value: Any
    qep_id: str
    stages: List[Tuple[str, float]]
    signature_key: str
    training_mode: bool
    plans_considered: int

    @property
    def seconds(self) -> float:
        return sum(s for _, s in self.stages)


class Planner:
    def __init__(self, catalog: Catalog, engines: Dict[str, Engine],
                 monitor: Monitor, migrator: Migrator) -> None:
        self.catalog = catalog
        self.engines = engines
        self.monitor = monitor
        self.migrator = migrator
        self.executor = Executor(engines, migrator, monitor)

    # -- plan enumeration -----------------------------------------------------
    def _candidate_engines(self, node: bql.IslandQueryNode) -> List[str]:
        members = [e.name for e in
                   self.catalog.engines_for_island(node.island)]
        members = [m for m in members if m in self.engines]
        # restrict to engines holding the referenced base objects
        cast_names = {c.dest_name for c in node.casts}
        refs = [o for o in signatures._referenced_objects(node)
                if o not in cast_names]
        if refs:
            holding = [m for m in members
                       if all(self.engines[m].has(r) for r in refs)]
            if holding:
                members = holding
        # straggler avoidance (Monitor feedback loop, DESIGN.md §5)
        slow = set(self.monitor.stragglers())
        fast = [m for m in members if m not in slow]
        return fast or members

    def _cast_candidates(self, src_engine: str, dst_engine: str
                         ) -> List[str]:
        src = self.catalog.engine_by_name(src_engine)
        dst = self.catalog.engine_by_name(dst_engine)
        if src and dst:
            casts = self.catalog.casts_between(src.eid, dst.eid)
            if casts:
                return [c.method for c in casts]
        return list(CAST_METHODS)

    def enumerate_plans(self, root: bql.IslandQueryNode
                        ) -> List[QueryExecutionPlan]:
        nodes, casts = assign_ids(root)
        node_ids = list(nodes)
        engine_options = [self._candidate_engines(nodes[nid])
                          for nid in node_ids]
        for nid, opts in zip(node_ids, engine_options):
            if not opts:
                raise ValueError(
                    f"no engine serves island {nodes[nid].island!r} "
                    f"with the referenced objects")
        plans: List[QueryExecutionPlan] = []
        child_of_cast = {}
        parent_of_cast = {}
        for cid, cast in casts.items():
            child_of_cast[cid] = next(
                nid for nid, n in nodes.items() if n is cast.child)
            parent_of_cast[cid] = next(
                nid for nid, n in nodes.items() if cast in n.casts)
        for combo in itertools.product(*engine_options):
            node_engines = dict(zip(node_ids, combo))
            cast_options = []
            for cid in casts:
                cast_options.append(self._cast_candidates(
                    node_engines[child_of_cast[cid]],
                    node_engines[parent_of_cast[cid]]))
            for cast_combo in itertools.product(*cast_options):
                plans.append(QueryExecutionPlan(
                    root=root, node_engines=node_engines,
                    cast_methods=dict(zip(casts, cast_combo))))
                if len(plans) >= MAX_ENUMERATED_PLANS:
                    return plans
        return plans

    # -- entry point (paper's Planner.processQuery) ----------------------------
    def process_query(self, userinput: str,
                      is_training_mode: bool = False) -> Response:
        t0 = time.perf_counter()
        root = bql.parse(userinput)
        parse_s = time.perf_counter() - t0

        if isinstance(root, bql.CatalogQueryNode):
            t1 = time.perf_counter()
            rows = self.catalog.query(root.query)
            return Response(
                value=rows, qep_id="catalog",
                stages=[("Parse", parse_s),
                        ("Catalog query", time.perf_counter() - t1)],
                signature_key="catalog", training_mode=is_training_mode,
                plans_considered=1)

        sig = signatures.of_query(root)
        t1 = time.perf_counter()
        plans = self.enumerate_plans(root)
        plan_s = time.perf_counter() - t1

        if is_training_mode:
            results = []
            for plan in plans:
                res = self.executor.execute_plan(plan)
                self.monitor.add_measurement(sig, plan.qep_id, res.seconds)
                results.append(res)
            best = min(results, key=lambda r: r.seconds)
            return Response(
                value=best.value, qep_id=best.qep_id,
                stages=[("Parse", parse_s),
                        ("Plan enumeration", plan_s)] + best.stages,
                signature_key=sig.key(), training_mode=True,
                plans_considered=len(plans))

        # lean mode: consult the Monitor
        t2 = time.perf_counter()
        best_qid = self.monitor.best_qep(sig)
        chosen = next((p for p in plans if p.qep_id == best_qid), plans[0])
        monitor_s = time.perf_counter() - t2
        res = self.executor.execute_plan(chosen)
        self.monitor.add_measurement(sig, chosen.qep_id, res.seconds)
        return Response(
            value=res.value, qep_id=chosen.qep_id,
            stages=[("Parse", parse_s), ("Plan enumeration", plan_s),
                    ("Monitor lookup", monitor_s)] + res.stages,
            signature_key=sig.key(), training_mode=False,
            plans_considered=len(plans))
