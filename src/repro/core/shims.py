"""Shims (paper §III): translate island-level queries into engine-native
execution.  One shim per (island, engine-kind); since every engine here
speaks the island's data model natively after ``coerce``, the shim's job is
to *parse and execute* the island language over the engine's stored objects:

  relational island — SQL subset (SELECT/WHERE/JOIN/GROUP BY/ORDER BY/LIMIT)
  array island      — AFL subset (scan/filter/project/aggregate/cross_join/
                      redimension/sort)
  text island       — JSON op spec ({'op': 'scan'|'range', 'table': ...})
  streaming island  — functional ops over ring-buffer streams (append/
                      window/aggregate/rate/snapshot), repro.stream.shim
"""
from __future__ import annotations

import json
import re
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm
from repro.core.engines import Engine

_OPS = {
    ">=": lambda a, b: a >= b, "<=": lambda a, b: a <= b,
    "!=": lambda a, b: a != b, "=": lambda a, b: a == b,
    ">": lambda a, b: a > b, "<": lambda a, b: a < b,
}


def _parse_value(tok: str):
    tok = tok.strip().strip("'\"")
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            return tok


# ---------------------------------------------------------------------------
# Relational island: SQL subset
# ---------------------------------------------------------------------------
_SQL_RE = re.compile(
    r"^\s*select\s+(?P<distinct>distinct\s+)?(?P<cols>.+?)\s+from\s+"
    r"(?P<from>.+?)"
    r"(?:\s+where\s+(?P<where>.+?))?"
    r"(?:\s+group\s+by\s+(?P<group>[\w\.]+))?"
    r"(?:\s+order\s+by\s+(?P<order>[\w\.]+)(?:\s+(?P<dir>asc|desc))?)?"
    r"(?:\s+limit\s+(?P<limit>\d+))?\s*;?\s*$",
    re.IGNORECASE | re.DOTALL)

_AGG_RE = re.compile(r"^(count|sum|avg|min|max)\(\s*(\*|[\w\.]+)\s*\)$",
                     re.IGNORECASE)


def _strip_prefix(col: str, table: dm.Table) -> str:
    if col in table.columns:
        return col
    if "." in col:
        tail = col.split(".")[-1]
        if tail in table.columns:
            return tail
    # qualified names like mimic2v26.d_patients.sex
    for c in table.columns:
        if col.endswith("." + c) or c.endswith("." + col):
            return c
    return col


def execute_relational(engine: Engine, sql: str) -> dm.Table:
    m = _SQL_RE.match(sql)
    if not m:
        raise ValueError(f"unsupported relational query: {sql!r}")

    # FROM: one table, or comma-separated pair (implicit join via WHERE)
    from_items = [t.strip() for t in m.group("from").split(",")]
    names, aliases = [], {}
    for item in from_items:
        parts = re.split(r"\s+as\s+|\s+", item.strip(), flags=re.IGNORECASE)
        names.append(parts[0])
        if len(parts) > 1:
            aliases[parts[-1]] = parts[0]
    table = engine.get(names[0])

    where = m.group("where")
    join_cond: Optional[Tuple[str, str]] = None
    filters: List[Tuple[str, str, Any]] = []
    if where:
        for clause in re.split(r"\s+and\s+", where, flags=re.IGNORECASE):
            clause = clause.strip()
            for op in ("<=", ">=", "!=", "=", "<", ">"):
                if op in clause:
                    lhs, rhs = clause.split(op, 1)
                    lhs, rhs = lhs.strip(), rhs.strip()
                    rhs_val = _parse_value(rhs)
                    if (len(names) > 1 and isinstance(rhs_val, str)
                            and re.match(r"^[\w\.]+$", rhs)):
                        join_cond = (lhs, rhs)
                    else:
                        filters.append((lhs, op, rhs_val))
                    break

    if len(names) > 1:
        right = engine.get(names[1])
        if join_cond is None:
            raise ValueError("two-table FROM requires a join predicate")
        lcol = _strip_prefix(join_cond[0], table)
        rcol = _strip_prefix(join_cond[1], right)
        if lcol not in table.columns:
            lcol, rcol = rcol, lcol
        table = table.join(right, lcol, rcol)

    for col, op, val in filters:
        c = _strip_prefix(col, table)
        mask = _OPS[op](table.columns[c], val)
        table = table.filter(mask)

    group = m.group("group")
    cols_spec = [c.strip() for c in _split_cols(m.group("cols"))]
    if group:
        gcol = _strip_prefix(group, table)
        for c in cols_spec:
            agg = _AGG_RE.match(c)
            if agg:
                fn, target = agg.group(1).lower(), agg.group(2)
                target = gcol if target == "*" else _strip_prefix(target,
                                                                  table)
                table = table.group_agg(gcol, fn, target)
                break
    elif len(cols_spec) == 1 and _AGG_RE.match(cols_spec[0]):
        agg = _AGG_RE.match(cols_spec[0])
        fn, target = agg.group(1).lower(), agg.group(2)
        if target == "*":
            target = table.fields[0]
        else:
            target = _strip_prefix(target, table)
        v = table.columns[target]
        out = {"count": lambda: jnp.asarray([v.shape[0]]),
               "sum": lambda: v.sum()[None],
               "avg": lambda: v.mean()[None],
               "min": lambda: v.min()[None],
               "max": lambda: v.max()[None]}[fn]()
        table = dm.Table({f"{fn}_{target}": out})
    elif cols_spec != ["*"]:
        table = table.project([_strip_prefix(c, table) for c in cols_spec])

    order = m.group("order")
    if order:
        table = table.sort_by(_strip_prefix(order, table),
                              descending=(m.group("dir") or "").lower()
                              == "desc")
    if m.group("distinct"):
        # distinct over the first column (sufficient for the subset)
        first = table.fields[0]
        _, idx = np.unique(np.asarray(table.columns[first]),
                           return_index=True)
        table = dm.Table({n: v[jnp.asarray(np.sort(idx))]
                          for n, v in table.columns.items()})
    limit = m.group("limit")
    if limit:
        table = table.limit(int(limit))
    return table


def _split_cols(spec: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in spec:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts]


# ---------------------------------------------------------------------------
# Array island: AFL subset
# ---------------------------------------------------------------------------
def execute_afl(engine: Engine, afl: str) -> dm.ArrayObject:
    afl = afl.strip()
    m = re.match(r"^(\w+)\s*\(", afl)
    if not m:
        # bare array name
        return engine.get(afl)
    fn = m.group(1).lower()
    body = afl[m.end() - 1:]
    inner, _ = _balanced(body)
    args = _split_args(inner)

    if fn == "scan":
        return execute_afl(engine, args[0])
    if fn == "filter":
        arr = execute_afl(engine, args[0])
        return arr.filter(lambda a: _afl_condition(a, args[1]))
    if fn == "project":
        arr = execute_afl(engine, args[0])
        return arr.project([a.strip() for a in args[1:]])
    if fn == "aggregate":
        arr = execute_afl(engine, args[0])
        agg = _AGG_RE.match(args[1].strip())
        if not agg:
            raise ValueError(f"bad aggregate: {args[1]!r}")
        target = agg.group(2)
        if target == "*":
            target = next(iter(arr.attrs))
        return arr.aggregate(agg.group(1).lower(), target)
    if fn == "cross_join":
        a = execute_afl(engine, args[0])
        b = execute_afl(engine, args[1])
        return a.cross_join(b)
    if fn == "redimension":
        arr = execute_afl(engine, args[0])
        shape, dims = _parse_scidb_schema(args[1])
        total = int(np.prod(arr.shape))
        want = int(np.prod(shape))
        assert total == want, f"redimension {arr.shape} -> {shape}"
        return arr.redimension(tuple(shape), tuple(dims))
    if fn == "sort":
        arr = execute_afl(engine, args[0])
        attr = args[1].strip() if len(args) > 1 else next(iter(arr.attrs))
        return arr.sort(attr)
    raise ValueError(f"unsupported AFL operator: {fn}")


def _balanced(s: str) -> Tuple[str, int]:
    depth = 0
    for j, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return s[1:j], j + 1
    raise ValueError(f"unbalanced AFL: {s!r}")


def _split_args(s: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([<{":
            depth += 1
        elif ch in ")]>}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _afl_condition(arr: dm.ArrayObject, cond: str):
    for op in ("<=", ">=", "!=", "=", "<", ">"):
        if op in cond:
            lhs, rhs = cond.split(op, 1)
            lhs = lhs.strip()
            val = _parse_value(rhs)
            if lhs in arr.attrs:
                field = arr.attrs[lhs]
            elif lhs in arr.dim_names:
                field = arr.dim_grid(lhs)
            else:
                raise ValueError(f"unknown attr/dim {lhs!r}")
            return _OPS[op](field, val)
    raise ValueError(f"bad AFL condition: {cond!r}")


def _parse_scidb_schema(schema: str) -> Tuple[List[int], List[str]]:
    """'<a:int32>[i=0:99,100,0, j=0:9,10,0]' -> ([100, 10], ['i','j']).

    Comma-separated parts without '=' are the SciDB chunk size / overlap of
    the preceding dimension and are ignored for shape purposes.
    """
    dims_part = schema[schema.index("["):].strip("[] \t\n")
    shape, names = [], []
    for d in _split_args(dims_part):
        d = d.strip()
        if "=" not in d:
            continue                      # chunk size / overlap
        m = re.match(r"^(\w+)\s*=\s*(-?\d+):(\*|-?\d+)", d)
        if not m:
            raise ValueError(f"bad dim spec {d!r}")
        names.append(m.group(1))
        lo = int(m.group(2))
        hi = m.group(3)
        if hi == "*":
            shape.append(-1)
        else:
            shape.append(int(hi) - lo + 1)
    return shape, names


# ---------------------------------------------------------------------------
# Text island: JSON op spec
# ---------------------------------------------------------------------------
def execute_text(engine: Engine, spec: str):
    payload = json.loads(spec.replace("'", '"'))
    table: dm.KVTable = engine.get(payload["table"])
    op = payload["op"]
    if op == "scan":
        return table.scan()
    if op == "range":
        rng = payload["range"]
        return table.range(tuple(rng["start"]), tuple(rng["end"]))
    raise ValueError(f"unsupported text op: {op}")


def execute(island: str, engine: Engine, query: str):
    if island == "relational":
        return execute_relational(engine, query)
    if island == "array":
        return execute_afl(engine, query)
    if island == "text":
        return execute_text(engine, query)
    if island == "streaming":
        from repro.stream.shim import execute_stream
        return execute_stream(engine, query)
    if island == "ml":
        from repro.stream.ml import execute_ml
        return execute_ml(engine, query)
    raise ValueError(f"unknown island {island}")
