"""Query signatures (paper §V.E): a structural fingerprint of a BQL query
used by the Monitor to match new queries against benchmarked ones
(``getClosestSignature``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

import numpy as np

from repro.core import bql

_OP_WORDS = ("select", "filter", "join", "cross_join", "project", "aggregate",
             "redimension", "sort", "scan", "range", "group", "order",
             "limit", "count", "sum", "avg", "min", "max", "where",
             "distinct",
             # streaming island (repro.stream.shim)
             "append", "window", "rate", "snapshot",
             # event-time streaming ops (watermarked windows + joins)
             "ewindow", "watermark", "flush")


@dataclasses.dataclass(frozen=True)
class Signature:
    islands: Tuple[str, ...]            # islands touched (sorted, with dups)
    ops: Tuple[Tuple[str, int], ...]    # (op keyword, count), sorted
    objects: Tuple[str, ...]            # referenced object names (sorted)
    num_casts: int
    depth: int

    def key(self) -> str:
        return (f"{'/'.join(self.islands)}|"
                f"{','.join(f'{o}:{c}' for o, c in self.ops)}|"
                f"{','.join(self.objects)}|c{self.num_casts}|d{self.depth}")

    def features(self) -> np.ndarray:
        vec = np.zeros(len(_OP_WORDS) + 3, dtype=np.float64)
        counts = dict(self.ops)
        for i, w in enumerate(_OP_WORDS):
            vec[i] = counts.get(w, 0)
        vec[-3] = len(self.islands)
        vec[-2] = self.num_casts
        vec[-1] = self.depth
        return vec

    def distance(self, other: "Signature") -> float:
        d = float(np.linalg.norm(self.features() - other.features()))
        # object overlap matters: disjoint tables are a weaker match
        a, b = set(self.objects), set(other.objects)
        union = a | b
        jaccard = (len(a & b) / len(union)) if union else 1.0
        return d + 4.0 * (1.0 - jaccard)


def _island_ops(node: bql.IslandQueryNode) -> Dict[str, int]:
    text = node.query.lower()
    counts: Dict[str, int] = {}
    for w in _OP_WORDS:
        n = len(re.findall(rf"\b{w}\b", text))
        if n:
            counts[w] = n
    return counts


_NAME_RE = re.compile(r"\b([a-zA-Z_][\w\.]*)\b")
_KEYWORDS = set(_OP_WORDS) | {
    "from", "as", "by", "asc", "desc", "and", "or", "op", "table", "start",
    "end", "true", "false",
    # join kwargs (join(W1, W2, on=ts, tol=0.5)) are not object refs
    "on", "tol"}


def _referenced_objects(node: bql.IslandQueryNode, engines_have=None
                        ) -> Tuple[str, ...]:
    cast_names = {c.dest_name for c in node.casts}
    names = set()
    for m in _NAME_RE.finditer(node.query):
        tok = m.group(1)
        if tok.lower() in _KEYWORDS or tok in cast_names:
            continue
        if "." in tok or (engines_have and engines_have(tok)):
            names.add(tok)
    return tuple(sorted(names))


def of_query(root) -> Signature:
    """Build a signature from a parsed BQL plan tree."""
    if isinstance(root, bql.CatalogQueryNode):
        return Signature(("catalog",), (("select", 1),), (), 0, 1)
    islands, objects = [], set()
    ops: Dict[str, int] = {}
    num_casts, depth = 0, 0

    def visit(node: bql.IslandQueryNode, d: int):
        nonlocal num_casts, depth
        depth = max(depth, d)
        islands.append(node.island)
        for k, v in _island_ops(node).items():
            ops[k] = ops.get(k, 0) + v
        objects.update(_referenced_objects(node))
        for cast in node.casts:
            num_casts += 1
            visit(cast.child, d + 1)

    visit(root, 1)
    return Signature(tuple(sorted(islands)),
                     tuple(sorted(ops.items())),
                     tuple(sorted(objects)), num_casts, depth)
