"""TensorPolystore: model state as first-class polystore objects.

Parameters, optimizer moments and KV caches are registered in the Catalog
and physically stored in the engine the placement policy names:

  params       -> DenseHBM  (bf16/f32 sharded arrays; the SciDB analog)
  opt moments  -> DenseHBM ("resident") | HostStore ("offload")
                  | KVStore int8 ("compressed", via the quant cast)
  KV cache     -> KVStore   (paged; bf16 or int8 pages)

Movement between engines always goes through the Migrator — the training
loop never touches placement directly, which is the polystore's location
independence applied to training state (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import BigDawg
from repro.core.migrator import MigrationParams


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    params_engine: str = "densehbm0"
    moments: str = "resident"          # resident | offload | compressed
    kv_codec: str = "raw"              # raw | int8


class TensorPolystore:
    def __init__(self, bd: BigDawg,
                 policy: Optional[PlacementPolicy] = None) -> None:
        self.bd = bd
        self.policy = policy or PlacementPolicy()

    # -- placement -------------------------------------------------------------
    def _moment_engine(self) -> str:
        return {"resident": "densehbm0", "offload": "hoststore0",
                "compressed": "kvstore0"}[self.policy.moments]

    def register_train_state(self, arch: str, state: Dict[str, Any]) -> None:
        dense = self.bd.engines[self.policy.params_engine]
        self.bd.register_object(self.policy.params_engine,
                                f"{arch}/params", state["params"])
        moment_engine = self._moment_engine()
        for key in ("m", "v"):
            obj_name = f"{arch}/opt/{key}"
            if self.policy.moments == "compressed":
                dense.put("__stage", state["opt"][key])
                self.bd.migrator.migrate(
                    dense, "__stage", self.bd.engines[moment_engine],
                    obj_name, MigrationParams(method="quant"))
                dense.delete("__stage")
                row = self.bd.catalog.engine_by_name(moment_engine)
                db = next(d for d in self.bd.catalog.databases.values()
                          if d.engine_id == row.eid)
                self.bd.catalog.add_object(obj_name, (), db.dbid, db.dbid)
            else:
                obj = state["opt"][key]
                if self.policy.moments == "offload":
                    obj = jax.tree.map(np.asarray, jax.device_get(obj))
                self.bd.register_object(moment_engine, obj_name, obj)
        self.bd.register_object(self.policy.params_engine,
                                f"{arch}/opt/step", state["opt"]["step"])

    def fetch_train_state(self, arch: str) -> Dict[str, Any]:
        from repro.kernels.quant_cast import ops as qops
        dense = self.bd.engines[self.policy.params_engine]
        params = dense.get(f"{arch}/params")
        moment_engine = self.bd.engines[self._moment_engine()]
        opt: Dict[str, Any] = {"step": dense.get(f"{arch}/opt/step")}
        template = jax.tree.leaves(params)
        for key in ("m", "v"):
            obj = moment_engine.get(f"{arch}/opt/{key}")
            if self.policy.moments == "compressed":
                # dequantize page dicts back to arrays, shaped like params
                flat_p, treedef = jax.tree.flatten(params)
                flat_q = treedef.flatten_up_to(obj)
                obj = treedef.unflatten([
                    qops.dequantize(d["q"], d["scale"], p.shape)
                    for d, p in zip(flat_q, flat_p)])
            elif self.policy.moments == "offload":
                obj = jax.tree.map(jnp.asarray, obj)
            opt[key] = obj
        return {"params": params, "opt": opt}

    # -- KV cache pages ----------------------------------------------------------
    def register_kv_cache(self, arch: str, cache) -> None:
        from repro.core import datamodel as dm
        kv = self.bd.engines["kvstore0"]
        if self.policy.kv_codec == "int8":
            dense = self.bd.engines[self.policy.params_engine]
            dense.put("__kv_stage", cache)
            self.bd.migrator.migrate(
                dense, "__kv_stage", kv, f"{arch}/kv_cache",
                MigrationParams(method="quant"))
            dense.delete("__kv_stage")
        else:
            kv.put(f"{arch}/kv_cache", cache)

    def fetch_kv_cache(self, arch: str, template=None):
        from repro.kernels.quant_cast import ops as qops
        kv = self.bd.engines["kvstore0"]
        obj = kv.get(f"{arch}/kv_cache")
        if self.policy.kv_codec == "int8" and template is not None:
            flat_t, treedef = jax.tree.flatten(template)
            flat_q = treedef.flatten_up_to(obj)
            return treedef.unflatten([
                qops.dequantize(d["q"], d["scale"], t.shape
                                ).astype(t.dtype)
                for d, t in zip(flat_q, flat_t)])
        return obj
