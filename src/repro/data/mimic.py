"""Synthetic MIMIC-II-style dataset (paper §IV): the real MIMIC II database
is access-restricted, so we generate schema-compatible synthetic data —
patient history into the relational engine (PostgreSQL analog), physiologic
waveforms into the array engine (SciDB analog), free-form text into the KV
engine (Accumulo analog) — exactly the default placement of the v0.1
release scripts.

``stream_mimic_waveforms`` is the *live* counterpart: physiologic
waveforms arrive continuously in the real workload, so it feeds the same
synthetic signal batch-by-batch into the streaming island (paper §III's
S-Store member; see ``repro.stream``), ticking the standing-query runtime
after every batch.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp

from repro.core import datamodel as dm
from repro.core.api import BigDawg


def load_mimic_demo(bd: BigDawg, *, num_patients: int = 256,
                    num_orders: int = 1024, wave_len: int = 4096,
                    num_logs: int = 64, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)

    # -- patient history -> relational engine (hoststore0) -------------------
    subject_id = np.arange(num_patients)
    d_patients = dm.Table({
        "subject_id": jnp.asarray(subject_id),
        "sex": jnp.asarray(rng.integers(0, 2, num_patients)),      # 0=F,1=M
        "dob_year": jnp.asarray(rng.integers(1930, 2000, num_patients)),
        "hospital_expire_flg": jnp.asarray(
            rng.integers(0, 2, num_patients)),
    })
    bd.register_object("hoststore0", "mimic2v26.d_patients", d_patients,
                       fields=tuple(d_patients.fields))

    poe_order = dm.Table({
        "poe_id": jnp.asarray(np.arange(num_orders)),
        "subject_id": jnp.asarray(
            rng.integers(0, num_patients, num_orders)),
        "icustay_id": jnp.asarray(rng.integers(0, 512, num_orders)),
        "dose": jnp.asarray(rng.uniform(0.5, 50.0, num_orders)),
    })
    bd.register_object("hoststore0", "mimic2v26.poe_order", poe_order,
                       fields=tuple(poe_order.fields))
    # replicate onto the second relational engine (paper ships mimic2_copy)
    bd.register_object("hoststore1", "mimic2v26.poe_order", poe_order,
                       fields=tuple(poe_order.fields))

    # -- physiologic waveforms -> array engine (densehbm0) -------------------
    t = np.arange(wave_len, dtype=np.float64)
    signal = (np.sin(2 * np.pi * t / 360.0)[None, :]
              * rng.uniform(0.5, 2.0, (8, 1))
              + 0.05 * rng.standard_normal((8, wave_len)))
    waveform = dm.ArrayObject(
        attrs={"signal": jnp.asarray(signal)},
        dim_names=("lead", "tick"))
    bd.register_object("densehbm0", "mimic2v26.waveform", waveform,
                       fields=("signal",))

    myarray = dm.ArrayObject(
        attrs={"val": jnp.asarray(rng.standard_normal(256))},
        dim_names=("dim1",))
    bd.register_object("densehbm0", "myarray", myarray, fields=("val",))

    # -- free-form text -> KV engine (kvstore0) ------------------------------
    keys, values = [], []
    for i in range(num_logs):
        keys.append((f"r_{i:04d}", "note", "text"))
        values.append(f"synthetic clinical note {i}: pt stable, "
                      f"hr={int(rng.integers(50, 120))}")
    bd.register_object("kvstore0", "mimic_logs", dm.KVTable(keys, values),
                       fields=("row", "colfam", "colqual", "value"))


def stream_mimic_waveforms(bd: BigDawg, *, batch_rows: int = 64,
                           num_batches: int = 32, capacity: int = 8192,
                           seed: int = 0,
                           name: str = "mimic2v26.waveform_stream",
                           engine_name: str = "streamstore0",
                           tick: bool = True, shards: int = 1,
                           shard_key: str = None,
                           num_engines: int = None) -> Iterator[Dict]:
    """Live MIMIC waveform feed: appends synthetic physiologic batches to
    a ring-buffer stream on the streaming island, one batch per
    iteration, advancing the continuous-query runtime after each.

    The signal is the same deterministic sine+noise family as
    ``load_mimic_demo``'s batch waveform, phased by the stream's global
    sequence number so a resumed feed continues the waveform seamlessly.
    With ``shards > 1`` the stream is hash-partitioned across multiple
    StreamEngines (scatter appends, seq-ordered gathers — results stay
    bit-identical to the unsharded feed).  Yields a per-batch dict with
    append counts and the standing-query responses that ran on that tick.
    """
    rng = np.random.default_rng(seed)
    engine = bd.engines[engine_name]
    if not engine.has(name):
        bd.register_stream(engine_name, name, ("signal", "hr"), capacity,
                           shards=shards, shard_key=shard_key,
                           num_engines=num_engines)
    stream = engine.get(name)
    for b in range(num_batches):
        t = stream.total_appended + np.arange(batch_rows,
                                              dtype=np.float64)
        signal = (np.sin(2 * np.pi * t / 360.0)
                  + 0.05 * rng.standard_normal(batch_rows))
        hr = 75.0 + 10.0 * np.sin(2 * np.pi * t / 3600.0) \
            + rng.standard_normal(batch_rows)
        counts = stream.append({"signal": signal, "hr": hr})
        ran = bd.streams.tick() if tick else []
        yield {"batch": b, **counts,
               "ran": [(cq_name, resp.plan_cache_hit)
                       for cq_name, resp in ran]}
