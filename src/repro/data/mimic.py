"""Synthetic MIMIC-II-style dataset (paper §IV): the real MIMIC II database
is access-restricted, so we generate schema-compatible synthetic data —
patient history into the relational engine (PostgreSQL analog), physiologic
waveforms into the array engine (SciDB analog), free-form text into the KV
engine (Accumulo analog) — exactly the default placement of the v0.1
release scripts.

``stream_mimic_waveforms`` is the *live* counterpart: physiologic
waveforms arrive continuously in the real workload, so it feeds the same
synthetic signal batch-by-batch into the streaming island (paper §III's
S-Store member; see ``repro.stream``), ticking the standing-query runtime
after every batch.  ``stream_mimic_paired_waveforms`` adds the
cross-stream event-time workload: two jittered, out-of-order waveform
feeds (ABP + ECG) over a shared ``ts`` axis, for watermarked windows and
interval joins.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np
import jax.numpy as jnp

from repro.core import datamodel as dm
from repro.core.api import BigDawg


def load_mimic_demo(bd: BigDawg, *, num_patients: int = 256,
                    num_orders: int = 1024, wave_len: int = 4096,
                    num_logs: int = 64, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)

    # -- patient history -> relational engine (hoststore0) -------------------
    subject_id = np.arange(num_patients)
    d_patients = dm.Table({
        "subject_id": jnp.asarray(subject_id),
        "sex": jnp.asarray(rng.integers(0, 2, num_patients)),      # 0=F,1=M
        "dob_year": jnp.asarray(rng.integers(1930, 2000, num_patients)),
        "hospital_expire_flg": jnp.asarray(
            rng.integers(0, 2, num_patients)),
    })
    bd.register_object("hoststore0", "mimic2v26.d_patients", d_patients,
                       fields=tuple(d_patients.fields))

    poe_order = dm.Table({
        "poe_id": jnp.asarray(np.arange(num_orders)),
        "subject_id": jnp.asarray(
            rng.integers(0, num_patients, num_orders)),
        "icustay_id": jnp.asarray(rng.integers(0, 512, num_orders)),
        "dose": jnp.asarray(rng.uniform(0.5, 50.0, num_orders)),
    })
    bd.register_object("hoststore0", "mimic2v26.poe_order", poe_order,
                       fields=tuple(poe_order.fields))
    # replicate onto the second relational engine (paper ships mimic2_copy)
    bd.register_object("hoststore1", "mimic2v26.poe_order", poe_order,
                       fields=tuple(poe_order.fields))

    # -- physiologic waveforms -> array engine (densehbm0) -------------------
    t = np.arange(wave_len, dtype=np.float64)
    signal = (np.sin(2 * np.pi * t / 360.0)[None, :]
              * rng.uniform(0.5, 2.0, (8, 1))
              + 0.05 * rng.standard_normal((8, wave_len)))
    waveform = dm.ArrayObject(
        attrs={"signal": jnp.asarray(signal)},
        dim_names=("lead", "tick"))
    bd.register_object("densehbm0", "mimic2v26.waveform", waveform,
                       fields=("signal",))

    myarray = dm.ArrayObject(
        attrs={"val": jnp.asarray(rng.standard_normal(256))},
        dim_names=("dim1",))
    bd.register_object("densehbm0", "myarray", myarray, fields=("val",))

    # -- free-form text -> KV engine (kvstore0) ------------------------------
    keys, values = [], []
    for i in range(num_logs):
        keys.append((f"r_{i:04d}", "note", "text"))
        values.append(f"synthetic clinical note {i}: pt stable, "
                      f"hr={int(rng.integers(50, 120))}")
    bd.register_object("kvstore0", "mimic_logs", dm.KVTable(keys, values),
                       fields=("row", "colfam", "colqual", "value"))


def stream_mimic_waveforms(bd: BigDawg, *, batch_rows: int = 64,
                           num_batches: int = 32, capacity: int = 8192,
                           seed: int = 0,
                           name: str = "mimic2v26.waveform_stream",
                           engine_name: str = "streamstore0",
                           tick: bool = True, shards: int = 1,
                           shard_key: str = None,
                           num_engines: int = None) -> Iterator[Dict]:
    """Live MIMIC waveform feed: appends synthetic physiologic batches to
    a ring-buffer stream on the streaming island, one batch per
    iteration, advancing the continuous-query runtime after each.

    The signal is the same deterministic sine+noise family as
    ``load_mimic_demo``'s batch waveform, phased by the stream's global
    sequence number so a resumed feed continues the waveform seamlessly.
    With ``shards > 1`` the stream is hash-partitioned across multiple
    StreamEngines (scatter appends, seq-ordered gathers — results stay
    bit-identical to the unsharded feed).  Yields a per-batch dict with
    append counts and the standing-query responses that ran on that tick.
    """
    rng = np.random.default_rng(seed)
    engine = bd.engines[engine_name]
    if not engine.has(name):
        bd.register_stream(engine_name, name, ("signal", "hr"), capacity,
                           shards=shards, shard_key=shard_key,
                           num_engines=num_engines)
    stream = engine.get(name)
    for b in range(num_batches):
        t = stream.total_appended + np.arange(batch_rows,
                                              dtype=np.float64)
        signal = (np.sin(2 * np.pi * t / 360.0)
                  + 0.05 * rng.standard_normal(batch_rows))
        hr = 75.0 + 10.0 * np.sin(2 * np.pi * t / 3600.0) \
            + rng.standard_normal(batch_rows)
        counts = stream.append({"signal": signal, "hr": hr})
        ran = bd.streams.tick() if tick else []
        yield {"batch": b, **counts,
               "ran": [(cq_name, resp.plan_cache_hit)
                       for cq_name, resp in ran]}


def stream_mimic_paired_waveforms(bd: BigDawg, *, batch_rows: int = 48,
                                  num_batches: int = 24,
                                  capacity: int = 8192, seed: int = 0,
                                  jitter: float = 2.0,
                                  max_delay: float = 6.0,
                                  shards: int = 2,
                                  abp_name: str = "mimic2v26.abp_stream",
                                  ecg_name: str = "mimic2v26.ecg_stream",
                                  engine_name: str = "streamstore0",
                                  tick: bool = True) -> Iterator[Dict]:
    """Jittered two-stream MIMIC waveform feed — the cross-stream
    event-time workload (paper §III: correlating ABP and ECG alarms).

    Two event-time streams, ``abp`` (arterial blood pressure) and
    ``ecg``, share one ``ts`` axis at 1 row/tick with the ECG phase-
    shifted by 0.25.  Delivery is *out of order*: each batch's rows are
    shuffled by a bounded network jitter (arrival order = order of
    ``ts + U(-jitter, jitter)``), so insertion buffers and watermarks do
    real work, while ``jitter < max_delay / 2`` guarantees no row is
    ever late — the streams reconstruct the exact in-order signal.
    Yields a per-batch dict with append counts, both watermarks, and the
    standing queries that ran on that tick; after the final batch both
    streams are flushed (punctuation) and one more tick runs so standing
    joins see the last closed window.
    """
    assert jitter >= 0 and max_delay > 2 * jitter, (jitter, max_delay)
    rng = np.random.default_rng(seed)
    engine = bd.engines[engine_name]
    streams = {}
    for sname, phase in ((abp_name, 0.0), (ecg_name, 0.25)):
        if not engine.has(sname):
            field = "abp" if sname == abp_name else "ecg"
            bd.register_stream(engine_name, sname, ("ts", field),
                               capacity, shards=shards,
                               ts_field="ts", max_delay=max_delay)
        streams[sname] = engine.get(sname)

    def _emit(b: int, ran) -> Dict:
        return {"batch": b,
                "watermarks": {n: s.watermark
                               for n, s in streams.items()},
                "late": {n: s.total_late for n, s in streams.items()},
                "ran": [(cq_name, resp.plan_cache_hit)
                        for cq_name, resp in ran]}

    base = 0.0
    for b in range(num_batches):
        t = base + np.arange(batch_rows, dtype=np.float64)
        base += batch_rows
        order = np.argsort(t + rng.uniform(-jitter, jitter, batch_rows))
        abp_ts = t[order]
        abp = (90.0 + 12.0 * np.sin(2 * np.pi * t / 360.0)
               + 0.5 * rng.standard_normal(batch_rows))[order]
        counts_abp = streams[abp_name].append({"ts": abp_ts,
                                               "abp": abp})
        order = np.argsort(t + rng.uniform(-jitter, jitter, batch_rows))
        ecg_ts = (t + 0.25)[order]
        ecg = (np.sin(2 * np.pi * t / 6.0)
               + 0.1 * rng.standard_normal(batch_rows))[order]
        counts_ecg = streams[ecg_name].append({"ts": ecg_ts,
                                               "ecg": ecg})
        ran = bd.streams.tick() if tick else []
        yield {**_emit(b, ran), "appended": {
            abp_name: counts_abp["appended"],
            ecg_name: counts_ecg["appended"]}}
    # punctuation: close the tail windows and let standing joins see them
    for s in streams.values():
        s.flush()
    ran = bd.streams.tick() if tick else []
    yield _emit(num_batches, ran)
