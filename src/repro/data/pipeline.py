"""Synthetic token data pipeline, served through the RelationalIsland.

The polystore story (DESIGN.md §3): a training batch is a relational-island
object — batches are materialized as Tables in a HostStore engine, cast to
the ArrayIsland (device placement) by the Migrator, and consumed by the
train step.  ``TokenDataset`` is deterministic in (seed, step, host) so
multi-host loaders shard without coordination, and restart-after-failure
resumes exactly (fault tolerance depends on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import datamodel as dm
from repro.models.config import ModelConfig
from repro.models import registry


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 4096
    global_batch: int = 256
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


class TokenDataset:
    """Deterministic synthetic LM token stream (zipf-ish unigram draws)."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig) -> None:
        assert dcfg.global_batch % dcfg.num_hosts == 0
        self.cfg = cfg
        self.dcfg = dcfg
        self.local_batch = dcfg.global_batch // dcfg.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.dcfg.seed, step, self.dcfg.host_id))
        st = registry.text_len(self.cfg, self.dcfg.seq_len)
        # zipf-flavoured unigram distribution, clipped to vocab
        raw = rng.zipf(1.3, size=(self.local_batch, st + 1))
        toks = (raw % self.cfg.vocab_size).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "vision":
            out["prefix_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.num_prefix_embeds,
                 self.cfg.d_model)).astype(np.float32)
        if self.cfg.frontend == "audio":
            out["frame_embeds"] = rng.standard_normal(
                (self.local_batch, max(1, self.dcfg.seq_len
                                       // self.cfg.src_ratio),
                 self.cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_as_table(batch: Dict[str, np.ndarray]) -> dm.Table:
    """Flatten a token batch into a relational-island Table object."""
    toks = np.asarray(batch["tokens"])
    b, s = toks.shape
    rows = b * s
    return dm.Table({
        "sample": jnp.asarray(np.repeat(np.arange(b), s)),
        "position": jnp.asarray(np.tile(np.arange(s), b)),
        "token": jnp.asarray(toks.reshape(-1)),
        "label": jnp.asarray(np.asarray(batch["labels"]).reshape(-1)),
    })


def table_as_batch(table, batch: int, seq: int) -> Dict[str, jnp.ndarray]:
    """Accepts a Table or its array-island cast (ArrayObject)."""
    fields = table.columns if isinstance(table, dm.Table) else table.attrs
    return {
        "tokens": fields["token"].reshape(batch, seq).astype(jnp.int32),
        "labels": fields["label"].reshape(batch, seq).astype(jnp.int32),
    }
