"""Pallas TPU flash attention (causal, GQA-aware).

TPU adaptation notes (DESIGN.md §2): blockwise online-softmax with
(BLOCK_Q x Dh) query tiles resident in VMEM and a sequential sweep over
(BLOCK_K x Dh) key/value tiles; the two matmuls per tile land on the MXU
with 128-aligned contraction dims.  The m/l/acc carries live in VMEM
scratch across the innermost (arbitrary-semantics) grid dimension —
the canonical TPU flash pattern, not a CUDA-warp port.

Causally-skipped tiles are genuinely skipped via pl.when, so the FLOPs
match the ~S^2/2 causal roofline rather than S^2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names this TPUCompilerParams; jax>=0.5 renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, num_kb: int,
                  causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Tiles strictly above the diagonal contribute nothing under causality.
    needed = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (BQ, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (BK, Dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale                     # (BQ, BK) on MXU
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)      # (BQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """q: (B,S,Hq,Dh); k,v: (B,T,Hkv,Dh). Returns (B,S,Hq,Dh)."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    num_qb = s // block_q
    num_kb = t // block_k

    kernel = functools.partial(
        _flash_kernel, scale=dh ** -0.5, block_q=block_q, block_k=block_k,
        num_kb=num_kb, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, dh),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, dh),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
