"""Jit'd public wrapper for flash attention — the ArrayIsland attention shim
(cfg.attn_impl == "flash").  Interpret mode on CPU; compiled on TPU."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as k
from repro.kernels.flash_attention import ref

_INTERPRET = jax.default_backend() != "tpu"


def flash_attention(q: jax.Array, kk: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = k.DEFAULT_BLOCK_Q,
                    block_k: int = k.DEFAULT_BLOCK_K) -> jax.Array:
    s, t = q.shape[1], kk.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    if s % bq or t % bk:
        # ragged tails fall back to the oracle (kernel wants aligned tiles)
        return ref.gqa_attention(q, kk, v, causal=causal)
    return k.flash_attention(q, kk, v, causal=causal, block_q=bq,
                             block_k=bk, interpret=_INTERPRET)
