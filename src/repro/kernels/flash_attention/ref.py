"""Pure-jnp oracle: causal GQA attention with fp32 softmax."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B,S,Hq,Dh); k,v: (B,T,Hkv,Dh) with Hq % Hkv == 0."""
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, kf) * dh ** -0.5
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnd->bsngd", probs, vf)
    return out.reshape(b, s, hq, dh).astype(q.dtype)
