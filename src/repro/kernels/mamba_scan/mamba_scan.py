"""Pallas TPU kernel: Mamba-1 selective scan, channel-blocked.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel leans on
shared-memory staging and per-thread recurrences.  On TPU we block the
d_inner axis into (BD)-wide stripes held in VMEM and sweep the sequence in
chunks; the state h (BD, N) stays pinned in VMEM scratch across the
sequential chunk axis.  Mamba-1's full (Di, N) decay matrix precludes the
SSD matmul trick (that needs Mamba-2's scalar-per-head A), so the inner
C-step loop is VPU elementwise work over (BD, N) tiles + one (BD,N)x(N,)
contraction per step — still far better than HBM round-trips per token.

Grid: (B, Di/BD, S/C) with the chunk axis sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names this TPUCompilerParams; jax>=0.5 renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BD = 256
DEFAULT_CHUNK = 64


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                 h_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)            # (BD, N)

    def step(t, h):
        ut = u_ref[0, t, :].astype(jnp.float32)   # (BD,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)
        bt = b_ref[0, t, :].astype(jnp.float32)   # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)
        da = jnp.exp(dtt[:, None] * a)            # (BD, N)
        h = da * h + (dtt * ut)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=-1)     # (BD,)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == num_chunks - 1)
    def _emit():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("bd", "chunk", "interpret"))
def selective_scan_chunked(u, dt, a, b, c, *, bd: int = DEFAULT_BD,
                           chunk: int = DEFAULT_CHUNK,
                           interpret: bool = True):
    """u,dt: (B,S,Di); a: (Di,N); b,c: (B,S,N) -> (y (B,S,Di), h (B,Di,N)).

    Zero initial state; streaming carries are folded by ops.py.
    """
    bsz, s, di = u.shape
    n = a.shape[1]
    bd = min(bd, di)
    assert di % bd == 0 and s % chunk == 0, (di, bd, s, chunk)
    nc = s // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, num_chunks=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(bsz, di // bd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di_, ci: (bi, ci, di_)),
            pl.BlockSpec((1, chunk, bd), lambda bi, di_, ci: (bi, ci, di_)),
            pl.BlockSpec((bd, n), lambda bi, di_, ci: (di_, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di_, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di_, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di_, ci: (bi, ci, di_)),
            pl.BlockSpec((1, bd, n), lambda bi, di_, ci: (bi, di_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), u.dtype),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(u, dt, a, b, c)
    return y, h
