"""Jit'd wrapper for the chunked Mamba selective scan; folds streaming
state carries (the recurrence is linear in h0) and falls back to the
oracle on ragged shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import mamba_scan as k
from repro.kernels.mamba_scan import ref

_INTERPRET = jax.default_backend() != "tpu"


def selective_scan(u, dt, a, b, c, h0=None, *, bd: int = k.DEFAULT_BD,
                   chunk: int = k.DEFAULT_CHUNK):
    bsz, s, di = u.shape
    n = a.shape[1]
    bd = min(bd, di)
    if s % chunk or di % bd:
        h_init = h0 if h0 is not None \
            else jnp.zeros((bsz, di, n), jnp.float32)
        return ref.selective_scan(u, dt, a, b, c, h_init)
    y, h = k.selective_scan_chunked(u, dt, a, b, c, bd=bd, chunk=chunk,
                                    interpret=_INTERPRET)
    if h0 is not None:
        # linear-in-state: add decayed-h0 contributions
        dtf = dt.astype(jnp.float32)
        log_da = dtf[..., None] * a[None, None]          # (B,S,Di,N)
        cum = jnp.cumsum(log_da, axis=1)
        decay = jnp.exp(cum)                              # prod_{i<=t} da_i
        y = y + jnp.einsum("bsdn,bdn,bsn->bsd", decay, h0,
                           c.astype(jnp.float32)).astype(y.dtype)
        h = h + jnp.exp(cum[:, -1]) * h0
    return y, h
