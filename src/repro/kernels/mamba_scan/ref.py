"""Pure-jnp oracle for the Mamba-1 selective scan (per-step lax.scan)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def selective_scan(u: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, h0: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """u,dt: (B,S,Di); a: (Di,N); b,c: (B,S,N); h0: (B,Di,N) fp32.

      h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t u_t) ⊗ B_t;  y_t = h_t · C_t
    """
    def step(h, inp):
        ut, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a[None])
        h = da * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_final
