"""Jit'd public wrappers for the quant_cast kernel: arbitrary-shape tensors
are flattened, padded to (ROWS x BLOCK) tiles, and routed through the Pallas
kernel (interpret=True on CPU; compiled on TPU).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quant_cast import quant_cast as k
from repro.kernels.quant_cast import ref

_INTERPRET = jax.default_backend() != "tpu"


def _pad_to_tiles(flat: jax.Array) -> Tuple[jax.Array, int]:
    tile = k.ROWS * k.BLOCK
    n = flat.shape[0]
    pad = (-n) % tile
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, k.BLOCK), n


def quantize(x: jax.Array, block: int = k.BLOCK, *, use_kernel: bool = True
             ) -> Tuple[jax.Array, jax.Array]:
    """Any-shape f32 -> (q int8 (nb, BLOCK), scale f32 (nb, 1)).

    ``block`` is fixed to the kernel lane width (128); the argument is kept
    for API compatibility with MigrationParams.quant_block.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    x2d, _ = _pad_to_tiles(flat)
    if use_kernel:
        q, scale = k.quantize_2d(x2d, interpret=_INTERPRET)
    else:
        q, scale = ref.quantize_blocks(x2d)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, shape, *,
               use_kernel: bool = True) -> jax.Array:
    if use_kernel:
        x2d = k.dequantize_2d(q, scale, interpret=_INTERPRET)
    else:
        x2d = ref.dequantize_blocks(q, scale)
    n = int(np.prod(shape))
    return x2d.reshape(-1)[:n].reshape(shape)
