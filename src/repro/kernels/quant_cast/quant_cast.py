"""Pallas TPU kernel: fused blockwise int8 quantize / dequantize.

This is the Migrator's "binary re-coding" cast (DenseHBM -> KVStore pages,
int8 gradient compression).  Tiles are (ROWS, BLOCK) = (8, 128) — one VREG
sublane x lane tile — so the absmax reduction stays in registers and the
kernel is purely bandwidth-bound (read f32, write int8 + 1 scale per row),
i.e. a ~4x traffic reduction over the f32 copy it replaces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8          # sublane tile
BLOCK = 128       # lane tile == quant block size


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...]                                   # (ROWS, BLOCK) f32
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_2d(x2d: jax.Array, *, interpret: bool = True):
    """x2d: (nb, BLOCK) f32, nb % ROWS == 0 -> (q int8, scale f32 (nb,1))."""
    nb = x2d.shape[0]
    grid = (nb // ROWS,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_2d(q: jax.Array, scale: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    nb = q.shape[0]
    grid = (nb // ROWS,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, scale)
