"""Pure-jnp oracle for the blockwise int8 quantize/dequantize cast."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_blocks(x2d: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x2d: (nb, block) f32 -> (q int8 (nb, block), scale f32 (nb, 1))."""
    absmax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x2d / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blocks(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
