"""Jit'd wrapper for the chunked WKV6 kernel.

Stability contract: the chunked form factors decay ratios as
exp(cumsum log w) products, so the per-chunk decay product must stay inside
fp32 range — with chunk=64 that holds for log w >= -0.25 per step
(w >= 0.78), far below RWKV6's trained decay floor.  Callers with ragged
sequence lengths fall back to the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import ref
from repro.kernels.rwkv6_scan import rwkv6_scan as k

_INTERPRET = jax.default_backend() != "tpu"


def wkv6(r, kk, v, w, u, state=None, *, chunk: int = k.DEFAULT_CHUNK):
    """r,k,v,w: (B,S,H,D); u: (H,D); optional initial state (B,H,D,D)."""
    b, s, h, d = r.shape
    if s % chunk:
        s0 = state if state is not None \
            else jnp.zeros((b, h, d, d), jnp.float32)
        return ref.wkv6(r, kk, v, w, u, s0)
    y, s_fin = k.wkv6_chunked(r, kk, v, w, u, chunk=chunk,
                              interpret=_INTERPRET)
    if state is not None:
        # fold the incoming carry: the kernel ran with S_0 = 0, and the
        # recurrence is linear in the state, so add the decayed-carry terms.
        log_a = jnp.cumsum(jnp.log(w.astype(jnp.float32)), axis=1)
        a_prev = jnp.exp(log_a - jnp.log(w.astype(jnp.float32)))
        # y_t += (r_t ⊙ A_{t-1}) S_prev
        y = y + jnp.einsum("bshd,bhde->bshe",
                           r.astype(jnp.float32) * a_prev, state
                           ).astype(y.dtype)
        a_full = jnp.exp(log_a[:, -1])             # (B,H,D)
        s_fin = s_fin + a_full[..., None] * state
    return y, s_fin
