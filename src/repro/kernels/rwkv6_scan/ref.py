"""Pure-jnp oracle for the WKV6 recurrence (per-step lax.scan)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,S,H,D) fp32; u: (H,D); state: (B,H,D,D).

      y_t = r_t (S_{t-1} + diag(u) k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          w.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), state
