"""Pallas TPU kernel: chunked WKV6 linear recurrence.

TPU adaptation (DESIGN.md §2): the GPU reference (RWKV CUDA) walks the
sequence one token per thread-block iteration.  On TPU we use the chunked
linear-attention form so the inner loop is three (C x D) matmuls on the MXU
instead of S rank-1 VPU updates:

  with cumulative decays A_t = prod_{i<=t} w_i (per k-channel):
    inter   y_t += (r_t ⊙ A_{t-1}) S_0
    intra   y_t += sum_{j<t} ((r_t ⊙ A_{t-1}/A_j) · k_j) v_j   (masked matmul)
    bonus   y_t += (r_t · (u ⊙ k_t)) v_t                        (diagonal)
    state   S_C  = A_C ⊙ S_0 + (K ⊙ A_C/A)^T V

A_t/A_j <= 1 for j <= t (decays in (0,1)) so the ratios are stable.
Grid: (B*H, S/C) with the chunk axis sequential; S_0 carries in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names this TPUCompilerParams; jax>=0.5 renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref,
                state_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)            # (C, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)            # decays in (0,1)
    u = u_ref[0].astype(jnp.float32)            # (1, D) bonus

    log_a = jnp.cumsum(jnp.log(w), axis=0)      # (C, D)
    a = jnp.exp(log_a)                          # A_t
    a_prev = jnp.exp(log_a - jnp.log(w))        # A_{t-1} = A_t / w_t

    s0 = state_ref[...]                         # (D, D)

    # inter-chunk: (r ⊙ A_{t-1}) @ S_0
    y = jnp.dot(r * a_prev, s0)

    # intra-chunk: masked ((r ⊙ A_{t-1}) @ (K / A)^T) @ V, strictly causal
    scores = jnp.dot(r * a_prev, (k / a).T)     # (C, C)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(cols < rows, scores, 0.0)
    y = y + jnp.dot(scores, v)

    # diagonal bonus: (r_t · (u ⊙ k_t)) v_t
    bonus = jnp.sum(r * u * k, axis=-1, keepdims=True)
    y = y + bonus * v

    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S_C = A_C ⊙ S_0 + (K ⊙ A_C/A)^T V
    a_c = a[-1:]                                # (1, D)
    state_ref[...] = a_c.T * s0 + jnp.dot((k * (a_c / a)).T, v)

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        sout_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
                 interpret: bool = True):
    """r,k,v,w: (B,S,H,D) fp32; u: (H,D). Returns (y (B,S,H,D), S (B,H,D,D)).

    Zero initial state (sequence mode); streaming callers fold their carry
    via the ops.py wrapper.
    """
    b, s, h, d = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def to_bh(x):
        return x.swapaxes(1, 2).reshape(b * h, s, d)

    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    ub = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nc)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, d), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, d, d), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, d), r.dtype),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(rb, kb, vb, wb, ub)

    y = y.reshape(b, h, s, d).swapaxes(1, 2)
    return y, s_out.reshape(b, h, d, d)
