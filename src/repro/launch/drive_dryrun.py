"""Sweep driver: runs the dry-run for every (arch x shape x mesh) cell in a
subprocess (XLA device-count isolation), appending JSONL results.

  PYTHONPATH=src python -m repro.launch.drive_dryrun \
      --out experiments/dryrun_results.jsonl [--multi-pod-only] [...]

Single-pod cells run the cost probe (roofline terms); multi-pod cells run
the compile-proof only (sharding coherence across the pod axis).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# NOTE: this driver must not import jax (children set their own XLA_FLAGS).
ARCH_NAMES = (
    "olmoe-1b-7b", "deepseek-moe-16b", "command-r-plus-104b",
    "command-r-35b", "deepseek-coder-33b", "qwen2-1.5b", "internvl2-2b",
    "seamless-m4t-medium", "rwkv6-7b", "jamba-v0.1-52b")
SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def existing_keys(path: str) -> set:
    keys = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    keys.add((r["arch"], r["shape"], r["mesh"]))
                except (json.JSONDecodeError, KeyError):
                    continue
    return keys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun_results.jsonl")
    ap.add_argument("--timeout", type=int, default=1500)
    ap.add_argument("--archs", nargs="*", default=list(ARCH_NAMES))
    ap.add_argument("--shapes", nargs="*", default=list(SHAPE_NAMES))
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = existing_keys(args.out) if args.resume else set()

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    total = 0
    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in args.archs:
            for shape in args.shapes:
                if (arch, shape, mesh_name) in done:
                    print(f"skip (done): {arch} {shape} {mesh_name}",
                          flush=True)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if multi_pod:
                    cmd += ["--multi-pod", "--no-cost-probe"]
                t0 = time.time()
                print(f"[{time.strftime('%H:%M:%S')}] {arch} {shape} "
                      f"{mesh_name} ...", flush=True)
                try:
                    proc = subprocess.run(
                        cmd, capture_output=True, text=True,
                        timeout=args.timeout)
                    tail = (proc.stdout.strip().splitlines() or [""])[-1]
                    status = "?"
                    try:
                        status = json.loads(tail).get("status", "?")
                    except json.JSONDecodeError:
                        status = f"crash rc={proc.returncode}"
                        with open(args.out, "a") as f:
                            f.write(json.dumps({
                                "arch": arch, "shape": shape,
                                "mesh": mesh_name, "status": "crash",
                                "error": proc.stderr[-400:]}) + "\n")
                    print(f"    -> {status} ({time.time()-t0:.0f}s)",
                          flush=True)
                except subprocess.TimeoutExpired:
                    print(f"    -> TIMEOUT ({args.timeout}s)", flush=True)
                    with open(args.out, "a") as f:
                        f.write(json.dumps({
                            "arch": arch, "shape": shape,
                            "mesh": mesh_name,
                            "status": "timeout"}) + "\n")
                total += 1
    print(f"swept {total} cells -> {args.out}", flush=True)


if __name__ == "__main__":
    main()
