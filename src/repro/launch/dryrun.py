import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) ----------
import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.shapes import SHAPES, applicable          # noqa: E402
from repro.launch import specs as S                          # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_num_devices  # noqa: E402
from repro.models import registry                            # noqa: E402
from repro.sharding import logical as L                      # noqa: E402
from repro.train.step import TrainConfig, make_train_step    # noqa: E402
from repro.optim.adamw import AdamWConfig                    # noqa: E402

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(lhs: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(lhs):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device wire-byte estimates from the partitioned HLO.

    Convention (documented in EXPERIMENTS.md): for a group of size g,
      all-gather / all-to-all: out_bytes * (g-1)/g
      reduce-scatter:          out_bytes * (g-1)        (operand ~= g*out)
      all-reduce:              2 * out_bytes * (g-1)/g  (RS + AG)
      collective-permute:      out_bytes
    Shapes in partitioned HLO are per-device shapes.
    """
    stats = {op: {"count": 0, "bytes": 0.0} for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            if f" {op}(" not in line and f" {op}-start(" not in line:
                continue
            lhs = line.split(f" {op}")[0]
            out_bytes = _shape_bytes(lhs)
            g = 1
            m = _GROUPS_RE.search(line)
            if m:
                g = int(m.group(2))
            else:
                m2 = _GROUPS_BRACE_RE.search(line)
                if m2:
                    g = len(m2.group(1).split(","))
            if g <= 1 and op != "collective-permute":
                continue
            if op == "all-reduce":
                wire = 2.0 * out_bytes * (g - 1) / g
            elif op == "reduce-scatter":
                wire = float(out_bytes) * (g - 1)
            elif op == "collective-permute":
                wire = float(out_bytes)
            else:
                wire = float(out_bytes) * (g - 1) / g
            stats[op]["count"] += 1
            stats[op]["bytes"] += wire
            break
    stats["total_bytes"] = sum(
        v["bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def microbatches_for(cfg, shape, mesh, n_params: int) -> int:
    per_dev = shape.global_batch // (
        mesh.shape["data"] * mesh.shape.get("pod", 1))
    if per_dev <= 1:
        return 1
    if n_params > 2e10:
        return per_dev                    # 1 sequence per device per ubatch
    if n_params > 2e9:
        return max(1, per_dev // 4)
    return 1


def active_params(cfg, specs) -> tuple:
    """(n_total, n_active): routed-expert params scaled by top_k/E."""
    n_total, n_active = 0, 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, L.ParamSpec))[0]:
        n = leaf.num_params()
        n_total += n
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        is_routed = (L.EXPERT in leaf.axes and "router" not in keys)
        if is_routed and cfg.num_experts:
            n_active += n * cfg.top_k / cfg.num_experts
        else:
            n_active += n
    return n_total, int(n_active)


def seq_scan_correction(cfg, tokens: int, devices: int, kind: str) -> float:
    """Analytic per-device FLOPs for the in-time-scan SSM cores, which XLA's
    cost model counts once (loop bodies).  ~0.1% of total; decode cells run
    the scan with length 1 so no correction applies.  Documented in
    EXPERIMENTS.md §Dry-run."""
    if kind == "decode" or cfg.ssm_kind == "":
        return 0.0
    plan = cfg.layer_plan()
    n_blocks = cfg.num_scanned()
    fl = 0.0
    for mixer, _ in plan * n_blocks:
        if mixer == "rwkv6":
            fl += tokens * 7.0 * cfg.d_model * cfg.rwkv_head_dim
        elif mixer == "mamba":
            di = cfg.ssm_expand * cfg.d_model
            fl += tokens * 8.0 * di * cfg.ssm_state
    if kind == "train":
        fl *= 3.0        # bwd ~= 2x fwd
    return fl / devices


def build_lowered(arch: str, shape_name: str, mesh, *,
                  seq_parallel=None, shard_kv_seq=None, microbatches=None,
                  remat=None, capacity_factor=None, donate: bool = True,
                  scan_layers: bool = True, vocab_pad_to=None,
                  kv_cache_dtype=None, shard_ctx_train=None,
                  moe_cap_shard=None, moe_dropless: bool = False):
    cfg = registry.get_config(arch)
    # dry-run lowers the at-scale shapes: use the capacity-clipped sort
    # dispatch (the O(tokens*k*D) design the cost probes are about), not
    # the dropless reference path (see models/moe.py docstring)
    overrides = {"moe_dropless": moe_dropless}
    if remat is not None:
        overrides["remat"] = remat
    if capacity_factor is not None:
        overrides["capacity_factor"] = capacity_factor
    if not scan_layers:
        overrides["scan_layers"] = False
    if vocab_pad_to is not None:
        overrides["vocab_pad_to"] = vocab_pad_to
    if kv_cache_dtype is not None:
        overrides["kv_cache_dtype"] = kv_cache_dtype
    if shard_ctx_train is not None:
        overrides["shard_ctx_train"] = shard_ctx_train
    if moe_cap_shard is not None:
        overrides["moe_cap_shard"] = moe_cap_shard
    if overrides:
        cfg = registry.get_config(arch, **overrides)
    shape = SHAPES[shape_name]
    rules = S.pick_rules(cfg, mesh, seq_parallel=seq_parallel,
                         shard_kv_seq=shard_kv_seq)
    specs = registry.param_specs(cfg)
    n_total, n_active = active_params(cfg, specs)

    if shape.kind == "train":
        mb = microbatches if microbatches is not None \
            else microbatches_for(cfg, shape, mesh, n_total)
        tcfg = TrainConfig(optimizer=AdamWConfig(), microbatches=mb)
        step = make_train_step(cfg, tcfg, rules)
        state_structs, state_shards = S.train_state_specs(cfg, mesh, rules)
        batch_structs, batch_shards = S.batch_specs(cfg, shape, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(state_shards, batch_shards),
            out_shardings=(state_shards, None),
            donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_structs, batch_structs)
        extra = {"microbatches": mb}
    elif shape.kind == "prefill":
        _, p_structs, p_shards = S.param_structs_and_shardings(
            cfg, mesh, rules, dtype=jnp.bfloat16)
        batch_structs, batch_shards = S.batch_specs(cfg, shape, mesh)
        batch_structs.pop("labels"), batch_shards.pop("labels")
        c_structs, c_shards = S.cache_structs_and_shardings(
            cfg, shape, mesh, rules)

        def prefill_step(params, batch, cache):
            logits, new_cache, extras = registry.prefill(
                params, batch, cache, cfg, rules)
            return logits, new_cache

        jitted = jax.jit(
            prefill_step,
            in_shardings=(p_shards, batch_shards, c_shards),
            out_shardings=(None, c_shards),
            donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(p_structs, batch_structs, c_structs)
        extra = {}
    else:  # decode
        _, p_structs, p_shards = S.param_structs_and_shardings(
            cfg, mesh, rules, dtype=jnp.bfloat16)
        batch_structs, batch_shards = S.decode_batch_specs(cfg, shape, mesh)
        c_structs, c_shards = S.cache_structs_and_shardings(
            cfg, shape, mesh, rules)

        def serve_step(params, batch, cache, pos):
            logits, new_cache = registry.decode_step(
                params, batch, cache, pos, cfg, rules)
            return logits, new_cache

        jitted = jax.jit(
            serve_step,
            in_shardings=(p_shards, batch_shards, c_shards, None),
            out_shardings=(None, c_shards),
            donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(p_structs, batch_structs, c_structs,
                               jax.ShapeDtypeStruct((), jnp.int32))
        extra = {}
    return lowered, {"n_params": n_total, "n_active": n_active, **extra}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             cost_probe: bool = True, **kw) -> dict:
    """Two lowerings per cell:
      1. compile-proof: scanned layers + memory-fitting microbatches ->
         memory_analysis + "it compiles on this mesh".
      2. cost probe: UNROLLED layers, microbatches=1 -> exact per-device
         flops / bytes / collective schedule (XLA cost analysis counts
         while bodies once, so scans must be unrolled to be counted;
         verified in EXPERIMENTS.md §Dry-run methodology).
    The multi-pod pass runs only the compile-proof (sharding coherence)."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    cell = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind}
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {**cell, "status": "skipped", "reason": reason}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, meta = build_lowered(arch, shape_name, mesh, **kw)
        compiled = lowered.compile()
    except Exception as exc:                               # noqa: BLE001
        return {**cell, "status": "error",
                "error": f"{type(exc).__name__}: {exc}"[:500]}
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    out = {
        **cell, "status": "ok", "compile_seconds": round(compile_s, 1),
        "devices": mesh_num_devices(mesh),
        "tokens": tokens,
        **meta,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }

    if cost_probe:
        t1 = time.time()
        try:
            probe_kw = dict(kw)
            probe_kw["microbatches"] = 1
            probe_kw["scan_layers"] = False
            lowered_p, _ = build_lowered(arch, shape_name, mesh, **probe_kw)
            compiled_p = lowered_p.compile()
            cost = compiled_p.cost_analysis() or {}
            colls = collective_stats(compiled_p.as_text())
            corr = seq_scan_correction(cfg, tokens,
                                       mesh_num_devices(mesh), shape.kind)
            out.update({
                "probe_compile_seconds": round(time.time() - t1, 1),
                "flops_per_device": cost.get("flops", 0.0) + corr,
                "seq_scan_flops_correction": corr,
                "bytes_per_device": cost.get("bytes accessed", 0.0),
                "collectives": colls,
            })
        except Exception as exc:                           # noqa: BLE001
            out["probe_error"] = f"{type(exc).__name__}: {exc}"[:300]
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", required=True, choices=registry.ARCH_NAMES)
    ap.add_argument("--shape", required=True, choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--seq-parallel", type=int, default=None)
    ap.add_argument("--shard-kv-seq", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--remat", type=str, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--no-cost-probe", action="store_true")
    ap.add_argument("--vocab-pad", type=int, default=None)
    ap.add_argument("--kv-cache-dtype", type=str, default=None,
                    choices=("bf16", "int8"))
    ap.add_argument("--shard-ctx-train", type=int, default=None)
    ap.add_argument("--moe-cap-shard", type=int, default=None)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    kw = {}
    if args.seq_parallel is not None:
        kw["seq_parallel"] = bool(args.seq_parallel)
    if args.shard_kv_seq is not None:
        kw["shard_kv_seq"] = bool(args.shard_kv_seq)
    if args.microbatches is not None:
        kw["microbatches"] = args.microbatches
    if args.remat is not None:
        kw["remat"] = args.remat
    if args.capacity_factor is not None:
        kw["capacity_factor"] = args.capacity_factor
    if args.no_donate:
        kw["donate"] = False
    if args.vocab_pad is not None:
        kw["vocab_pad_to"] = args.vocab_pad
    if args.kv_cache_dtype is not None:
        kw["kv_cache_dtype"] = args.kv_cache_dtype
    if args.shard_ctx_train is not None:
        kw["shard_ctx_train"] = bool(args.shard_ctx_train)
    if args.moe_cap_shard is not None:
        kw["moe_cap_shard"] = bool(args.moe_cap_shard)

    result = run_cell(args.arch, args.shape, args.multi_pod,
                      cost_probe=not args.no_cost_probe, **kw)
    line = json.dumps(result)
    print(line)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    if result["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    main()
