"""§Perf hillclimb driver: runs planned dry-run variants for the three
selected cells, logging each (hypothesis, flags, roofline terms) to
experiments/perf_log.jsonl for the EXPERIMENTS.md §Perf narrative.

  PYTHONPATH=src python -m repro.launch.hillclimb [--only A,B,C]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

OUT = "experiments/perf_log.jsonl"

# (cell_id, iteration, hypothesis, arch, shape, extra dryrun flags)
PLAN = [
    # --- Cell A: deepseek-coder-33b x train_4k (worst roofline fraction) --
    ("A", 0, "baseline (heads 56 % 16 != 0 -> attention replicated on "
     "model axis; reference attention materializes S^2 scores)",
     "deepseek-coder-33b", "train_4k", []),
    ("A", 1, "context-parallel attention: shard k/v sequence over model "
     "axis in training -> score memory & compute /16",
     "deepseek-coder-33b", "train_4k", ["--shard-ctx-train", "1"]),
    ("A", 2, "A1 + remat=none: remove the +2ND recompute from the probe "
     "(memory_analysis shows the activation cost of turning remat off)",
     "deepseek-coder-33b", "train_4k",
     ["--shard-ctx-train", "1", "--remat", "none"]),
    ("A", 3, "A1 + seq-parallel residuals off (isolate SP contribution "
     "to collectives)", "deepseek-coder-33b", "train_4k",
     ["--shard-ctx-train", "1", "--seq-parallel", "0"]),

    # --- Cell B: seamless-m4t-medium x train_4k (most collective-bound) --
    ("B", 0, "baseline (vocab 256206 % 16 != 0 -> unembed replicated, "
     "full-logits all-reduce)", "seamless-m4t-medium", "train_4k", []),
    ("B", 1, "pad vocab to 256256 (%16==0) -> logits vocab-sharded, "
     "all-reduce of (B,S,V) disappears", "seamless-m4t-medium",
     "train_4k", ["--vocab-pad", "128"]),
    ("B", 2, "B1 + context-parallel attention (kv=16 divides, but "
     "encoder is not causal -> check effect)", "seamless-m4t-medium",
     "train_4k", ["--vocab-pad", "128", "--shard-ctx-train", "1"]),
    ("B", 3, "B1 + seq-parallel residuals on (small model: check SP "
     "overhead vs saving)", "seamless-m4t-medium", "train_4k",
     ["--vocab-pad", "128", "--seq-parallel", "1"]),

    # --- Cell C: command-r-plus-104b x decode_32k (paper-technique) ------
    ("C", 0, "baseline (bf16 KV pages; memory-bound: params + 2x cache "
     "read per token; DUS accounting inflates measured bytes)",
     "command-r-plus-104b", "decode_32k", []),
    ("C", 1, "int8 KV pages via the quant cast (paper's binary re-coding "
     "migration applied to the serving cache) -> cache bytes /2",
     "command-r-plus-104b", "decode_32k", ["--kv-cache-dtype", "int8"]),
    ("C", 2, "C1 + no donation (check aliasing contribution to the "
     "memory picture)", "command-r-plus-104b", "decode_32k",
     ["--kv-cache-dtype", "int8", "--no-donate"]),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    cells = args.only.split(",") if args.only else None

    os.makedirs("experiments", exist_ok=True)
    for cell, it, hypothesis, arch, shape, flags in PLAN:
        if cells and cell not in cells:
            continue
        print(f"[{time.strftime('%H:%M:%S')}] {cell}{it}: {arch} {shape} "
              f"{' '.join(flags)}", flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape] + flags
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=args.timeout)
        tail = (proc.stdout.strip().splitlines() or ["{}"])[-1]
        try:
            rec = json.loads(tail)
        except json.JSONDecodeError:
            rec = {"status": "crash", "error": proc.stderr[-400:]}
        rec.update({"cell": cell, "iteration": it,
                    "hypothesis": hypothesis, "flags": flags,
                    "wall_seconds": round(time.time() - t0, 1)})
        with open(OUT, "a") as f:
            f.write(json.dumps(rec) + "\n")
        print(f"    -> {rec.get('status')} ({rec['wall_seconds']}s)",
              flush=True)


if __name__ == "__main__":
    main()

# Post-A1 fix (§Perf A4): the k/v constraint alone was ignored by SPMD
# propagation (A1 == A0); the constraint must pin the score matrices
# themselves (models/attention.py gqa_attend ctx_sharded).  A4 re-runs A1's
# flags against the fixed implementation.
PLAN_A4 = [
    ("A", 4, "FIX + retry of A1: pin scores/probs KV_SEQ-sharded inside "
     "gqa_attend (debug-forward of refuted A1: SPMD all-gathered k and "
     "replicated S^2 scores unless the scores themselves are constrained)",
     "deepseek-coder-33b", "train_4k", ["--shard-ctx-train", "1"]),
    ("B", 4, "same fix applied to qwen2-class replication (12 heads % 16)",
     "qwen2-1.5b", "train_4k", ["--shard-ctx-train", "1"]),
]

# Final optimized variants against the v2 (constraints-active) baseline:
# run AFTER the v2 sweep; iteration=5 rows feed render_report's final table.
PLAN_V2_OPT = [
    ("A", 5, "v2-optimized: context-parallel scores (fixed constraint)",
     "deepseek-coder-33b", "train_4k", ["--shard-ctx-train", "1"]),
    ("B", 5, "v2-optimized: vocab padded to 256256 (VOCAB->model shards)",
     "seamless-m4t-medium", "train_4k", ["--vocab-pad", "128"]),
    ("C", 5, "v2-optimized: int8 KV pages (quant cast on KVStore pages)",
     "command-r-plus-104b", "decode_32k", ["--kv-cache-dtype", "int8"]),
]
