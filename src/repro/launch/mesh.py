"""Production mesh builders (deliverable (e)).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices before any jax
import (launch/dryrun.py lines 1-2).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Single-device mesh with the production axis names (CPU smoke)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def mesh_num_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
