"""Production mesh builders (deliverable (e)).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices before any jax
import (launch/dryrun.py lines 1-2).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:  # older jax.sharding has no AxisType / axis_types kwarg
    AxisType = None


def make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the production axis names (CPU smoke)."""
    return make_mesh((1, 1), ("data", "model"))


def mesh_num_devices(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]
