"""input_specs(): ShapeDtypeStruct stand-ins for every model input of every
(arch x shape) cell — weak-type-correct, shardable, no device allocation —
plus the matching NamedSharding trees (deliverable (e) step 2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeConfig
from repro.models import registry
from repro.models.config import ModelConfig
from repro.sharding import logical as L


def pick_rules(cfg: ModelConfig, mesh: Mesh, *,
               seq_parallel: Optional[bool] = None,
               shard_kv_seq: Optional[bool] = None) -> L.AxisRules:
    """Arch-aware rule selection: KV-cache sharding axis is heads when they
    divide the model axis, else cache-sequence; SP on for big d_model."""
    model_size = mesh.shape["model"]
    if shard_kv_seq is None:
        shard_kv_seq = (cfg.num_kv_heads == 0
                        or cfg.num_kv_heads % model_size != 0)
    if seq_parallel is None:
        seq_parallel = cfg.d_model * cfg.num_layers >= 4096 * 28
    return L.default_rules(mesh, shard_kv_seq=shard_kv_seq,
                           seq_parallel=seq_parallel)


def _batch_axes(mesh: Mesh, batch_dim: Optional[int] = None):
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if batch_dim is not None:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if batch_dim % size != 0:
            # long_500k-style tiny batches: fall back to replication
            return None
    return axes if len(axes) > 1 else axes[0]


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(structs, shardings) for a training batch dict."""
    b = shape.global_batch
    st = registry.text_len(cfg, shape.seq_len)
    ba = _batch_axes(mesh, b)
    structs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, st), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, st), jnp.int32),
    }
    shards: Dict[str, Any] = {
        "tokens": NamedSharding(mesh, P(ba, None)),
        "labels": NamedSharding(mesh, P(ba, None)),
    }
    if cfg.frontend == "vision":
        structs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
        shards["prefix_embeds"] = NamedSharding(mesh, P(ba, None, None))
    if cfg.frontend == "audio":
        structs["frame_embeds"] = jax.ShapeDtypeStruct(
            (b, max(1, shape.seq_len // cfg.src_ratio), cfg.d_model),
            jnp.float32)
        shards["frame_embeds"] = NamedSharding(mesh, P(ba, None, None))
    return structs, shards


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                       ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    b = shape.global_batch
    ba = _batch_axes(mesh, b)
    structs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    shards: Dict[str, Any] = {
        "tokens": NamedSharding(mesh, P(ba, None))}
    if registry.is_encdec(cfg):
        src = max(1, shape.seq_len // cfg.src_ratio)
        structs["memory"] = jax.ShapeDtypeStruct(
            (b, src, cfg.d_model), jnp.bfloat16)
        shards["memory"] = NamedSharding(mesh, P(ba, None, None))
    return structs, shards


def param_structs_and_shardings(cfg: ModelConfig, mesh: Mesh,
                                rules: L.AxisRules, *,
                                dtype=None):
    specs = registry.param_specs(cfg)
    if dtype is not None:
        specs = jax.tree.map(
            lambda s: dataclasses.replace(s, dtype=dtype), specs,
            is_leaf=lambda x: isinstance(x, L.ParamSpec))
    structs = L.spec_tree_structs(specs)
    shardings = L.spec_tree_shardings(specs, mesh, rules)
    return specs, structs, shardings


def train_state_specs(cfg: ModelConfig, mesh: Mesh, rules: L.AxisRules):
    """(structs, shardings) for {"params", "opt"} train state."""
    specs, p_structs, p_shards = param_structs_and_shardings(
        cfg, mesh, rules)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    m_structs = jax.tree.map(f32, p_structs)
    structs = {
        "params": p_structs,
        "opt": {"m": m_structs, "v": m_structs,
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    shardings = {
        "params": p_shards,
        "opt": {"m": p_shards, "v": p_shards,
                "step": NamedSharding(mesh, P())},
    }
    return structs, shardings


def cache_structs_and_shardings(cfg: ModelConfig, shape: ShapeConfig,
                                mesh: Mesh, rules: L.AxisRules):
    specs = registry.cache_specs(cfg, shape.global_batch, shape.seq_len)
    return (L.spec_tree_structs(specs),
            L.spec_tree_shardings(specs, mesh, rules))
