"""GQA attention: reference einsum implementation (used for lowering/dry-run
and CPU smoke tests) plus the dispatch point for the Pallas flash kernel shim.

The reference path is deliberately written so XLA SPMD can shard it either by
heads (``kv_heads -> model``) or by cache sequence (``kv_seq -> model``); in
the latter case the softmax max/sum reductions over the sharded axis lower to
the expected all-reduces (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, hq, dh), (L.EMBED, L.HEADS, L.HEAD_DIM)),
        "wk": ParamSpec((d, hkv, dh), (L.EMBED, L.KV_HEADS, L.HEAD_DIM)),
        "wv": ParamSpec((d, hkv, dh), (L.EMBED, L.KV_HEADS, L.HEAD_DIM)),
        "wo": ParamSpec((hq, dh, d), (L.HEADS, L.HEAD_DIM, L.EMBED)),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((hq, dh), (L.HEADS, L.HEAD_DIM), init="zeros")
        specs["bk"] = ParamSpec((hkv, dh), (L.KV_HEADS, L.HEAD_DIM),
                                init="zeros")
        specs["bv"] = ParamSpec((hkv, dh), (L.KV_HEADS, L.HEAD_DIM),
                                init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((dh,), (L.HEAD_DIM,), init="ones")
        specs["k_norm"] = ParamSpec((dh,), (L.HEAD_DIM,), init="ones")
    return specs


def _rms(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def project_qkv(params: dict, x: jax.Array, cfg: ModelConfig, rules,
                positions: Optional[jax.Array], *, use_rope: bool = True,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = _rms(q, params["q_norm"], cfg.norm_eps)
        k = _rms(k, params["k_norm"], cfg.norm_eps)
    if use_rope and positions is not None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    q = L.constrain(q, rules, (L.BATCH, L.SEQ, L.HEADS, L.HEAD_DIM))
    k = L.constrain(k, rules, (L.BATCH, L.SEQ, L.KV_HEADS, L.HEAD_DIM))
    v = L.constrain(v, rules, (L.BATCH, L.SEQ, L.KV_HEADS, L.HEAD_DIM))
    return q, k, v


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array,
               mask: Optional[jax.Array], cfg: ModelConfig, rules,
               ctx_sharded: bool = False) -> jax.Array:
    """q: (B,S,Hq,Dh); k,v: (B,T,Hkv,Dh); mask broadcastable to (B,1,1,S,T).

    ``ctx_sharded`` pins the score/probability matrices KV_SEQ-sharded
    (context parallelism): SPMD propagation alone prefers all-gathering k
    and replicating the S×T scores (verified in §Perf A1), so the
    constraint must sit on the scores themselves; XLA then inserts the
    softmax max/sum all-reduces and the pv partial-sum psum.
    """
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    groups = hq // hkv
    qg = q.reshape(b, s, hkv, groups, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k) * scale
    scores = scores.astype(jnp.float32)
    score_axes = (L.BATCH, L.KV_HEADS, None, L.SEQ, L.KV_SEQ)
    if ctx_sharded:
        scores = L.constrain(scores, rules, score_axes)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    if ctx_sharded:
        probs = L.constrain(probs, rules, score_axes)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    out = out.reshape(b, s, hq, dh)
    return L.constrain(out, rules, (L.BATCH, L.SEQ, L.HEADS, L.HEAD_DIM))


def causal_mask(s: int, t: int, offset: int = 0) -> jax.Array:
    """(1,1,1,S,T) boolean mask: query i attends to keys j <= i + offset."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    return (kj <= qi)[None, None, None]


def self_attention(params: dict, x: jax.Array, cfg: ModelConfig, rules,
                   positions: Optional[jax.Array] = None,
                   causal: bool = True) -> jax.Array:
    """Full-sequence self attention (training / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = project_qkv(params, x, cfg, rules, positions)
    if cfg.shard_ctx_train:
        # context-parallel attention (§Perf hillclimb): shard k/v over the
        # model axis along SEQUENCE; XLA inserts the softmax/psum
        # collectives, dividing score memory and attention compute by the
        # TP degree even when head counts don't divide the mesh axis.
        k = L.constrain(k, rules, (L.BATCH, L.KV_SEQ, L.KV_HEADS,
                                   L.HEAD_DIM))
        v = L.constrain(v, rules, (L.BATCH, L.KV_SEQ, L.KV_HEADS,
                                   L.HEAD_DIM))
    if cfg.attn_impl == "flash" and causal:
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=True)
    else:
        mask = causal_mask(s, s) if causal else None
        out = gqa_attend(q, k, v, mask, cfg, rules,
                         ctx_sharded=cfg.shard_ctx_train)
    dt = x.dtype
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))


def cross_attention(params: dict, x: jax.Array, memory: jax.Array,
                    cfg: ModelConfig, rules) -> jax.Array:
    """Decoder->encoder attention (enc-dec archs). No causal mask, no rope."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", memory, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", memory, params["wv"].astype(dt))
    out = gqa_attend(q, k, v, None, cfg, rules)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))


# ---------------------------------------------------------------------------
# KV-cache decode path (TextIsland / KVStore engine feeds these tensors)
# ---------------------------------------------------------------------------
def kv_cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    kv_axes = (L.BATCH, L.KV_SEQ, L.KV_HEADS, L.HEAD_DIM)
    if cfg.kv_cache_dtype == "int8":
        # quant_cast pages (the Migrator's int8 binary cast applied to the
        # serving cache): 1B/elem + one f32 scale per (token, head)
        sc_axes = (L.BATCH, L.KV_SEQ, L.KV_HEADS, None)
        return {
            "k": ParamSpec((batch, cache_len, hkv, dh), kv_axes,
                           dtype=jnp.int8, init="zeros"),
            "v": ParamSpec((batch, cache_len, hkv, dh), kv_axes,
                           dtype=jnp.int8, init="zeros"),
            "k_scale": ParamSpec((batch, cache_len, hkv, 1), sc_axes,
                                 dtype=jnp.float32, init="zeros"),
            "v_scale": ParamSpec((batch, cache_len, hkv, 1), sc_axes,
                                 dtype=jnp.float32, init="zeros"),
        }
    return {
        "k": ParamSpec((batch, cache_len, hkv, dh), kv_axes,
                       dtype=jnp.bfloat16, init="zeros"),
        "v": ParamSpec((batch, cache_len, hkv, dh), kv_axes,
                       dtype=jnp.bfloat16, init="zeros"),
    }


def _quant_heads(x: jax.Array):
    """Per-(token, head) int8 quantization of (B,S,H,Dh) k/v tensors."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                     keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequant_heads(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def write_kv(cache: dict, k_new: jax.Array, v_new: jax.Array, pos,
             cfg: ModelConfig) -> dict:
    """Write a [pos, pos+S) span of k/v into the cache (codec-aware)."""
    new_cache = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_heads(k_new)
        vq, vs = _quant_heads(v_new)
        writes = (("k", kq), ("v", vq), ("k_scale", ks), ("v_scale", vs))
    else:
        writes = (("k", k_new), ("v", v_new))
    for name, val in writes:
        new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], val.astype(cache[name].dtype), pos, axis=1)
    return new_cache


def decode_attention(params: dict, x: jax.Array, cache: dict,
                     pos: jax.Array, cfg: ModelConfig, rules
                     ) -> Tuple[jax.Array, dict]:
    """One-token decode: write (k,v) at ``pos``, attend over cache[:pos+1].

    x: (B, 1, D); pos: scalar int32 (same position for the whole batch — the
    serve scheduler aligns slots); cache k/v: (B, T, Hkv, Dh).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = project_qkv(params, x, cfg, rules, positions)
    new_cache = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant_heads(k_new)
        vq, vs = _quant_heads(v_new)
        for name, val in (("k", kq), ("v", vq), ("k_scale", ks),
                          ("v_scale", vs)):
            new_cache[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], val.astype(cache[name].dtype), pos, axis=1)
        k_att = _dequant_heads(new_cache["k"], new_cache["k_scale"],
                               q.dtype)
        v_att = _dequant_heads(new_cache["v"], new_cache["v_scale"],
                               q.dtype)
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
        new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
        k_att = new_cache["k"].astype(q.dtype)
        v_att = new_cache["v"].astype(q.dtype)
    t = k_att.shape[1]
    mask = (jnp.arange(t)[None, None, None, None, :] <= pos)
    out = gqa_attend(q, k_att, v_att, mask, cfg, rules)
    dt = x.dtype
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    out = L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))
    return out, new_cache
