"""Unified model configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False             # RMSNorm on q/k heads (olmoe)
    parallel_block: bool = False      # cohere-style: attn and ffn in parallel
    rope_theta: float = 10000.0
    attn_impl: str = "reference"      # reference | flash (Pallas)

    # norms / ffn
    norm_kind: str = "rmsnorm"        # rmsnorm | layernorm
    mlp_kind: str = "swiglu"          # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    moe_every: int = 1                # MoE ffn on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    first_k_dense: int = 0            # leading dense layers (deepseek-moe)
    dense_d_ff: int = 0               # d_ff for those leading dense layers
    capacity_factor: float = 1.25     # capacity path only (moe_dropless=False)
    # Dropless (exact) MoE is the reference semantic: forward ≡ decode and
    # per-token results don't depend on batch composition.  The capacity-
    # clipped sort dispatch is the at-scale training approximation; the
    # launch dry-run opts into it explicitly (see moe.py docstring).
    moe_dropless: bool = True

    # hybrid / ssm
    attn_every: int = 1               # attention on layers where i % attn_every == attn_offset
    attn_offset: int = 0
    ssm_kind: str = ""                # "" | rwkv6 | mamba
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64

    # encoder-decoder
    encoder_layers: int = 0           # >0 => enc-dec (audio family)
    cross_attention: bool = False

    # modality frontends (STUB: precomputed embeddings via input_specs)
    frontend: str = ""                # "" | vision | audio
    num_prefix_embeds: int = 0        # vision patches prepended to the sequence
    src_ratio: int = 4                # enc-dec: src_len = seq_len // src_ratio

    # training-time knobs
    remat: str = "block"              # none | block | full
    scan_layers: bool = True

    # perf knobs (EXPERIMENTS.md §Perf)
    vocab_pad_to: int = 0             # pad vocab so it shards (hillclimb)
    kv_cache_dtype: str = "bf16"      # bf16 | int8 (quant_cast pages)
    shard_ctx_train: bool = False     # shard k/v sequence in training attn
    # §Perf MoE iteration: constraining the dispatch buffers (EXPERT→model,
    # CAPACITY→data) makes SPMD lower the expert scatter 8× worse than
    # propagation-placed dispatch — measured in EXPERIMENTS.md §Perf; the
    # constrained variant remains available for A/B via this knob.
    moe_cap_shard: bool = False

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to and self.vocab_size % self.vocab_pad_to:
            return self.vocab_size + (
                self.vocab_pad_to - self.vocab_size % self.vocab_pad_to)
        return self.vocab_size

    def __post_init__(self):
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.num_experts:
            assert self.top_k > 0 and self.moe_d_ff > 0, self.name

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.ssm_kind != "" and self.attn_every == 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM and hybrid archs only (DESIGN.md §4)."""
        return self.ssm_kind != ""

    @property
    def decoder_layers(self) -> int:
        return self.num_layers

    def layer_plan(self) -> Tuple[Tuple[str, str], ...]:
        """Returns ((mixer_kind, ffn_kind), ...) for one scan period.

        mixer: 'attn' | 'rwkv6' | 'mamba';  ffn: 'dense' | 'moe' | 'rwkv_cm'.
        Period = number of distinct sub-layer slots in the repeating pattern.
        """
        if self.ssm_kind == "rwkv6":
            return (("rwkv6", "rwkv_cm"),)
        period = 1
        if self.ssm_kind:                 # hybrid (jamba)
            period = max(period, self.attn_every)
        if self.is_moe:
            period = _lcm(period, self.moe_every)
        plan = []
        for i in range(period):
            if self.ssm_kind and not (
                    self.attn_every and i % self.attn_every == self.attn_offset):
                mixer = self.ssm_kind
            else:
                mixer = "attn"
            if self.is_moe and i % self.moe_every == self.moe_offset:
                ffn = "moe"
            else:
                ffn = "dense"
            plan.append((mixer, ffn))
        return tuple(plan)

    def scan_period(self) -> int:
        return len(self.layer_plan())

    def num_scanned(self) -> int:
        body = self.num_layers - self.first_k_dense
        period = self.scan_period()
        assert body % period == 0, (self.name, body, period)
        return body // period


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)
