"""Encoder-decoder transformer (seamless-m4t family, arXiv:2308.11596).

The speech frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings (B, S_src, D); this module implements the
transformer backbone (encoder, causal decoder with cross-attention).
Positions are learned absolute embeddings (NLLB-style), no rope.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.models.config import ModelConfig
from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec

MAX_POSITIONS = 32768


def _enc_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        "attn": attention.attn_specs(cfg),
        "ln2": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        "ffn": layers.ffn_specs(cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def _dec_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        "self_attn": attention.attn_specs(cfg),
        "ln_x": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        "cross_attn": attention.attn_specs(cfg, cross=True),
        "ln2": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        "ffn": layers.ffn_specs(cfg.d_model, cfg.d_ff, cfg.mlp_kind),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": layers.embed_specs(cfg.padded_vocab, cfg.d_model,
                                    cfg.tie_embeddings),
        "pos_embed": ParamSpec((MAX_POSITIONS, cfg.d_model),
                               (None, L.EMBED), init="embed_normal"),
        "enc_blocks": layers.stack_specs(_enc_block_specs(cfg),
                                         cfg.encoder_layers),
        "enc_norm": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        "dec_blocks": layers.stack_specs(_dec_block_specs(cfg),
                                         cfg.num_layers),
        "final_norm": layers.norm_specs(cfg.d_model, cfg.norm_kind),
    }


def _add_positions(params, x: jax.Array, offset) -> jax.Array:
    s = x.shape[1]
    pos = jax.lax.dynamic_slice_in_dim(
        params["pos_embed"], jnp.asarray(offset, jnp.int32), s, axis=0)
    return x + pos[None].astype(x.dtype)


def encode(params, frame_embeds: jax.Array, cfg: ModelConfig, rules=None
           ) -> jax.Array:
    """frame_embeds: (B, S_src, D) stub frontend output -> encoder memory."""
    x = frame_embeds.astype(jnp.bfloat16)
    x = _add_positions(params, x, 0)
    x = L.constrain(x, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))

    def body(xc, block):
        h = layers.apply_norm(block["ln1"], xc, cfg.norm_kind, cfg.norm_eps)
        xc = xc + attention.self_attention(block["attn"], h, cfg, rules,
                                           causal=False)
        h = layers.apply_norm(block["ln2"], xc, cfg.norm_kind, cfg.norm_eps)
        xc = xc + layers.apply_ffn(block["ffn"], h, cfg.mlp_kind, rules)
        xc = L.constrain(xc, rules, (L.BATCH, L.RESID, L.ACT_EMBED))
        return xc, None

    if cfg.remat in ("block", "full"):
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda p: p[i],
                                        params["enc_blocks"]))
    return layers.apply_norm(params["enc_norm"], x, cfg.norm_kind,
                             cfg.norm_eps)


def _dec_block(block, x, memory, cfg, rules, *, cache=None, pos=None,
               mode="train"):
    h = layers.apply_norm(block["ln1"], x, cfg.norm_kind, cfg.norm_eps)
    if mode == "train":
        x = x + attention.self_attention(block["self_attn"], h, cfg, rules)
        new_kv = None
    elif mode == "prefill":
        x = x + attention.self_attention(block["self_attn"], h, cfg, rules)
        s = h.shape[1]
        positions = jnp.arange(s)[None, :]
        _, k, v = attention.project_qkv(block["self_attn"], h, cfg, rules,
                                        positions)
        new_kv = attention.write_kv(cache, k, v, 0, cfg)
    else:
        out, new_kv = attention.decode_attention(block["self_attn"], h,
                                                 cache, pos, cfg, rules)
        x = x + out

    h = layers.apply_norm(block["ln_x"], x, cfg.norm_kind, cfg.norm_eps)
    x = x + attention.cross_attention(block["cross_attn"], h, memory, cfg,
                                      rules)
    h = layers.apply_norm(block["ln2"], x, cfg.norm_kind, cfg.norm_eps)
    x = x + layers.apply_ffn(block["ffn"], h, cfg.mlp_kind, rules)
    return x, new_kv


def _run_decoder(params, x, memory, cfg, rules, *, cache=None, pos=None,
                 mode="train"):
    def body(xc, scanned):
        if cache is not None:
            block, kv = scanned
        else:
            block, kv = scanned, None
        xc, new_kv = _dec_block(block, xc, memory, cfg, rules, cache=kv,
                                pos=pos, mode=mode)
        if mode == "train":
            xc = L.constrain(xc, rules, (L.BATCH, L.RESID, L.ACT_EMBED))
        return xc, new_kv

    if cfg.remat in ("block", "full"):
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        xs = (params["dec_blocks"], cache) if cache is not None \
            else params["dec_blocks"]
        x, new_cache = jax.lax.scan(body, x, xs)
        return x, new_cache
    collected = []
    for i in range(cfg.num_layers):
        block = jax.tree.map(lambda p: p[i], params["dec_blocks"])
        if cache is not None:
            kv = jax.tree.map(lambda c: c[i], cache)
            x, new_kv = body(x, (block, kv))
            collected.append(new_kv)
        else:
            x, _ = body(x, block)
    new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *collected)
                 if cache is not None else None)
    return x, new_cache


def forward(params, tokens, frame_embeds, cfg: ModelConfig, rules=None
            ) -> Tuple[jax.Array, jax.Array]:
    """Training: (tokens (B,S_tgt), frames (B,S_src,D)) -> (logits, aux=0)."""
    memory = encode(params, frame_embeds, cfg, rules)
    x = layers.embed_tokens(params["embed"], tokens, rules)
    x = _add_positions(params, x, 0)
    x, _ = _run_decoder(params, x, memory, cfg, rules, mode="train")
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_kind,
                          cfg.norm_eps)
    logits = layers.logits_out(params["embed"], x, rules)
    return logits, jnp.zeros((), jnp.float32)


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    kv = attention.kv_cache_specs(cfg, batch, cache_len)
    return layers.stack_specs(kv, cfg.num_layers)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, cache_len),
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def prefill(params, tokens, frame_embeds, cache, cfg: ModelConfig,
            rules=None):
    memory = encode(params, frame_embeds, cfg, rules)
    x = layers.embed_tokens(params["embed"], tokens, rules)
    x = _add_positions(params, x, 0)
    x, new_cache = _run_decoder(params, x, memory, cfg, rules, cache=cache,
                                mode="prefill")
    x = layers.apply_norm(params["final_norm"], x[:, -1:], cfg.norm_kind,
                          cfg.norm_eps)
    return layers.logits_out(params["embed"], x, rules), new_cache, memory


def decode_step(params, tokens, memory, cache, pos, cfg: ModelConfig,
                rules=None):
    """tokens (B,1); memory (B,S_src,D) fixed encoder output."""
    x = layers.embed_tokens(params["embed"], tokens, rules)
    s_idx = jnp.asarray(pos, jnp.int32)
    pos_vec = jax.lax.dynamic_slice_in_dim(params["pos_embed"], s_idx, 1,
                                           axis=0)
    x = x + pos_vec[None].astype(x.dtype)
    x, new_cache = _run_decoder(params, x, memory, cfg, rules, cache=cache,
                                pos=pos, mode="decode")
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_kind,
                          cfg.norm_eps)
    return layers.logits_out(params["embed"], x, rules), new_cache
