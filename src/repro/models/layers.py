"""Shared building blocks for the model zoo: norms, embeddings, rotary,
feed-forward variants.  Pure-functional JAX; params are pytrees described by
``ParamSpec`` (sharding/logical.py) so every tensor carries logical axes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------
def norm_specs(d_model: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": ParamSpec((d_model,), (L.EMBED,), init="ones")}
    if kind == "layernorm":
        return {"scale": ParamSpec((d_model,), (L.EMBED,), init="ones"),
                "bias": ParamSpec((d_model,), (L.EMBED,), init="zeros")}
    raise ValueError(kind)


def apply_norm(params: dict, x: jax.Array, kind: str,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + eps)
        out = x * params["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mean) * jax.lax.rsqrt(var + eps)
        out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32)
    else:
        raise ValueError(kind)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_specs(vocab: int, d_model: int, tie: bool) -> dict:
    specs = {"embedding": ParamSpec((vocab, d_model), (L.VOCAB, L.EMBED),
                                    init="embed_normal")}
    if not tie:
        specs["unembed"] = ParamSpec((d_model, vocab), (L.EMBED, L.VOCAB),
                                     init="normal")
    return specs


def embed_tokens(params: dict, tokens: jax.Array, rules,
                 compute_dtype=jnp.bfloat16) -> jax.Array:
    table = params["embedding"].astype(compute_dtype)
    x = jnp.take(table, tokens, axis=0)
    return L.constrain(x, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))


def logits_out(params: dict, x: jax.Array, rules,
               softcap: float = 0.0) -> jax.Array:
    if "unembed" in params:
        w = params["unembed"].astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    else:
        w = params["embedding"].astype(x.dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    logits = logits.astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return L.constrain(logits, rules, (L.BATCH, L.SEQ, L.VOCAB))


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)          # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)          # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense feed-forward variants
# ---------------------------------------------------------------------------
def ffn_specs(d_model: int, d_ff: int, kind: str) -> dict:
    if kind == "swiglu":
        return {
            "wi_gate": ParamSpec((d_model, d_ff), (L.EMBED, L.MLP)),
            "wi_up": ParamSpec((d_model, d_ff), (L.EMBED, L.MLP)),
            "wo": ParamSpec((d_ff, d_model), (L.MLP, L.EMBED)),
        }
    if kind == "gelu":
        return {
            "wi": ParamSpec((d_model, d_ff), (L.EMBED, L.MLP)),
            "bi": ParamSpec((d_ff,), (L.MLP,), init="zeros"),
            "wo": ParamSpec((d_ff, d_model), (L.MLP, L.EMBED)),
            "bo": ParamSpec((d_model,), (L.EMBED,), init="zeros"),
        }
    raise ValueError(kind)


def apply_ffn(params: dict, x: jax.Array, kind: str, rules) -> jax.Array:
    dt = x.dtype
    if kind == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(dt))
        up = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(dt))
        h = jax.nn.silu(gate) * up
        h = L.constrain(h, rules, (L.BATCH, L.SEQ, L.MLP))
        out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))
    elif kind == "gelu":
        h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dt))
        h = jax.nn.gelu(h + params["bi"].astype(dt))
        h = L.constrain(h, rules, (L.BATCH, L.SEQ, L.MLP))
        out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt)) \
            + params["bo"].astype(dt)
    else:
        raise ValueError(kind)
    return L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))


# ---------------------------------------------------------------------------
# Tree utilities for scanned (stacked) layers
# ---------------------------------------------------------------------------
def stack_specs(spec_tree, n: int):
    """Prepend a LAYER axis of size n to every ParamSpec in a tree."""
    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (L.LAYER,) + s.axes, dtype=s.dtype,
                         init=s.init, init_scale=s.init_scale)
    return jax.tree.map(_stack, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
