"""Unified decoder-only language model covering the dense / moe / ssm /
hybrid / vlm families of the assigned pool.

Layers are scanned (lax.scan over stacked params) with a configurable period:
dense archs scan single blocks, Jamba scans period-8 super-blocks (7 mamba +
1 attention, MoE on odd sub-layers).  HLO size is therefore depth-independent,
which is what makes the 104B dry-run compile on a CPU host (DESIGN.md §5).

Three entry points:
  forward(...)       — full-sequence training forward -> (logits, aux)
  prefill(...)       — full-sequence forward that also fills caches/states
  decode_step(...)   — one-token step against caches/states (serve path)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, moe, rwkv6
from repro.models.config import ModelConfig
from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def _mixer_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "attn":
        return attention.attn_specs(cfg)
    if kind == "rwkv6":
        return rwkv6.time_mix_specs(cfg)
    if kind == "mamba":
        return mamba.mamba_specs(cfg)
    raise ValueError(kind)


def _ffn_specs(cfg: ModelConfig, kind: str, *, dense_ff: int = 0) -> dict:
    if kind == "dense":
        return layers.ffn_specs(cfg.d_model, dense_ff or cfg.d_ff,
                                cfg.mlp_kind)
    if kind == "moe":
        return moe.moe_specs(cfg)
    if kind == "rwkv_cm":
        return rwkv6.channel_mix_specs(cfg)
    raise ValueError(kind)


def _block_specs(cfg: ModelConfig, plan) -> dict:
    specs: Dict[str, Any] = {}
    for i, (mixer_kind, ffn_kind) in enumerate(plan):
        sub = {
            "mixer": _mixer_specs(cfg, mixer_kind),
            "ffn": _ffn_specs(cfg, ffn_kind),
            "ln1": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        }
        if not cfg.parallel_block:
            sub["ln2"] = layers.norm_specs(cfg.d_model, cfg.norm_kind)
        specs[f"sub{i}"] = sub
    return specs


def param_specs(cfg: ModelConfig) -> dict:
    plan = cfg.layer_plan()
    specs: Dict[str, Any] = {
        "embed": layers.embed_specs(cfg.padded_vocab, cfg.d_model,
                                    cfg.tie_embeddings),
        "final_norm": layers.norm_specs(cfg.d_model, cfg.norm_kind),
    }
    # prologue: leading dense layers outside the scan (deepseek-moe)
    for j in range(cfg.first_k_dense):
        sub = {
            "mixer": _mixer_specs(cfg, "attn"),
            "ffn": _ffn_specs(cfg, "dense", dense_ff=cfg.dense_d_ff),
            "ln1": layers.norm_specs(cfg.d_model, cfg.norm_kind),
        }
        if not cfg.parallel_block:
            sub["ln2"] = layers.norm_specs(cfg.d_model, cfg.norm_kind)
        specs[f"prologue{j}"] = sub
    specs["blocks"] = layers.stack_specs(_block_specs(cfg, plan),
                                         cfg.num_scanned())
    return specs


# ---------------------------------------------------------------------------
# Cache specs (serve path) — registered as KVStore objects by the catalog
# ---------------------------------------------------------------------------
def _sub_cache_specs(cfg: ModelConfig, mixer_kind: str, ffn_kind: str,
                     batch: int, cache_len: int) -> dict:
    out: Dict[str, Any] = {}
    if mixer_kind == "attn":
        out["kv"] = attention.kv_cache_specs(cfg, batch, cache_len)
    elif mixer_kind == "rwkv6":
        out["time"] = rwkv6.init_time_state(cfg, batch)
    elif mixer_kind == "mamba":
        out["ssm"] = mamba.init_mamba_state(cfg, batch)
    if ffn_kind == "rwkv_cm":
        out["channel"] = rwkv6.init_channel_state(cfg, batch)
    return out


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    plan = cfg.layer_plan()
    specs: Dict[str, Any] = {}
    for j in range(cfg.first_k_dense):
        specs[f"prologue{j}"] = _sub_cache_specs(cfg, "attn", "dense",
                                                 batch, cache_len)
    block = {f"sub{i}": _sub_cache_specs(cfg, mk, fk, batch, cache_len)
             for i, (mk, fk) in enumerate(plan)}
    specs["blocks"] = layers.stack_specs(block, cfg.num_scanned())
    return specs


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch,
                                                           cache_len),
        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Sub-layer application
# ---------------------------------------------------------------------------
def _apply_mixer(params, x, kind, cfg, rules, *, cache=None, pos=None,
                 mode="train"):
    """Returns (out, new_cache). cache is the mixer's state dict or None."""
    if kind == "attn":
        if mode == "train":
            return attention.self_attention(params, x, cfg, rules), None
        if mode == "prefill":
            s = x.shape[1]
            out = attention.self_attention(params, x, cfg, rules)
            # fill the cache with this sequence's k/v (codec-aware)
            positions = jnp.arange(s)[None, :]
            _, k, v = attention.project_qkv(params, x, cfg, rules, positions)
            return out, {"kv": attention.write_kv(cache["kv"], k, v, 0,
                                                  cfg)}
        # decode
        out, kv = attention.decode_attention(params, x, cache["kv"], pos,
                                             cfg, rules)
        return out, {"kv": kv}
    if kind == "rwkv6":
        state = cache["time"] if cache is not None else None
        if mode == "train":
            out, _ = rwkv6.apply_time_mix(params, x, cfg, rules, None)
            return out, None
        out, new = rwkv6.apply_time_mix(params, x, cfg, rules, state)
        return out, {"time": new}
    if kind == "mamba":
        state = cache["ssm"] if cache is not None else None
        if mode == "train":
            out, _ = mamba.apply_mamba(params, x, cfg, rules, None)
            return out, None
        out, new = mamba.apply_mamba(params, x, cfg, rules, state)
        return out, {"ssm": new}
    raise ValueError(kind)


def _apply_ffn(params, x, kind, cfg, rules, *, cache=None, mode="train"):
    if kind == "dense":
        return layers.apply_ffn(params, x, cfg.mlp_kind, rules), None, 0.0
    if kind == "moe":
        out, aux = moe.apply_moe(params, x, cfg, rules)
        return out, None, aux
    if kind == "rwkv_cm":
        state = cache["channel"] if cache is not None else None
        if mode == "train":
            out, _ = rwkv6.apply_channel_mix(params, x, cfg, rules, None)
            return out, None, 0.0
        out, new = rwkv6.apply_channel_mix(params, x, cfg, rules, state)
        return out, {"channel": new}, 0.0
    raise ValueError(kind)


def _apply_sub(sub_params, x, mixer_kind, ffn_kind, cfg, rules, *,
               cache=None, pos=None, mode="train"):
    """One (mixer + ffn) sub-layer with residuals. Returns (x, cache, aux)."""
    mixer_cache = cache if cache is not None else None
    if cfg.parallel_block:
        h = layers.apply_norm(sub_params["ln1"], x, cfg.norm_kind,
                              cfg.norm_eps)
        attn_out, new_mixer = _apply_mixer(
            sub_params["mixer"], h, mixer_kind, cfg, rules,
            cache=mixer_cache, pos=pos, mode=mode)
        ffn_out, new_ffn, aux = _apply_ffn(
            sub_params["ffn"], h, ffn_kind, cfg, rules, cache=mixer_cache,
            mode=mode)
        x = x + attn_out + ffn_out
    else:
        h = layers.apply_norm(sub_params["ln1"], x, cfg.norm_kind,
                              cfg.norm_eps)
        attn_out, new_mixer = _apply_mixer(
            sub_params["mixer"], h, mixer_kind, cfg, rules,
            cache=mixer_cache, pos=pos, mode=mode)
        x = x + attn_out
        h = layers.apply_norm(sub_params["ln2"], x, cfg.norm_kind,
                              cfg.norm_eps)
        ffn_out, new_ffn, aux = _apply_ffn(
            sub_params["ffn"], h, ffn_kind, cfg, rules, cache=mixer_cache,
            mode=mode)
        x = x + ffn_out
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        for upd in (new_mixer, new_ffn):
            if upd:
                new_cache.update(upd)
    return x, new_cache, aux


def _apply_block(block_params, x, plan, cfg, rules, *, cache=None, pos=None,
                 mode="train"):
    """One scan period (all sub-layers). Returns (x, new_cache, aux_sum)."""
    aux_sum = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    for i, (mixer_kind, ffn_kind) in enumerate(plan):
        sub_cache = cache[f"sub{i}"] if cache is not None else None
        x, sc, aux = _apply_sub(
            block_params[f"sub{i}"], x, mixer_kind, ffn_kind, cfg, rules,
            cache=sub_cache, pos=pos, mode=mode)
        # sequence-parallel residual: saved scan carries are seq-sharded
        if mode == "train":
            x = L.constrain(x, rules, (L.BATCH, L.RESID, L.ACT_EMBED))
        aux_sum = aux_sum + jnp.asarray(aux, jnp.float32)
        if cache is not None:
            new_cache[f"sub{i}"] = sc
    return x, (new_cache if cache is not None else None), aux_sum


# ---------------------------------------------------------------------------
# Full model passes
# ---------------------------------------------------------------------------
def _embed_inputs(params, tokens, cfg, rules, prefix_embeds=None,
                  compute_dtype=jnp.bfloat16):
    x = layers.embed_tokens(params["embed"], tokens, rules,
                            compute_dtype=compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(compute_dtype), x], axis=1)
        x = L.constrain(x, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))
    return x


def _run_stack(params, x, cfg, rules, *, cache=None, pos=None, mode="train"):
    """Prologue layers then the scanned stack. Returns (x, cache, aux)."""
    plan = cfg.layer_plan()
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {} if cache is not None else None

    for j in range(cfg.first_k_dense):
        sub_cache = cache[f"prologue{j}"] if cache is not None else None
        x, sc, aux = _apply_sub(params[f"prologue{j}"], x, "attn", "dense",
                                cfg, rules, cache=sub_cache, pos=pos,
                                mode=mode)
        aux_total = aux_total + aux
        if cache is not None:
            new_cache[f"prologue{j}"] = sc

    def body(carry, scanned):
        xc, aux_acc = carry
        if cache is not None:
            block_p, block_c = scanned
        else:
            block_p, block_c = scanned, None
        xc, bc, aux = _apply_block(block_p, xc, plan, cfg, rules,
                                   cache=block_c, pos=pos, mode=mode)
        return (xc, aux_acc + aux), bc

    if cfg.remat in ("block", "full"):
        body = jax.checkpoint(body)

    if cfg.scan_layers:
        xs = (params["blocks"], cache["blocks"]) if cache is not None \
            else params["blocks"]
        (x, aux_total2), block_caches = jax.lax.scan(body, (x, aux_total),
                                                     xs)
        if cache is not None:
            new_cache["blocks"] = block_caches
        return x, new_cache, aux_total2

    # unrolled path: exact cost_analysis (XLA counts while bodies once, so
    # the dry-run cost probe lowers with scan_layers=False; DESIGN.md §7)
    n = cfg.num_scanned()
    carry = (x, aux_total)
    collected = []
    for i in range(n):
        block_p = jax.tree.map(lambda p: p[i], params["blocks"])
        if cache is not None:
            block_c = jax.tree.map(lambda c: c[i], cache["blocks"])
            carry, bc = body(carry, (block_p, block_c))
            collected.append(bc)
        else:
            carry, _ = body(carry, block_p)
    x, aux_total2 = carry
    if cache is not None:
        new_cache["blocks"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *collected)
    return x, new_cache, aux_total2


def forward(params, tokens, cfg: ModelConfig, rules=None,
            prefix_embeds=None) -> Tuple[jax.Array, jax.Array]:
    """Training forward: tokens (B,S_text) -> (logits (B,S,V), aux_loss)."""
    x = _embed_inputs(params, tokens, cfg, rules, prefix_embeds)
    x, _, aux = _run_stack(params, x, cfg, rules, mode="train")
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_kind,
                          cfg.norm_eps)
    logits = layers.logits_out(params["embed"], x, rules,
                               softcap=cfg.logit_softcap)
    return logits, aux


def prefill(params, tokens, cache, cfg: ModelConfig, rules=None,
            prefix_embeds=None):
    """Fill caches for positions [0, S). Returns (last-token logits, cache)."""
    x = _embed_inputs(params, tokens, cfg, rules, prefix_embeds)
    x, new_cache, _ = _run_stack(params, x, cfg, rules, cache=cache,
                                 mode="prefill")
    x = layers.apply_norm(params["final_norm"], x[:, -1:], cfg.norm_kind,
                          cfg.norm_eps)
    logits = layers.logits_out(params["embed"], x, rules,
                               softcap=cfg.logit_softcap)
    return logits, new_cache


def decode_step(params, tokens, cache, pos, cfg: ModelConfig, rules=None):
    """One-token decode. tokens: (B,1); pos: scalar cache write position."""
    x = _embed_inputs(params, tokens, cfg, rules)
    x, new_cache, _ = _run_stack(params, x, cfg, rules, cache=cache, pos=pos,
                                 mode="decode")
    x = layers.apply_norm(params["final_norm"], x, cfg.norm_kind,
                          cfg.norm_eps)
    logits = layers.logits_out(params["embed"], x, rules,
                               softcap=cfg.logit_softcap)
    return logits, new_cache
