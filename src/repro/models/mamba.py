"""Mamba (selective SSM) mixer as used in Jamba (arXiv:2403.19887).

Reference implementation scans over time with lax.scan; the chunked Pallas
kernel lives in kernels/mamba_scan.  Decode is an O(1) state update.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def mamba_specs(cfg: ModelConfig) -> dict:
    d, di, n, r, cw = (cfg.d_model, d_inner(cfg), cfg.ssm_state,
                       dt_rank(cfg), cfg.ssm_conv)
    return {
        "in_proj": ParamSpec((d, 2 * di), (L.EMBED, L.MLP)),
        "conv_w": ParamSpec((cw, di), (L.CONV, L.MLP), init="normal"),
        "conv_b": ParamSpec((di,), (L.MLP,), init="zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), (L.MLP, None)),
        "dt_proj": ParamSpec((r, di), (None, L.MLP)),
        "dt_bias": ParamSpec((di,), (L.MLP,), init="zeros"),
        "a_log": ParamSpec((di, n), (L.MLP, L.STATE), init="zeros"),
        "d_skip": ParamSpec((di,), (L.MLP,), init="ones"),
        "out_proj": ParamSpec((di, d), (L.MLP, L.EMBED)),
        # Jamba stabilizes dt/B/C with RMSNorm scales
        "dt_norm": ParamSpec((r,), (None,), init="ones"),
        "b_norm": ParamSpec((n,), (L.STATE,), init="ones"),
        "c_norm": ParamSpec((n,), (L.STATE,), init="ones"),
    }


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict:
    di, n, cw = d_inner(cfg), cfg.ssm_state, cfg.ssm_conv
    return {
        "h": ParamSpec((batch, di, n), (L.BATCH, L.MLP, L.STATE),
                       dtype=jnp.float32, init="zeros"),
        "conv": ParamSpec((batch, cw - 1, di), (L.BATCH, L.CONV, L.MLP),
                          dtype=jnp.bfloat16, init="zeros"),
    }


def _rms(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array]) -> jax.Array:
    """Depthwise causal conv1d.  x: (B,S,Di); w: (CW,Di); prev: (B,CW-1,Di)."""
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)           # (B, S+CW-1, Di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None]
              for i in range(cw))
    return out + b[None, None]


def selective_scan(u: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, h0: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """u,dt: (B,S,Di); a: (Di,N); b,c: (B,S,N); h0: (B,Di,N) fp32.

      h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t u_t) ⊗ B_t;  y_t = h_t · C_t
    """
    def step(h, inp):
        ut, dtt, bt, ct = inp                         # (B,Di),(B,Di),(B,N)x2
        da = jnp.exp(dtt[..., None] * a[None])        # (B,Di,N)
        h = da * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_final


def apply_mamba(params: dict, x: jax.Array, cfg: ModelConfig, rules,
                state: Optional[dict] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    bsz, s, d = x.shape
    di, n, r = d_inner(cfg), cfg.ssm_state, dt_rank(cfg)
    dt_ = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    xz = L.constrain(xz, rules, (L.BATCH, L.SEQ, L.MLP))
    xin, z = jnp.split(xz, 2, axis=-1)

    prev_conv = state["conv"].astype(dt_) if state is not None else None
    xc = _causal_conv(xin, params["conv_w"].astype(dt_),
                      params["conv_b"].astype(dt_), prev_conv)
    xc = jax.nn.silu(xc)
    xc = L.constrain(xc, rules, (L.BATCH, L.SEQ, L.MLP))

    proj = jnp.einsum("bse,ep->bsp", xc, params["x_proj"].astype(dt_))
    dt_low, b_in, c_in = jnp.split(proj, [r, r + n], axis=-1)
    dt_low = _rms(dt_low, params["dt_norm"], cfg.norm_eps)
    b_in = _rms(b_in, params["b_norm"], cfg.norm_eps)
    c_in = _rms(c_in, params["c_norm"], cfg.norm_eps)
    dt_full = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_low, params["dt_proj"].astype(dt_))
        + params["dt_bias"].astype(dt_))

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    h0 = (state["h"] if state is not None
          else jnp.zeros((bsz, di, n), jnp.float32))
    y, h_final = selective_scan(xc, dt_full, a, b_in, c_in, h0)
    y = y.astype(dt_) + xc * params["d_skip"].astype(dt_)[None, None]

    out = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", out, params["out_proj"].astype(dt_))
    out = L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))

    new_state = None
    if state is not None:
        tail = jnp.concatenate([prev_conv, xin], axis=1)[:, -(cfg.ssm_conv - 1):]
        new_state = {"h": h_final, "conv": tail.astype(jnp.bfloat16)}
    return out, new_state
