"""Mixture-of-Experts FFN: dropless reference path + sort-based
(megablocks-style) capacity dispatch for the at-scale dry-run path.

Design notes (DESIGN.md §7):
* The *reference* path (``cfg.moe_dropless``, the default) is exactly
  dropless: every routed token-slot contributes, so a token's output
  depends only on its own row — forward ≡ prefill+decode and the result
  is invariant to what else shares the batch.  This matches the actual
  training recipes of the assigned MoE archs (OLMoE trains without token
  dropping, arXiv:2409.02060 §2; Jamba/DeepSeek-MoE serve dropless) and
  is the invariant the serve/bdml paths build on.  Capacity-clipped
  dispatch silently *dropped over-capacity slots* — and because dispatch
  sorts slots in token order, the drops land on the LAST tokens of the
  batch, exactly the positions decode recomputes exactly: that was the
  root cause of the olmoe-1b-7b decode/forward drift (and part of the
  jamba-v0.1-52b multi-step drift) carried since PR 1.
* The *capacity* path (``moe_dropless=False``: sort-based dispatch — a
  (tokens*k) argsort by expert id, a capacity-clipped scatter into an
  (E, C, D) buffer, a batched expert GEMM, and a weighted scatter-add
  combine) keeps dispatch cost O(tokens*k*D) bytes instead of
  O(tokens*E*C) FLOPs, which at the assigned shapes (1M tokens, 64
  experts) is the difference between a viable layer and a dispatch
  tensor that dwarfs the expert GEMMs.  The launch dry-run selects it
  explicitly (its cost probes are about those shapes); it is a
  throughput approximation, not reference semantics.
* Expert weights carry logical axis EXPERT -> mesh ``model`` (expert
  parallelism); the buffer is constrained the same way so XLA SPMD emits the
  canonical all-to-all on dispatch/combine.
* Shared experts (deepseek-moe) are algebraically a single wider dense swiglu
  (sum of always-active swiglu experts == block-diagonal concat), so they are
  stored as one fused FFN of hidden = num_shared * moe_d_ff.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    specs = {
        "router": ParamSpec((d, e), (L.EMBED, L.EXPERT)),
        "wi_gate": ParamSpec((e, d, f), (L.EXPERT, L.EMBED, None)),
        "wi_up": ParamSpec((e, d, f), (L.EXPERT, L.EMBED, None)),
        "wo": ParamSpec((e, f, d), (L.EXPERT, None, L.EMBED)),
    }
    if cfg.num_shared_experts:
        specs["shared"] = layers.ffn_specs(
            d, cfg.num_shared_experts * f, "swiglu")
    return specs


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)     # round up to 8 (TPU sublane multiple)


def route(params: dict, xt: jax.Array, cfg: ModelConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gate_weights (T,K), expert_ids (T,K), aux_loss scalar)."""
    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32),
        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    # Load-balancing aux loss (Switch-style): E * sum(frac_tokens * frac_prob)
    e = cfg.num_experts
    onehot = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return gate, expert_ids, aux


def _apply_moe_dropless(params: dict, x: jax.Array, cfg: ModelConfig, rules
                        ) -> Tuple[jax.Array, jax.Array]:
    """Exact dropless MoE: dense per-expert compute, gate-masked combine.

    Every routed slot contributes, so out[b, s] is a pure function of
    x[b, s] — no cross-token capacity coupling.  O(T*E*F) FLOPs; fine for
    the reduced/serving configs, the capacity path below covers the
    1M-token dry-run shapes.
    """
    b, s, d = x.shape
    t = b * s
    e = cfg.num_experts
    dt = x.dtype

    xt = x.reshape(t, d)
    xt = L.constrain(xt, rules, (L.BATCH, L.ACT_EMBED))
    gate, expert_ids, aux = route(params, xt, cfg)

    # combine weights (T, E): gate mass each token sends to each expert
    # (top-k ids are distinct, so the scatter-add never collides per row)
    w = jnp.zeros((t, e), dt).at[
        jnp.arange(t)[:, None], expert_ids].add(gate.astype(dt))

    gate_h = jnp.einsum("td,edf->tef", xt, params["wi_gate"].astype(dt))
    up_h = jnp.einsum("td,edf->tef", xt, params["wi_up"].astype(dt))
    h = jax.nn.silu(gate_h) * up_h
    out_e = jnp.einsum("tef,efd->ted", h, params["wo"].astype(dt))
    y = jnp.einsum("ted,te->td", out_e, w)
    y = L.constrain(y, rules, (L.BATCH, L.ACT_EMBED))

    out = y.reshape(b, s, d)
    if cfg.num_shared_experts:
        out = out + layers.apply_ffn(params["shared"], x, "swiglu", rules)
    return L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED)), aux


def apply_moe(params: dict, x: jax.Array, cfg: ModelConfig, rules
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss)."""
    if cfg.moe_dropless:
        return _apply_moe_dropless(params, x, cfg, rules)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(t, cfg)
    dt = x.dtype
    if not cfg.moe_cap_shard:
        # §Perf MoE iteration 2: unconstrained dispatch — let SPMD
        # propagation place the dispatch buffers (v1 behaviour)
        rules = None

    xt = x.reshape(t, d)
    xt = L.constrain(xt, rules, (L.BATCH, L.ACT_EMBED))
    gate, expert_ids, aux = route(params, xt, cfg)

    flat_e = expert_ids.reshape(t * k)
    flat_gate = gate.reshape(t * k).astype(dt)

    # --- dispatch: sort token-slots by expert, clip to capacity ------------
    sort_idx = jnp.argsort(flat_e)                       # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=e)
    seg_start = jnp.cumsum(counts) - counts              # (E,)
    pos_in_expert = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_expert < c
    token_idx = sort_idx // k                            # sorted-slot -> token
    # over-capacity slots get index e*c == out-of-bounds -> dropped/zero
    dest = jnp.where(keep, sorted_e * c + pos_in_expert, e * c)

    buf = jnp.zeros((e * c, d), dtype=dt).at[dest].set(
        xt.astype(dt)[token_idx], mode="drop")
    buf = buf.reshape(e, c, d)
    cap_ax = L.CAPACITY if cfg.moe_cap_shard else None
    buf = L.constrain(buf, rules, (L.EXPERT, cap_ax, L.ACT_EMBED))

    # --- expert FFN (batched swiglu GEMMs; EXPERT axis -> model mesh) ------
    gate_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"].astype(dt))
    up_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(dt))
    h = jax.nn.silu(gate_h) * up_h
    h = L.constrain(h, rules, (L.EXPERT, cap_ax, None))
    out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))
    out_e = L.constrain(out_e, rules, (L.EXPERT, cap_ax, L.ACT_EMBED))

    # --- combine: gather back to token slots, weight, scatter-add ----------
    out_flat = out_e.reshape(e * c, d)
    gathered = out_flat.at[dest].get(mode="fill",
                                     fill_value=0)       # (T*K, D); dropped->0
    contrib = gathered * flat_gate[sort_idx][:, None]
    y = jnp.zeros((t, d), dtype=dt).at[token_idx].add(contrib)
    y = L.constrain(y, rules, (L.BATCH, L.ACT_EMBED))

    out = y.reshape(b, s, d)
    if cfg.num_shared_experts:
        out = out + layers.apply_ffn(params["shared"], x, "swiglu", rules)
    return L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED)), aux
