"""Architecture registry: maps --arch ids to configs and provides the
uniform batch-dict model API used by train/serve/launch.

Batch dicts (data pipeline & input_specs produce exactly these):
  train:   {tokens (B,S_text) i32, labels (B,S_text) i32
            [, prefix_embeds (B,P,D) f32]            # vlm stub frontend
            [, frame_embeds (B,S_src,D) f32]}        # audio stub frontend
  prefill: {tokens (B,S)} (+ stubs) + cache pytree
  decode:  {tokens (B,1)} + cache pytree + pos scalar
            (+ memory (B,S_src,D) for enc-dec)
"""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "command-r-35b": "repro.configs.command_r_35b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str, *, reduced: bool = False, **overrides
               ) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    cfg = mod.reduced() if reduced else mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


def param_specs(cfg: ModelConfig):
    return encdec.param_specs(cfg) if is_encdec(cfg) else lm.param_specs(cfg)


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    if is_encdec(cfg):
        return encdec.cache_specs(cfg, batch, cache_len)
    return lm.cache_specs(cfg, batch, cache_len)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch, cache_len)
    return lm.init_cache(cfg, batch, cache_len)


def forward(params, batch: Dict[str, Any], cfg: ModelConfig, rules=None
            ) -> Tuple[jax.Array, jax.Array]:
    if is_encdec(cfg):
        return encdec.forward(params, batch["tokens"],
                              batch["frame_embeds"], cfg, rules)
    return lm.forward(params, batch["tokens"], cfg, rules,
                      prefix_embeds=batch.get("prefix_embeds"))


def prefill(params, batch: Dict[str, Any], cache, cfg: ModelConfig,
            rules=None):
    """Returns (last-token logits, cache, extras-dict)."""
    if is_encdec(cfg):
        logits, new_cache, memory = encdec.prefill(
            params, batch["tokens"], batch["frame_embeds"], cache, cfg,
            rules)
        return logits, new_cache, {"memory": memory}
    logits, new_cache = lm.prefill(params, batch["tokens"], cache, cfg,
                                   rules,
                                   prefix_embeds=batch.get("prefix_embeds"))
    return logits, new_cache, {}


def decode_step(params, batch: Dict[str, Any], cache, pos,
                cfg: ModelConfig, rules=None):
    if is_encdec(cfg):
        return encdec.decode_step(params, batch["tokens"], batch["memory"],
                                  cache, pos, cfg, rules)
    return lm.decode_step(params, batch["tokens"], cache, pos, cfg, rules)


def loss_fn(logits: jax.Array, labels: jax.Array, aux: jax.Array,
            aux_weight: float = 0.01) -> jax.Array:
    """Next-token CE over the last S_text positions (+ MoE aux loss)."""
    s_text = labels.shape[1]
    logits = logits[:, -s_text:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + aux_weight * aux


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text positions for a cell's total sequence length."""
    if cfg.frontend == "vision":
        return seq_len - cfg.num_prefix_embeds
    return seq_len


def make_train_batch(cfg: ModelConfig, seq_len: int, batch: int, key=None
                     ) -> Dict[str, Any]:
    """Materialized random batch (CPU smoke tests / examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    st = text_len(cfg, seq_len)
    out = {
        "tokens": jax.random.randint(k1, (batch, st), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
        "labels": jax.random.randint(k2, (batch, st), 0, cfg.vocab_size,
                                     dtype=jnp.int32),
    }
    if cfg.frontend == "vision":
        out["prefix_embeds"] = jax.random.normal(
            k3, (batch, cfg.num_prefix_embeds, cfg.d_model),
            dtype=jnp.float32)
    if cfg.frontend == "audio":
        out["frame_embeds"] = jax.random.normal(
            k3, (batch, max(1, seq_len // cfg.src_ratio), cfg.d_model),
            dtype=jnp.float32)
    return out
