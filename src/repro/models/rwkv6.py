"""RWKV6 ("Finch") token-mix and channel-mix layers — data-dependent decay
linear recurrence (arXiv:2404.05892), attention-free.

The sequence form here is the pure-jnp reference (lax.scan over time); the
chunked Pallas kernel lives in kernels/rwkv6_scan and is used via the
``ArrayIsland`` shim when cfg.attn_impl == "flash" (kernel shims share the
impl knob).  Decode is a single state update — O(1) per token, which is why
this arch runs the long_500k shape.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding import logical as L
from repro.sharding.logical import ParamSpec

LORA_RANK = 32
DECAY_LORA_RANK = 64


def num_rwkv_heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % cfg.rwkv_head_dim == 0
    return cfg.d_model // cfg.rwkv_head_dim


def time_mix_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = num_rwkv_heads(cfg)
    dh = cfg.rwkv_head_dim
    return {
        # token-shift interpolation: base mus + data-dependent lora (ddlerp)
        "mu_base": ParamSpec((5, d), (None, L.EMBED), init="zeros"),
        "mu_w1": ParamSpec((d, 5 * LORA_RANK), (L.EMBED, None)),
        "mu_w2": ParamSpec((5, LORA_RANK, d), (None, None, L.EMBED)),
        # projections
        "wr": ParamSpec((d, d), (L.EMBED, L.MLP)),
        "wk": ParamSpec((d, d), (L.EMBED, L.MLP)),
        "wv": ParamSpec((d, d), (L.EMBED, L.MLP)),
        "wg": ParamSpec((d, d), (L.EMBED, L.MLP)),
        "wo": ParamSpec((d, d), (L.MLP, L.EMBED)),
        # data-dependent decay
        "w0": ParamSpec((d,), (L.EMBED,), init="zeros"),
        "w_lora_a": ParamSpec((d, DECAY_LORA_RANK), (L.EMBED, None)),
        "w_lora_b": ParamSpec((DECAY_LORA_RANK, d), (None, L.EMBED)),
        # bonus (per-head u) and per-head group-norm
        "u": ParamSpec((h, dh), (L.HEADS, L.HEAD_DIM), init="zeros"),
        "ln_scale": ParamSpec((d,), (L.EMBED,), init="ones"),
        "ln_bias": ParamSpec((d,), (L.EMBED,), init="zeros"),
    }


def channel_mix_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), (L.EMBED,), init="zeros"),
        "mu_r": ParamSpec((d,), (L.EMBED,), init="zeros"),
        "wk": ParamSpec((d, f), (L.EMBED, L.MLP)),
        "wv": ParamSpec((f, d), (L.MLP, L.EMBED)),
        "wr": ParamSpec((d, d), (L.EMBED, None)),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """shift(x)[t] = x[t-1]; position 0 takes ``prev`` (decode carry) or 0."""
    shifted = jnp.roll(x, 1, axis=1)
    first = prev if prev is not None else jnp.zeros_like(x[:, :1])
    return shifted.at[:, :1].set(first)


def _ddlerp(params: dict, x: jax.Array, xx: jax.Array) -> Tuple[jax.Array, ...]:
    """RWKV6 data-dependent lerp producing the 5 mixed inputs (r,w,k,v,g)."""
    dt = x.dtype
    dx = xx - x
    # low-rank data-dependent offsets
    mu_base = params["mu_base"].astype(dt)
    base = x + dx * mu_base[0][None, None, :]
    z = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, params["mu_w1"].astype(dt)))
    z = z.reshape(*z.shape[:-1], 5, LORA_RANK)
    offs = jnp.einsum("bstr,trd->bstd", z, params["mu_w2"].astype(dt))
    outs = []
    for i in range(5):
        mu = mu_base[i][None, None, :] + offs[:, :, i]
        outs.append(x + dx * mu)
    return tuple(outs)    # (xr, xw, xk, xv, xg)


def _decay(params: dict, xw: jax.Array) -> jax.Array:
    """Per-channel per-token decay w in (0,1): exp(-exp(w0 + lora(xw)))."""
    lora = jnp.einsum("bsr,rd->bsd",
                      jnp.tanh(jnp.einsum("bsd,dr->bsr", xw,
                                          params["w_lora_a"])),
                      params["w_lora_b"])
    return jnp.exp(-jnp.exp((params["w0"][None, None] + lora
                             ).astype(jnp.float32)))


def _group_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
                num_heads: int, eps: float = 64e-5) -> jax.Array:
    b, s, d = x.shape
    xh = x.reshape(b, s, num_heads, d // num_heads).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(b, s, d) * scale + bias
    return out


def wkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Reference WKV6 recurrence.

    r,k,v,w: (B, S, H, Dh) fp32; u: (H, Dh); state: (B, H, Dh, Dh).
      S_t = diag(w_t) S_{t-1} + k_t^T v_t
      y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    Returns y (B, S, H, Dh) and the final state.
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                      # (B,H,Dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          w.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), state


def init_time_state(cfg: ModelConfig, batch: int) -> dict:
    h, dh = num_rwkv_heads(cfg), cfg.rwkv_head_dim
    return {
        "wkv": ParamSpec((batch, h, dh, dh),
                         (L.BATCH, L.HEADS, None, None),
                         dtype=jnp.float32, init="zeros"),
        "shift": ParamSpec((batch, 1, cfg.d_model),
                           (L.BATCH, None, None),
                           dtype=jnp.bfloat16, init="zeros"),
    }


def apply_time_mix(params: dict, x: jax.Array, cfg: ModelConfig, rules,
                   state: Optional[dict] = None
                   ) -> Tuple[jax.Array, Optional[dict]]:
    """Sequence-mode (state=None -> zeros) or streaming (carry state)."""
    b, s, d = x.shape
    h, dh = num_rwkv_heads(cfg), cfg.rwkv_head_dim
    dt = x.dtype

    prev = state["shift"].astype(dt) if state is not None else None
    xx = _token_shift(x, prev)
    xr, xw, xk, xv, xg = _ddlerp(params, x, xx)

    r = jnp.einsum("bsd,de->bse", xr, params["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", xk, params["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", xv, params["wv"].astype(dt))
    g = jnp.einsum("bsd,de->bse", xg, params["wg"].astype(dt))
    w = _decay(params, xw)                                 # fp32 (B,S,D)

    rh = r.reshape(b, s, h, dh).astype(jnp.float32)
    kh = k.reshape(b, s, h, dh).astype(jnp.float32)
    vh = v.reshape(b, s, h, dh).astype(jnp.float32)
    wh = w.reshape(b, s, h, dh)
    rh = L.constrain(rh, rules, (L.BATCH, L.SEQ, L.HEADS, L.HEAD_DIM))

    s0 = (state["wkv"] if state is not None
          else jnp.zeros((b, h, dh, dh), jnp.float32))
    y, s_final = wkv_scan(rh, kh, vh, wh,
                          params["u"].astype(jnp.float32), s0)

    y = _group_norm(y.reshape(b, s, d), params["ln_scale"],
                    params["ln_bias"], h)
    out = (y.astype(dt) * jax.nn.silu(g))
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dt))
    out = L.constrain(out, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))

    new_state = None
    if state is not None:
        new_state = {"wkv": s_final, "shift": x[:, -1:].astype(jnp.bfloat16)}
    return out, new_state


def init_channel_state(cfg: ModelConfig, batch: int) -> dict:
    return {"shift": ParamSpec((batch, 1, cfg.d_model),
                               (L.BATCH, None, None),
                               dtype=jnp.bfloat16, init="zeros")}


def apply_channel_mix(params: dict, x: jax.Array, cfg: ModelConfig, rules,
                      state: Optional[dict] = None
                      ) -> Tuple[jax.Array, Optional[dict]]:
    dt = x.dtype
    prev = state["shift"].astype(dt) if state is not None else None
    xx = _token_shift(x, prev)
    dx = xx - x
    xk = x + dx * params["mu_k"][None, None].astype(dt)
    xr = x + dx * params["mu_r"][None, None].astype(dt)
    k = jnp.einsum("bsd,df->bsf", xk, params["wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    k = L.constrain(k, rules, (L.BATCH, L.SEQ, L.MLP))
    vv = jnp.einsum("bsf,fd->bsd", k, params["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["wr"].astype(dt)))
    out = L.constrain(r * vv, rules, (L.BATCH, L.SEQ, L.ACT_EMBED))
    new_state = None
    if state is not None:
        new_state = {"shift": x[:, -1:].astype(jnp.bfloat16)}
    return out, new_state
