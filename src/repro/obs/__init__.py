"""Observability (``repro.obs``): tracing + unified metrics.

The paper's Monitor (§V.E) is the layer that makes a polystore tunable;
this package is its instrumentation substrate, threaded through every
subsystem:

* ``repro.obs.trace`` — ``span("layer/stage", **attrs)`` context
  managers with contextvars propagation across worker pools and commit
  lanes, a bounded per-process span ring, Chrome-trace/flamegraph
  exporters, and a slow-op log (``REPRO_SLOW_OP_MS``).  Everything keys
  off ``REPRO_TRACE`` (default off) and is near-free when disabled.
* ``repro.obs.metrics`` — a process-wide registry of counters, gauges
  and log-bucket histograms (p50/p95/p99 without per-sample storage)
  that absorbs the subsystems' ad-hoc counters, with Prometheus text
  exposition (``admin metrics`` and an optional ``/metrics`` HTTP dump).

See docs/OPERATIONS.md "Observability" for knobs and naming scheme.
"""
from repro.obs import metrics, trace            # noqa: F401
from repro.obs.trace import bind, span          # noqa: F401
