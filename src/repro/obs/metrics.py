"""Unified metrics: one process-wide registry of counters, gauges and
histograms absorbing the subsystems' ad-hoc counters (Monitor EWMAs,
``compile.stats()``, ``ingest_concurrency()``, ``shim.JOIN_STATS``,
plan-cache stats), with Prometheus text exposition.

Naming scheme: ``repro_<subsystem>_<what>[_<unit>][_total]`` —
counters end in ``_total``, durations are ``_seconds``, and labels
identify the series (``stream=\"...\"``, ``engine=\"...\"``,
``method=\"...\"``).  See docs/OPERATIONS.md "Observability".

Histograms use fixed log-scale buckets (10 per decade, 1e-6..1e3 — the
span of everything this process times, from sub-µs ring writes to
multi-minute training runs), so p50/p95/p99 come from bucket
interpolation without per-sample storage; a quantile estimate is always
within one bucket ratio (10^0.1 ≈ 1.26x) of the true sample quantile.

The registry is always on (it is the exposition surface ``admin
metrics`` and ``status()`` read) — only *tracing* keys off
``REPRO_TRACE``.  Updates are a lock + float add, cheap enough for
per-tick paths; per-row hot loops stay uninstrumented.

Cumulative sources that keep their own counters absorb via
``Counter.set_total`` (monotone), so the legacy dict and the registry
series can never disagree by more than one scrape.
"""
from __future__ import annotations

import threading
from bisect import bisect_right
from typing import Any, Dict, List, Optional, Tuple

# 10 buckets per decade across 1e-6 .. 1e3: 91 bounds, 92 counts (the
# last is the +Inf overflow bucket)
BUCKET_BOUNDS: Tuple[float, ...] = tuple(
    10.0 ** (e / 10.0) for e in range(-60, 31))
BUCKET_RATIO = 10.0 ** 0.1


class Counter:
    """Monotone counter (``inc`` for owned counts, ``set_total`` to
    absorb an external cumulative counter)."""
    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def set_total(self, value: float) -> None:
        """Raise the counter to an externally tracked cumulative value
        (monotone: a stale or reset source can never move it back)."""
        with self._lock:
            if value > self._value:
                self._value = value

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value."""
    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed log-bucket histogram; quantiles by linear interpolation
    inside the crossing bucket (error bounded by one bucket ratio)."""
    __slots__ = ("_lock", "_counts", "_sum", "_count", "_max")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counts = [0] * (len(BUCKET_BOUNDS) + 1)
        self._sum = 0.0
        self._count = 0
        self._max = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        idx = bisect_right(BUCKET_BOUNDS, value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1
            if value > self._max:
                self._max = value

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0..1) from the bucket counts; 0.0
        when empty.  The overflow bucket interpolates toward the max
        observed value."""
        assert 0.0 <= q <= 1.0
        with self._lock:
            counts, total, vmax = list(self._counts), self._count, self._max
        if total == 0:
            return 0.0
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = BUCKET_BOUNDS[i - 1] if i > 0 else 0.0
                hi = (BUCKET_BOUNDS[i] if i < len(BUCKET_BOUNDS)
                      else max(vmax, lo))
                frac = (target - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return vmax

    def percentiles(self) -> Dict[str, float]:
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def bucket_counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Name+labels -> metric.  ``counter/gauge/histogram`` get-or-create
    a series; ``snapshot()`` and ``prometheus_text()`` read every series
    under the registry lock, so a scrape is internally consistent per
    metric (no series is half-registered)."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        # name -> {"type": str, "help": str,
        #          "series": {((label, value), ...): metric}}
        self._families: Dict[str, Dict[str, Any]] = {}

    def _get(self, kind: str, name: str, help_text: str,
             labels: Dict[str, Any]):
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = {"type": kind, "help": help_text, "series": {}}
                self._families[name] = fam
            elif fam["type"] != kind:
                raise ValueError(
                    f"metric {name!r} is a {fam['type']}, not a {kind}")
            metric = fam["series"].get(key)
            if metric is None:
                metric = _TYPES[kind]()
                fam["series"][key] = metric
            return metric

    def counter(self, name: str, help: str = "",
                **labels: Any) -> Counter:
        return self._get("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: Any) -> Gauge:
        return self._get("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "",
                  **labels: Any) -> Histogram:
        return self._get("histogram", name, help, labels)

    def reset(self) -> None:
        with self._lock:
            self._families.clear()

    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe view of every series: counters/gauges report their
        value, histograms count/sum/p50/p95/p99."""
        out: Dict[str, Any] = {}
        with self._lock:
            families = {name: (fam["type"], dict(fam["series"]))
                        for name, fam in self._families.items()}
        for name, (kind, series) in sorted(families.items()):
            rows = []
            for key, metric in sorted(series.items()):
                row: Dict[str, Any] = {"labels": dict(key)}
                if kind == "histogram":
                    row.update(count=metric.count,
                               sum=round(metric.sum, 9),
                               **{k: round(v, 9) for k, v in
                                  metric.percentiles().items()})
                else:
                    row["value"] = metric.value
                rows.append(row)
            out[name] = {"type": kind, "series": rows}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4 (the ``/metrics``
        payload): HELP/TYPE headers, one sample line per series,
        histograms as cumulative ``_bucket{le=...}`` + ``_sum`` +
        ``_count``."""
        lines: List[str] = []
        with self._lock:
            families = {name: (fam["type"], fam["help"],
                               dict(fam["series"]))
                        for name, fam in self._families.items()}
        for name, (kind, help_text, series) in sorted(families.items()):
            if help_text:
                lines.append(f"# HELP {name} {_escape_help(help_text)}")
            lines.append(f"# TYPE {name} {kind}")
            for key, metric in sorted(series.items()):
                if kind == "histogram":
                    counts = metric.bucket_counts()
                    cum = 0
                    for bound, c in zip(BUCKET_BOUNDS, counts):
                        cum += c
                        lines.append(
                            f"{name}_bucket"
                            f"{_labels(key, le=_fmt(bound))} {cum}")
                    cum += counts[-1]
                    lines.append(
                        f"{name}_bucket{_labels(key, le='+Inf')} {cum}")
                    lines.append(
                        f"{name}_sum{_labels(key)} {_fmt(metric.sum)}")
                    lines.append(
                        f"{name}_count{_labels(key)} {metric.count}")
                else:
                    lines.append(
                        f"{name}{_labels(key)} {_fmt(metric.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(value: float) -> str:
    as_int = int(value)
    return str(as_int) if value == as_int else repr(float(value))


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label(value: str) -> str:
    return (value.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labels(key: Tuple[Tuple[str, str], ...], **extra: str) -> str:
    items = list(key) + sorted(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label(v)}"' for k, v in items)
    return "{" + body + "}"


# the process-wide registry every subsystem writes to
REGISTRY = Registry()


def counter(name: str, help: str = "", **labels: Any) -> Counter:
    return REGISTRY.counter(name, help, **labels)


def gauge(name: str, help: str = "", **labels: Any) -> Gauge:
    return REGISTRY.gauge(name, help, **labels)


def histogram(name: str, help: str = "", **labels: Any) -> Histogram:
    return REGISTRY.histogram(name, help, **labels)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


def prometheus_text() -> str:
    return REGISTRY.prometheus_text()


def reset() -> None:
    REGISTRY.reset()


# -- HTTP exposition (the serve-reachable /metrics dump) ----------------------
def start_http_server(port: int = 0, host: str = "127.0.0.1"):
    """Serve ``prometheus_text()`` at ``GET /metrics`` on a daemon
    thread; returns the ``ThreadingHTTPServer`` (``server_address`` has
    the bound port when ``port=0``; call ``shutdown()`` to stop).  Uses
    only the stdlib so headless deployments pay no new dependency."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self) -> None:                      # noqa: N802
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            payload = prometheus_text().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(payload)))
            self.end_headers()
            self.wfile.write(payload)

        def log_message(self, *args: Any) -> None:     # quiet
            pass

    server = ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="obs-metrics-http", daemon=True)
    thread.start()
    return server
