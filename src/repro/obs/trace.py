"""Tracing: ``span("layer/stage", **attrs)`` context managers recording
into a bounded per-process ring, with contextvars propagation across
thread pools (DAG executor workers, producer staging threads, ordered
commit lanes) so parent links survive thread hops.

Gated by ``REPRO_TRACE`` (default off).  When disabled, ``span()``
returns a shared no-op context manager and ``bind()`` returns its
argument unchanged — the instrumented hot paths pay one module-global
check plus a kwargs dict, nothing else (the ``stream/trace_overhead``
bench row keeps this honest).

Span names are ``layer/stage`` (``planner/query``, ``executor/node``,
``committer/commit``, ``stream/tick``, ``compile/execute`` ...); the
layer prefix becomes the Chrome-trace category, so Perfetto can filter
by subsystem.  ``trace_id`` is inherited from the enclosing span (pass
one explicitly at a root — e.g. the tick id) and parent links are span
ids, valid across threads.

Exporters: ``chrome_trace()`` (Perfetto-loadable trace-event JSON with
flow events marking cross-thread parent links) and ``flamegraph()``
(text summary aggregated by parent-chain path).  Spans slower than
``REPRO_SLOW_OP_MS`` additionally land in the slow-op ring with their
attrs (``slow_ops()``).
"""
from __future__ import annotations

import collections
import itertools
import json
import os
import threading
import time
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

_TRUTHY = ("1", "true", "yes", "on")


def _env_flag(name: str, default: str = "0") -> bool:
    return os.environ.get(name, default).strip().lower() in _TRUTHY


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except ValueError:
        return default


# module-global fast path: span() checks this one bool when disabled
_ENABLED = _env_flag("REPRO_TRACE")
# slow-op threshold (milliseconds); spans at or above it land in the
# slow-op ring even though every span lands in the main ring
_SLOW_MS = _env_float("REPRO_SLOW_OP_MS", 100.0)

_LOCK = threading.Lock()
_SPANS: "collections.deque[SpanRecord]" = collections.deque(
    maxlen=max(16, _env_int("REPRO_TRACE_RING", 8192)))
_SLOW: "collections.deque[Dict[str, Any]]" = collections.deque(
    maxlen=max(16, _env_int("REPRO_SLOW_OP_RING", 512)))

_SPAN_IDS = itertools.count(1)
_TRACE_IDS = itertools.count(1)

# injectable for tests (slow-op threshold behaviour with a fake clock)
_clock = time.perf_counter

# the active span of the calling context; bind() re-plants it on worker
# threads so child spans link to their logical parent across pools
_CURRENT: "ContextVar[Optional[Span]]" = ContextVar(
    "repro_obs_current_span", default=None)


def enabled() -> bool:
    return _ENABLED


def set_enabled(on: bool) -> bool:
    """Flip tracing programmatically; returns the previous state."""
    global _ENABLED
    prev, _ENABLED = _ENABLED, bool(on)
    return prev


def refresh() -> None:
    """Re-read ``REPRO_TRACE`` / ``REPRO_SLOW_OP_MS`` from the
    environment (ring sizes are fixed at import)."""
    global _SLOW_MS
    set_enabled(_env_flag("REPRO_TRACE"))
    _SLOW_MS = _env_float("REPRO_SLOW_OP_MS", 100.0)


def slow_op_threshold_ms() -> float:
    return _SLOW_MS


@dataclass
class SpanRecord:
    """One finished span (immutable once in the ring)."""
    name: str
    trace_id: str
    span_id: int
    parent_id: Optional[int]
    start: float                    # perf-counter seconds
    duration: float                 # seconds
    thread_id: int
    thread_name: str
    attrs: Dict[str, Any] = field(default_factory=dict)


class _NoopSpan:
    """Shared do-nothing span: the entire disabled-mode surface."""
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> None:
        pass


NOOP = _NoopSpan()


class Span:
    """An open span; records itself into the ring on exit."""
    __slots__ = ("name", "trace_id", "span_id", "parent_id", "attrs",
                 "_t0", "_token")

    def __init__(self, name: str, trace_id: Optional[str],
                 attrs: Dict[str, Any]) -> None:
        self.name = name
        self.trace_id = trace_id
        self.attrs = attrs
        self.span_id = 0
        self.parent_id: Optional[int] = None
        self._t0 = 0.0
        self._token = None

    def set(self, **attrs: Any) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        parent = _CURRENT.get()
        if parent is not None:
            self.parent_id = parent.span_id
            if self.trace_id is None:
                self.trace_id = parent.trace_id
        if self.trace_id is None:
            self.trace_id = f"t{next(_TRACE_IDS)}"
        self.span_id = next(_SPAN_IDS)
        self._token = _CURRENT.set(self)
        self._t0 = _clock()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        duration = _clock() - self._t0
        _CURRENT.reset(self._token)
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        thread = threading.current_thread()
        rec = SpanRecord(
            name=self.name, trace_id=self.trace_id, span_id=self.span_id,
            parent_id=self.parent_id, start=self._t0, duration=duration,
            thread_id=thread.ident or 0, thread_name=thread.name,
            attrs=dict(self.attrs))
        with _LOCK:
            _SPANS.append(rec)
            if duration * 1e3 >= _SLOW_MS:
                _SLOW.append({
                    "name": rec.name, "trace_id": rec.trace_id,
                    "span_id": rec.span_id, "ms": round(duration * 1e3, 3),
                    "thread": rec.thread_name, "attrs": dict(rec.attrs)})
        return False


def span(name: str, trace_id: Optional[str] = None, **attrs: Any):
    """Open a span.  ``with span("executor/node", engine="s0") as sp:``
    — use ``sp.set(...)`` for attrs only known mid-span.  No-op (one
    shared object, zero allocation beyond the kwargs dict) when tracing
    is disabled."""
    if not _ENABLED:
        return NOOP
    return Span(name, trace_id, attrs)


def bind(fn):
    """Carry the caller's active span onto whatever thread runs ``fn``
    (pool submissions, committer lanes): spans opened inside the call
    parent-link to the span active at *bind* time.  Identity when
    tracing is disabled or no span is active, so hot paths can call it
    unconditionally.  Safe for one bound fn to run on many threads at
    once — each call plants/resets only its own contextvar token."""
    if not _ENABLED:
        return fn
    parent = _CURRENT.get()
    if parent is None:
        return fn

    def _bound(*args: Any, **kwargs: Any):
        token = _CURRENT.set(parent)
        try:
            return fn(*args, **kwargs)
        finally:
            _CURRENT.reset(token)

    return _bound


def current_trace_id() -> Optional[str]:
    cur = _CURRENT.get()
    return cur.trace_id if cur is not None else None


def spans() -> List[SpanRecord]:
    with _LOCK:
        return list(_SPANS)


def slow_ops() -> List[Dict[str, Any]]:
    with _LOCK:
        return list(_SLOW)


def reset() -> None:
    """Drop recorded spans and slow ops (the enabled flag is untouched)."""
    with _LOCK:
        _SPANS.clear()
        _SLOW.clear()


# -- exporters ----------------------------------------------------------------
def chrome_trace(records: Optional[List[SpanRecord]] = None
                 ) -> Dict[str, Any]:
    """Chrome trace-event JSON (the ``{"traceEvents": [...]}`` envelope
    Perfetto and chrome://tracing load).  Spans become complete ("X")
    events on their real thread; a child whose parent ran on another
    thread additionally gets a flow arrow ("s" on the parent thread ->
    "f" on the child's) so cross-thread parent links are visible."""
    records = spans() if records is None else list(records)
    pid = os.getpid()
    by_id = {r.span_id: r for r in records}
    events: List[Dict[str, Any]] = []
    thread_names: Dict[int, str] = {}
    for r in records:
        thread_names.setdefault(r.thread_id, r.thread_name)
        ts = int(r.start * 1e6)
        events.append({
            "name": r.name, "cat": r.name.split("/", 1)[0], "ph": "X",
            "ts": ts, "dur": max(1, int(r.duration * 1e6)),
            "pid": pid, "tid": r.thread_id,
            "args": dict(r.attrs, trace_id=r.trace_id,
                         span_id=r.span_id, parent_id=r.parent_id)})
        parent = by_id.get(r.parent_id)
        if parent is not None and parent.thread_id != r.thread_id:
            # flow start sits inside the parent slice (the child started
            # while its parent was open), finish binds to the child slice
            events.append({"name": "parent", "cat": "obs.flow",
                           "ph": "s", "id": r.span_id, "pid": pid,
                           "tid": parent.thread_id, "ts": ts})
            events.append({"name": "parent", "cat": "obs.flow",
                           "ph": "f", "bp": "e", "id": r.span_id,
                           "pid": pid, "tid": r.thread_id, "ts": ts})
    for tid, tname in sorted(thread_names.items()):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": tname}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def save_chrome_trace(path: str,
                      records: Optional[List[SpanRecord]] = None) -> int:
    """Write ``chrome_trace()`` to ``path``; returns the span count."""
    doc = chrome_trace(records)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return sum(1 for e in doc["traceEvents"] if e["ph"] == "X")


def flamegraph(records: Optional[List[SpanRecord]] = None,
               max_rows: int = 40) -> str:
    """Text flamegraph: spans aggregated by their parent-chain path
    (``stream/tick;planner/query;executor/node``), each path showing
    total milliseconds, call count and share of root time.  A span whose
    parent was evicted from the ring roots its own path."""
    records = spans() if records is None else list(records)
    by_id = {r.span_id: r for r in records}
    totals: Dict[tuple, List[float]] = {}
    root_ms = 0.0
    for r in records:
        path, cur, hops = [r.name], r, 0
        while cur.parent_id is not None and hops < 64:
            parent = by_id.get(cur.parent_id)
            if parent is None:
                break
            path.append(parent.name)
            cur, hops = parent, hops + 1
        path_t = tuple(reversed(path))
        bucket = totals.setdefault(path_t, [0.0, 0])
        bucket[0] += r.duration * 1e3
        bucket[1] += 1
        if len(path_t) == 1:
            root_ms += r.duration * 1e3
    lines = [f"{'total_ms':>10} {'calls':>7}  path "
             f"({len(records)} spans)"]
    ranked = sorted(totals.items(), key=lambda kv: kv[0])
    for path_t, (ms, calls) in ranked[:max_rows]:
        share = f" {100.0 * ms / root_ms:5.1f}%" if root_ms else ""
        indent = "  " * (len(path_t) - 1)
        lines.append(f"{ms:10.2f} {calls:7d}  {indent}{path_t[-1]}"
                     f"{share}")
    if len(ranked) > max_rows:
        lines.append(f"... {len(ranked) - max_rows} more paths")
    return "\n".join(lines)
