"""AdamW with mixed precision and optional int8-compressed moments.

The second-moment compression reuses the quant_cast codec — the optimizer
state then lives as an int8 "KVStore-engine" object in the polystore sense
(catalog policy decides; DESIGN.md §3).  Functional API: state is a pytree
aligned with params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"        # cosine | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        return cfg.learning_rate * warm
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state) -> Tuple[Any, dict]:
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim > 1 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state


def compress_moments_int8(state: dict) -> dict:
    """int8-quantize the second moment (gradient-statistics compression for
    cross-pod checkpoint traffic); inverse is decompress_moments_int8."""
    from repro.kernels.quant_cast import ops as qops

    def q(leaf):
        qv, sc = qops.quantize(leaf)
        return {"q": qv, "scale": sc, "shape": leaf.shape}

    return {**state, "v": jax.tree.map(
        q, state["v"], is_leaf=lambda x: isinstance(x, jax.Array))}


def decompress_moments_int8(state: dict) -> dict:
    from repro.kernels.quant_cast import ops as qops

    def dq(leaf):
        return qops.dequantize(leaf["q"], leaf["scale"], leaf["shape"])

    return {**state, "v": jax.tree.map(
        dq, state["v"], is_leaf=lambda x: isinstance(x, dict)
        and "q" in x)}
