"""Fault-tolerant execution harness: heartbeats, failure injection,
checkpoint/restart recovery, and straggler mitigation.

On a real cluster the heartbeat source is the coordinator's RPC layer;
here hosts are simulated workers so the recovery logic (detect -> restore
latest checkpoint -> rebuild state -> resume from the failed step, with the
deterministic data pipeline replaying the exact batch) is fully exercised
by tests.  The straggler path feeds the polystore Monitor (per-engine EWMA
-> Planner avoidance), the same loop the paper uses for engine selection.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.manager import CheckpointManager
from repro.core.monitor import Monitor


class NodeFailure(Exception):
    def __init__(self, host_id: int, step: int) -> None:
        super().__init__(f"host {host_id} failed at step {step}")
        self.host_id = host_id
        self.step = step


class SimulatedCrash(BaseException):
    """Raised by an armed crash point to simulate a process kill at a
    precise instruction boundary.  Deliberately a ``BaseException``:
    recovery-minded ``except Exception`` handlers in the code under
    test must NOT swallow a kill — only the test harness catches it.
    """

    def __init__(self, point: str, hit: int) -> None:
        super().__init__(f"simulated crash at {point!r} (hit #{hit})")
        self.point = point
        self.hit = hit


# -- crash points ------------------------------------------------------------
#
# Durable-path code (segment log appends, checkpoint promote/prune,
# commit/flush boundaries) calls ``crash_point("layer/step")`` at every
# instruction boundary where a real kill could land.  Disarmed — the
# production state — the call is one attribute load and a None check.
# Tests arm a deterministic countdown: the k-th matching hit raises
# ``SimulatedCrash``, so a property-test strategy that draws ``k``
# enumerates the entire crash surface, and shrinking ``k`` toward 1
# minimizes a failure to the earliest crash site that exhibits it.

_CRASH_LOCK = threading.Lock()
_ARMED: Optional[Dict[str, Any]] = None


def arm_crash_point(match: Optional[str] = None, at_hit: int = 1) -> None:
    """Arm the global crash injector: the ``at_hit``-th crash point whose
    name matches the ``match`` glob (all points when None) raises
    ``SimulatedCrash``.  Hits are counted process-wide under a lock, so
    the schedule is deterministic for a deterministic workload."""
    global _ARMED
    assert at_hit >= 1
    with _CRASH_LOCK:
        _ARMED = {"match": match, "remaining": int(at_hit),
                  "hits": [], "fired": None}


def disarm_crash_points() -> Dict[str, Any]:
    """Disarm and return the report: ``hits`` (every matching point
    reached, in order) and ``fired`` (the point that crashed, or None —
    e.g. when ``at_hit`` exceeded the workload's crash surface, which is
    how tests *count* the surface before sweeping it)."""
    global _ARMED
    with _CRASH_LOCK:
        report, _ARMED = _ARMED, None
    return report if report is not None else {"hits": [], "fired": None}


def crash_points_armed() -> bool:
    return _ARMED is not None


def crash_point(name: str,
                flush: Optional[Callable[[], None]] = None) -> None:
    """A possible kill site.  No-op unless armed.  When this hit fires,
    ``flush`` (if given) runs first — the caller's chance to push
    buffered bytes to disk so the simulated kill leaves exactly the
    torn on-disk state a real kill at this boundary would."""
    if _ARMED is None:
        return
    with _CRASH_LOCK:
        armed = _ARMED
        if armed is None:
            return
        if armed["match"] is not None and \
                not fnmatch.fnmatch(name, armed["match"]):
            return
        armed["hits"].append(name)
        armed["remaining"] -= 1
        if armed["remaining"] > 0:
            return
        if armed["fired"] is not None:        # crash once, not per thread
            return
        armed["fired"] = name
        hit = len(armed["hits"])
    if flush is not None:
        flush()
    raise SimulatedCrash(name, hit)


@contextlib.contextmanager
def crash_at(match: Optional[str] = None, at_hit: int = 1):
    """Context manager: arm on entry, disarm on exit, yield a mutable
    report dict that is filled in on exit (``hits``/``fired``)."""
    arm_crash_point(match, at_hit)
    report: Dict[str, Any] = {}
    try:
        yield report
    finally:
        report.update(disarm_crash_points())


@dataclasses.dataclass
class Heartbeat:
    host_id: int
    last_seen: float
    step: int


class HeartbeatRegistry:
    def __init__(self, timeout_seconds: float = 10.0) -> None:
        self.timeout = timeout_seconds
        self.beats: Dict[int, Heartbeat] = {}

    def beat(self, host_id: int, step: int) -> None:
        self.beats[host_id] = Heartbeat(host_id, time.monotonic(), step)

    def dead_hosts(self) -> List[int]:
        now = time.monotonic()
        return [h for h, b in self.beats.items()
                if now - b.last_seen > self.timeout]


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: host_id}."""
    schedule: Dict[int, int] = dataclasses.field(default_factory=dict)

    def check(self, step: int) -> None:
        if step in self.schedule:
            host = self.schedule.pop(step)
            raise NodeFailure(host, step)


@dataclasses.dataclass
class RecoveryReport:
    steps_run: int
    failures_recovered: int
    restarts: List[int]


def run_with_recovery(
        *, init_state: Callable[[], Any],
        step_fn: Callable[[Any, int], Any],
        ckpt: CheckpointManager,
        num_steps: int,
        checkpoint_every: int = 10,
        injector: Optional[FailureInjector] = None,
        max_failures: int = 4) -> RecoveryReport:
    """Run ``num_steps`` of ``step_fn`` with checkpoint/restart recovery.

    On NodeFailure: restore the latest checkpoint and resume from the step
    after it.  The data pipeline is step-deterministic, so replayed steps
    recompute identical batches (exactly-once semantics w.r.t. optimizer
    updates is guaranteed by restarting from the checkpointed step).
    """
    failures = 0
    restarts: List[int] = []
    state = init_state()
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state, start = ckpt.restore(state)
        start += 1

    step = start
    steps_run = 0
    while step < num_steps:
        try:
            if injector is not None:
                injector.check(step)
            state = step_fn(state, step)
            steps_run += 1
            if step % checkpoint_every == 0:
                ckpt.save(step, state)
            step += 1
        except NodeFailure:
            failures += 1
            if failures > max_failures:
                raise
            restarts.append(step)
            # detect -> restore -> resume
            latest = ckpt.latest_step()
            if latest is None:
                state = init_state()
                step = 0
            else:
                state, restored = ckpt.restore(state)
                step = restored + 1
    ckpt.wait()
    return RecoveryReport(steps_run=steps_run,
                          failures_recovered=failures, restarts=restarts)


@dataclasses.dataclass
class StragglerMitigator:
    """Per-host step-time EWMAs; slow hosts are reported for re-sharding.

    Policy mirrors the paper's Monitor->Planner loop: the Monitor observes,
    the Planner re-routes (here: the launcher re-balances data shards away
    from hosts whose EWMA exceeds factor x median).
    """
    monitor: Monitor
    factor: float = 2.0

    def observe(self, host_id: int, seconds: float) -> None:
        self.monitor.observe_engine(f"host{host_id}", seconds)

    def slow_hosts(self) -> List[int]:
        return [int(name[4:]) for name in
                self.monitor.stragglers(self.factor)
                if name.startswith("host")]

    def rebalance(self, num_hosts: int) -> Dict[int, float]:
        """Returns per-host data-shard weights (slow hosts get less)."""
        slow = set(self.slow_hosts())
        weights = {h: (0.5 if h in slow else 1.0)
                   for h in range(num_hosts)}
        total = sum(weights.values())
        return {h: w / total for h, w in weights.items()}
