"""Serving engine: prefill + greedy decode against the KV cache, with a
wave-based (iteration-level) batching scheduler and optional int8 KV-page
codec (the KVStore engine policy, cast via quant_cast).

Decode slots are position-aligned within a wave (one scalar cache cursor),
which is exactly the shape the decode_32k / long_500k dry-run cells lower;
requests are padded into waves by the scheduler.
"""
from __future__ import annotations

import atexit
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig
from repro.obs import metrics, trace
from repro.stream.spec import StreamSpec


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    cache_len: int = 256
    max_new_tokens: int = 32
    quantize_kv_between_waves: bool = False
    # concurrency knob (same family as core.executor.ExecutorConfig):
    # waves in flight at once. Each wave owns its KV cache, so waves are
    # independent; >1 overlaps host-side scheduling with device compute.
    max_parallel_waves: int = 1
    # observability: serve a Prometheus /metrics endpoint on this port
    # (0 = don't; the registry is process-wide, so any port exposes
    # every subsystem's series, not just serving)
    metrics_port: Optional[int] = None
    # polystore streams this serving tier provisions at startup — the
    # same declarative StreamSpec values register_stream/recover_stream
    # speak (the FrontDoor registers each on open(); specs are frozen,
    # so the whole config stays hashable)
    streams: Tuple[StreamSpec, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "streams", tuple(self.streams))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray
    prefill_seconds: float
    decode_seconds: float


class ServeSession:
    """One wave: batched prefill then lock-step greedy decode."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 rules=None) -> None:
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.rules = rules
        self._prefill = jax.jit(
            lambda p, batch, cache: registry.prefill(p, batch, cache, cfg,
                                                     rules))
        self._decode = jax.jit(
            lambda p, batch, cache, pos: registry.decode_step(
                p, batch, cache, pos, cfg, rules))

    def run_wave(self, requests: List[Request]) -> List[Completion]:
        with trace.span("serve/wave", batch=len(requests)) as sp:
            completions = self._run_wave(requests)
            sp.set(prefill_s=round(completions[0].prefill_seconds, 6),
                   decode_s=round(completions[0].decode_seconds, 6))
        metrics.counter("repro_serve_waves_total",
                        "decode waves executed").inc()
        metrics.histogram("repro_serve_prefill_seconds",
                          "batched prefill time per wave").observe(
            completions[0].prefill_seconds)
        metrics.histogram("repro_serve_decode_seconds",
                          "lock-step decode time per wave").observe(
            completions[0].decode_seconds)
        return completions

    def _run_wave(self, requests: List[Request]) -> List[Completion]:
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, -len(r.prompt):] = r.prompt      # left-pad
        cache = registry.init_cache(self.cfg, b, self.scfg.cache_len)

        batch: Dict[str, Any] = {"tokens": jnp.asarray(prompts)}
        if self.cfg.frontend == "vision":
            batch["prefix_embeds"] = jnp.zeros(
                (b, self.cfg.num_prefix_embeds, self.cfg.d_model),
                jnp.float32)
        if self.cfg.frontend == "audio":
            batch["frame_embeds"] = jnp.zeros(
                (b, max(1, plen // self.cfg.src_ratio), self.cfg.d_model),
                jnp.float32)

        t0 = time.perf_counter()
        logits, cache, extras = self._prefill(self.params, batch, cache)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0

        pos0 = plen + (self.cfg.num_prefix_embeds
                       if self.cfg.frontend == "vision" else 0)
        max_new = min(self.scfg.max_new_tokens,
                      self.scfg.cache_len - pos0 - 1,
                      max(r.max_new_tokens for r in requests))
        outs = [np.argmax(np.asarray(logits[:, -1]), -1)]
        t1 = time.perf_counter()
        for i in range(max_new - 1):
            tok = jnp.asarray(outs[-1][:, None], jnp.int32)
            dbatch = {"tokens": tok, **extras}
            logits, cache = self._decode(self.params, dbatch, cache,
                                         jnp.int32(pos0 + i))
            outs.append(np.argmax(np.asarray(logits[:, -1]), -1))
        decode_s = time.perf_counter() - t1

        toks = np.stack(outs, axis=1)                    # (B, max_new)
        return [Completion(r.rid, toks[i, :r.max_new_tokens],
                           prefill_s, decode_s)
                for i, r in enumerate(requests)]


class TickWaveScheduler:
    """Incremental wave scheduler for standing-query work.

    ``Scheduler`` below packs a FIFO of requests into waves up front;
    streaming work arrives differently — one standing ``infer`` query at
    a time within a StreamRuntime tick, with no point where the whole
    batch is visible.  This variant opens a wave on the first submission
    carrying a new key (the tick number) and accounts every later
    same-key submission to the open wave, so N concurrent standing
    queries cost one wave per tick.  Work still executes per submission
    at its canonical shape: a wave batches scheduling, compilation-cache
    reuse and observability, never the GEMM shapes — results stay
    bitwise independent of what else shares the wave (the same
    batch-composition independence the dropless MoE path guarantees).
    """

    def __init__(self, span_name: str = "ml/wave") -> None:
        self.span_name = span_name
        self.waves = 0                 # waves opened (lifetime)
        self.submissions = 0           # work items (lifetime)
        self.current_batch = 0         # items in the open wave
        self._key: Optional[Any] = None

    def submit(self, key, fn):
        """Run ``fn`` inside the wave for ``key``, opening one if the
        key is new.  Returns ``fn()``'s result; exceptions propagate
        after the submission is accounted (the wave survives — later
        same-tick queries still join it)."""
        if key != self._key:
            self._key = key
            self.waves += 1
            self.current_batch = 0
            metrics.counter("repro_ml_waves_total",
                            "standing-infer waves opened").inc()
        self.current_batch += 1
        self.submissions += 1
        with trace.span(self.span_name, wave=self.waves,
                        batch=self.current_batch):
            return fn()

    def stats(self) -> Dict[str, int]:
        return {"waves": self.waves, "submissions": self.submissions,
                "current_batch": self.current_batch}


class Scheduler:
    """Wave scheduler: FIFO queue packed into max_batch waves.

    With ``max_parallel_waves > 1`` waves run overlapped on a thread pool
    (each wave has its own KV cache; the jitted functions are shared and
    thread-safe).  Completions are collected in submission order either
    way, so output ordering is deterministic."""

    def __init__(self, session: ServeSession) -> None:
        self.session = session
        self.queue: List[Request] = []
        self.completed: List[Completion] = []
        self._metrics_server = None
        self._closed = False
        port = session.scfg.metrics_port
        if port is not None:
            self._metrics_server = metrics.start_http_server(port)
            # the /metrics listener is a non-daemon resource holding a
            # socket: guarantee it is torn down at interpreter exit even
            # when the caller forgets close()
            atexit.register(self.close)

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def run(self) -> List[Completion]:
        waves = []
        while self.queue:
            waves.append(self.queue[: self.session.scfg.max_batch])
            self.queue = self.queue[self.session.scfg.max_batch:]
        parallel = max(1, self.session.scfg.max_parallel_waves)
        with trace.span("serve/schedule", waves=len(waves),
                        parallel=parallel):
            if parallel == 1 or len(waves) <= 1:
                for wave in waves:
                    self.completed.extend(self.session.run_wave(wave))
            else:
                from concurrent.futures import ThreadPoolExecutor
                run_wave = trace.bind(self.session.run_wave)
                with ThreadPoolExecutor(max_workers=parallel) as pool:
                    for done in pool.map(run_wave, waves):
                        self.completed.extend(done)
        return self.completed

    def close(self) -> None:
        """Shut down the /metrics endpoint.  Idempotent: safe to call
        any number of times, from user code and from the atexit hook
        (double shutdown of a ThreadingHTTPServer deadlocks — the
        ``_closed`` latch makes every call after the first a no-op)."""
        if self._closed:
            return
        self._closed = True
        if self._metrics_server is not None:
            self._metrics_server.shutdown()
            self._metrics_server.server_close()   # release the socket
            self._metrics_server = None
            atexit.unregister(self.close)
