"""Multi-tenant serving front door over the polystore (ROADMAP
direction 3; the BigDAWG papers' framing of the polystore as a
*service* — clients hit one API, the middleware handles placement and
degradation).

One :class:`FrontDoor` fronts a ``BigDawg`` deployment.  Tenants open
sessions, register standing BQL queries against the shared streams,
and poll ticked results:

    door = FrontDoor(bd, ServeConfig(streams=(spec,)))
    session = door.open_session("tenant-a")
    sub = session.subscribe("bdstream(window_avg(S, 8, v))")
    feed.append(...); bd.streams.tick()
    for tick_no, value in sub.poll(): ...

Four responsibilities, each riding an existing layer:

- **Admission control** — hard capacity caps (``max_tenants``,
  ``max_queries_per_tenant``) plus a load circuit breaker fed by
  ``Monitor.stream_stats`` / ``ingest_concurrency()``: once the
  deployment's standing queries have dropped or lagged past the
  configured thresholds since the door opened (or in-flight ingest
  exceeds its bound *right now*), new sessions and subscriptions are
  refused with :class:`AdmissionError` until an operator calls
  ``reset_admission()``.  Serving the tenants already admitted beats
  melting down for new ones.

- **Plan-cache warm sharing** — subscriptions are deduplicated by
  ``(bql, cadence)`` into one shared :class:`ContinuousQuery`: N
  tenants asking the same question cost one execution per tick (and
  one signature-keyed plan-cache entry, the PR-1 cache), fanned out to
  N result buffers.  The house bit-identity invariant extends here:
  results via the front door ≡ direct ``register_continuous``.

- **Backpressure** — each subscription owns a bounded result buffer;
  a consumer that stops polling loses its *oldest* results (counted,
  per subscription and globally) instead of growing the process
  without bound.  The tick never blocks on a slow tenant.

- **Replica fan-out** — ``replicate()`` builds read replicas of hot
  streams through the Migrator's stream-route *copy* mode; durable
  primaries' replicas are caught up incrementally from the segment
  log (``durability.catch_up``), so snapshot reads scale across
  engines without forking the primary's seq space.

Results are delivered by a ``StreamRuntime`` tick listener, so both
cooperative ticks and the background driver feed subscriptions.  The
front door speaks :class:`~repro.stream.spec.StreamSpec` only — the
legacy ``register_stream`` kwargs never reach this layer.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.obs import metrics, trace
from repro.serve.engine import ServeConfig
from repro.stream.spec import StreamSpec

_SUB_IDS = itertools.count()
_CQ_PREFIX = "fd"


class AdmissionError(Exception):
    """The front door refused a session/subscription: capacity cap hit
    or the load circuit breaker is open."""


class Subscription:
    """One tenant's attachment to a (possibly shared) standing query:
    a bounded buffer of ``(tick, value)`` results.

    The buffer is the backpressure boundary — when the tenant polls
    slower than ticks produce, the oldest results are dropped and
    counted (``dropped``); the tick is never blocked by a slow
    consumer."""

    def __init__(self, sub_id: int, tenant: str, bql: str,
                 every_n_ticks: int, buffer: int) -> None:
        self.sub_id = sub_id
        self.tenant = tenant
        self.bql = bql
        self.every_n_ticks = every_n_ticks
        self.delivered = 0
        self.dropped = 0
        self._buffer: "collections.deque[Tuple[int, Any]]" = \
            collections.deque(maxlen=max(1, int(buffer)))
        self._lock = threading.Lock()
        self.closed = False

    def _push(self, tick: int, value: Any) -> None:
        with self._lock:
            if len(self._buffer) == self._buffer.maxlen:
                self._buffer.popleft()
                self.dropped += 1
            self._buffer.append((tick, value))
            self.delivered += 1

    def poll(self, max_items: Optional[int] = None
             ) -> List[Tuple[int, Any]]:
        """Drain up to ``max_items`` buffered ``(tick, value)`` results
        (all of them by default), oldest first."""
        out: List[Tuple[int, Any]] = []
        with self._lock:
            while self._buffer and (max_items is None
                                    or len(out) < max_items):
                out.append(self._buffer.popleft())
        return out

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._buffer)


class _SharedQuery:
    """One registered ContinuousQuery plus the subscriptions fanned out
    from it (the warm-sharing unit: one execution per tick, N
    deliveries)."""

    def __init__(self, cq, key: Tuple[str, int]) -> None:
        self.cq = cq
        self.key = key
        self.subs: List[Subscription] = []


class TenantSession:
    """One tenant's handle on the front door.  Cheap: sessions hold no
    threads; every subscription shares the deployment's single
    StreamRuntime."""

    def __init__(self, door: "FrontDoor", tenant: str) -> None:
        self.door = door
        self.tenant = tenant
        self.subscriptions: List[Subscription] = []
        self.closed = False

    def subscribe(self, bql: str,
                  every_n_ticks: int = 1) -> Subscription:
        """Register a standing BQL query; results arrive in the
        returned subscription's buffer on every due tick.  Identical
        ``(bql, every_n_ticks)`` across tenants share one execution
        (and one warm plan-cache entry)."""
        return self.door._subscribe(self, bql, every_n_ticks)

    def unsubscribe(self, sub: Subscription) -> None:
        self.door._unsubscribe(self, sub)

    def read(self, stream: str, n: Optional[int] = None):
        """Snapshot read of ``stream`` served from a read replica when
        one exists (round-robin over replicas; primary otherwise).
        Returns the last ``n`` rows as a Table (the whole ring with
        ``n=None``)."""
        return self.door.read(stream, n)

    def close(self) -> None:
        self.door._close_session(self)


class FrontDoor:
    """The multi-tenant query service over one BigDawg deployment."""

    def __init__(self, bd, config: Optional[ServeConfig] = None, *,
                 stream_engine: Optional[str] = None,
                 max_tenants: int = 64,
                 max_queries_per_tenant: int = 8,
                 result_buffer: int = 64,
                 admit_max_dropped: Optional[int] = None,
                 admit_max_backpressure: Optional[int] = None,
                 admit_max_inflight_rows: Optional[int] = None) -> None:
        self.bd = bd
        self.config = config or ServeConfig()
        self.max_tenants = int(max_tenants)
        self.max_queries_per_tenant = int(max_queries_per_tenant)
        self.result_buffer = int(result_buffer)
        self.admit_max_dropped = admit_max_dropped
        self.admit_max_backpressure = admit_max_backpressure
        self.admit_max_inflight_rows = admit_max_inflight_rows
        self._lock = threading.RLock()
        self.sessions: Dict[str, TenantSession] = {}
        self._shared: Dict[Tuple[str, int], _SharedQuery] = {}
        self._by_cq_name: Dict[str, _SharedQuery] = {}
        # replicas: logical stream -> [(replica name, engine name)]
        self._replicas: Dict[str, List[Tuple[str, str]]] = {}
        self._replica_rr: Dict[str, int] = {}
        self.sessions_opened = 0
        self.admission_rejects = 0
        self.results_delivered = 0
        self.results_dropped = 0
        self.shared_attaches = 0     # subscriptions served by an
        #                              already-registered shared query
        self._fanout_seconds: "collections.deque[float]" = \
            collections.deque(maxlen=512)
        # provision the config's streams (spec-only surface) on one
        # StreamEngine — sharded specs spread themselves via
        # ensure_stream_engines inside registration
        if stream_engine is None:
            stream_engine = bd.ensure_stream_engines(1)[0]
        self.stream_engine = stream_engine
        for spec in self.config.streams:
            if not isinstance(spec, StreamSpec):
                raise TypeError(
                    f"ServeConfig.streams must hold StreamSpec values, "
                    f"got {type(spec).__name__}")
            bd.register_stream(stream_engine, spec)
        # admission baseline: the circuit breaker measures load
        # accumulated SINCE the door opened, not deployment lifetime
        self._baseline = self._load_totals()
        bd.streams.add_tick_listener(self._on_tick)
        self.closed = False
        self._observe()

    # -- admission -------------------------------------------------------------
    def _load_totals(self) -> Tuple[int, int]:
        snap = self.bd.monitor.snapshot()
        dropped = sum(s.get("dropped", 0)
                      for s in snap["stream_stats"].values())
        backpressure = sum(s.get("backpressure", 0)
                           for s in snap["stream_stats"].values())
        return dropped, backpressure

    def _inflight_rows(self) -> int:
        snap = self.bd.monitor.snapshot()
        return sum(s.get("in_flight_rows", 0)
                   for s in snap["ingest_stats"].values())

    def _check_load(self, what: str) -> None:
        """The load circuit breaker: refuse new work while the
        deployment is visibly shedding (drops/lag since the door
        opened past threshold) or ingest is flooded right now."""
        dropped, backpressure = self._load_totals()
        d0, b0 = self._baseline
        reasons = []
        if (self.admit_max_dropped is not None
                and dropped - d0 > self.admit_max_dropped):
            reasons.append(f"{dropped - d0} rows dropped "
                           f"(> {self.admit_max_dropped})")
        if (self.admit_max_backpressure is not None
                and backpressure - b0 > self.admit_max_backpressure):
            reasons.append(f"{backpressure - b0} lagging executions "
                           f"(> {self.admit_max_backpressure})")
        if self.admit_max_inflight_rows is not None:
            inflight = self._inflight_rows()
            if inflight > self.admit_max_inflight_rows:
                reasons.append(f"{inflight} rows in flight "
                               f"(> {self.admit_max_inflight_rows})")
        if reasons:
            self._reject(what, "; ".join(reasons))

    def _reject(self, what: str, why: str) -> None:
        with self._lock:
            self.admission_rejects += 1
        metrics.counter("repro_serve_admission_rejects_total",
                        "front-door admissions refused").inc()
        self._observe()
        raise AdmissionError(f"{what} refused: {why}")

    def reset_admission(self) -> None:
        """Re-arm the load circuit breaker: future admission decisions
        measure drops/lag from now (the operator's 'the incident is
        over' lever)."""
        self._baseline = self._load_totals()

    # -- sessions & subscriptions ----------------------------------------------
    def open_session(self, tenant: str) -> TenantSession:
        """Admit a tenant (capacity cap + load circuit breaker) and
        hand back its session."""
        with trace.span("serve/open_session", tenant=tenant):
            with self._lock:
                if tenant in self.sessions:
                    return self.sessions[tenant]
                if len(self.sessions) >= self.max_tenants:
                    at = len(self.sessions)
                else:
                    at = None
            if at is not None:
                self._reject(f"session for {tenant!r}",
                             f"at max_tenants={self.max_tenants}")
            self._check_load(f"session for {tenant!r}")
            with self._lock:
                session = TenantSession(self, tenant)
                self.sessions[tenant] = session
                self.sessions_opened += 1
            metrics.counter("repro_serve_sessions_total",
                            "front-door sessions opened").inc()
            self._observe()
            return session

    def _subscribe(self, session: TenantSession, bql: str,
                   every_n_ticks: int) -> Subscription:
        if session.closed:
            raise AdmissionError(
                f"session for {session.tenant!r} is closed")
        with self._lock:
            over = (len(session.subscriptions)
                    >= self.max_queries_per_tenant)
        if over:
            self._reject(
                f"subscription for {session.tenant!r}",
                f"at max_queries_per_tenant="
                f"{self.max_queries_per_tenant}")
        self._check_load(f"subscription for {session.tenant!r}")
        key = (bql, int(every_n_ticks))
        with trace.span("serve/subscribe", tenant=session.tenant,
                        cadence=every_n_ticks) as sp:
            with self._lock:
                shared = self._shared.get(key)
                if shared is None:
                    cq = self.bd.streams.register_continuous(
                        bql, every_n_ticks=every_n_ticks,
                        name=f"{_CQ_PREFIX}{next(_SUB_IDS)}")
                    shared = _SharedQuery(cq, key)
                    self._shared[key] = shared
                    self._by_cq_name[cq.name] = shared
                else:
                    # warm sharing: this tenant rides the existing
                    # execution and its already-populated plan cache
                    self.shared_attaches += 1
                sub = Subscription(next(_SUB_IDS), session.tenant,
                                   bql, every_n_ticks,
                                   self.result_buffer)
                shared.subs.append(sub)
                session.subscriptions.append(sub)
                sp.set(query=shared.cq.name,
                       fanout=len(shared.subs))
        self._observe()
        return sub

    def _unsubscribe(self, session: TenantSession,
                     sub: Subscription) -> None:
        with self._lock:
            sub.closed = True
            if sub in session.subscriptions:
                session.subscriptions.remove(sub)
            key = (sub.bql, sub.every_n_ticks)
            shared = self._shared.get(key)
            if shared is not None and sub in shared.subs:
                shared.subs.remove(sub)
                if not shared.subs:
                    # last subscriber gone: stop executing the query
                    self._shared.pop(key, None)
                    self._by_cq_name.pop(shared.cq.name, None)
                    self.bd.streams.deregister(shared.cq.name)
        self._observe()

    def _close_session(self, session: TenantSession) -> None:
        with self._lock:
            subs = list(session.subscriptions)
        for sub in subs:
            self._unsubscribe(session, sub)
        with self._lock:
            session.closed = True
            self.sessions.pop(session.tenant, None)
        self._observe()

    # -- result fan-out (StreamRuntime tick listener) --------------------------
    def _on_tick(self, tick_no: int, ran) -> None:
        t0 = time.perf_counter()
        delivered = dropped = 0
        with self._lock:
            targets = [(self._by_cq_name[name], response)
                       for name, response in ran
                       if name in self._by_cq_name]
            fanouts = [(shared.subs[:], response)
                       for shared, response in targets]
        for subs, response in fanouts:
            for sub in subs:
                before = sub.dropped
                sub._push(tick_no, response.value)
                delivered += 1
                dropped += sub.dropped - before
        if fanouts:
            took = time.perf_counter() - t0
            with self._lock:
                self.results_delivered += delivered
                self.results_dropped += dropped
                self._fanout_seconds.append(took)
            metrics.counter("repro_serve_results_delivered_total",
                            "results fanned out to tenant "
                            "subscriptions").inc(delivered)
            if dropped:
                metrics.counter(
                    "repro_serve_results_dropped_total",
                    "results dropped by subscription backpressure"
                ).inc(dropped)
            metrics.histogram("repro_serve_fanout_seconds",
                              "per-tick result fan-out time").observe(
                took)
        self._observe()

    # -- replica fan-out reads -------------------------------------------------
    def replicate(self, stream: str, n: int = 1,
                  engines: Optional[List[str]] = None) -> List[str]:
        """Build ``n`` read replicas of ``stream`` via the Migrator's
        stream-route copy mode, spread over ``engines`` (auto-grown
        StreamEngines by default).  Durable primaries' replicas carry
        segment-log positions, so ``refresh_replicas`` can catch them
        up incrementally."""
        from repro.stream.engine import StreamEngine
        primary, home = self._find_stream(stream)
        if engines is None:
            engines = self.bd.ensure_stream_engines(max(2, n))
            engines = [e for e in engines if e != home][:n] or engines[:n]
        created = []
        with trace.span("serve/replicate", stream=stream, n=n):
            for i in range(n):
                ename = engines[i % len(engines)]
                engine_to = self.bd.engines[ename]
                if not isinstance(engine_to, StreamEngine):
                    raise TypeError(f"{ename!r} is not a StreamEngine")
                existing = self._replicas.get(stream, [])
                rname = f"{stream}.replica{len(existing) + i}"
                from repro.core.migrator import MigrationParams
                self.bd.migrator.migrate(
                    self.bd.engines[home], stream, engine_to, rname,
                    MigrationParams(method="stream", copy=True))
                created.append((rname, ename))
        with self._lock:
            self._replicas.setdefault(stream, []).extend(created)
        self._observe()
        return [r for r, _ in created]

    def refresh_replicas(self, stream: str) -> Dict[str, int]:
        """Catch every replica of ``stream`` up to the primary's
        durable frontier by replaying the segment-log delta (no-op
        rows=0 for an already-current replica).  Requires a durable
        primary."""
        from repro.stream import durability as dur
        primary, _ = self._find_stream(stream)
        durable = getattr(primary, "_durable", None)
        if durable is None:
            raise AdmissionError(
                f"stream {stream!r} has no durability attached — "
                f"replicas cannot be caught up from a segment log")
        out = {}
        with self._lock:
            replicas = list(self._replicas.get(stream, []))
        for rname, ename in replicas:
            replica = self.bd.engines[ename].get(rname)
            out[rname] = dur.catch_up(replica, durable)["rows"]
        return out

    def read(self, stream: str, n: Optional[int] = None):
        """Snapshot/window read served from a read replica when one
        exists (round-robin), else the primary."""
        with self._lock:
            replicas = self._replicas.get(stream)
            if replicas:
                idx = self._replica_rr.get(stream, 0)
                self._replica_rr[stream] = (idx + 1) % len(replicas)
                rname, ename = replicas[idx % len(replicas)]
            else:
                rname = ename = None
        if rname is not None:
            target = self.bd.engines[ename].get(rname)
        else:
            target, _ = self._find_stream(stream)
        with trace.span("serve/read", stream=stream,
                        replica=rname or ""):
            return (target.snapshot() if n is None
                    else target.window(int(n)))

    def _find_stream(self, name: str) -> Tuple[Any, str]:
        from repro.stream.engine import StreamEngine
        for ename, engine in self.bd.engines.items():
            if isinstance(engine, StreamEngine) \
                    and name in engine.streams():
                return engine.streams()[name], ename
        raise KeyError(f"no StreamEngine serves a stream {name!r}")

    # -- status ----------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            lats = sorted(
                lat for shared in self._shared.values()
                for lat in shared.cq.latencies)
            fan = sorted(self._fanout_seconds)

            def pct(xs, q):
                return (round(xs[min(len(xs) - 1,
                                     int(q * len(xs)))] * 1e3, 3)
                        if xs else 0.0)

            return {
                "tenants": len(self.sessions),
                "subscriptions": sum(len(s.subscriptions)
                                     for s in self.sessions.values()),
                "shared_queries": len(self._shared),
                "shared_attaches": self.shared_attaches,
                "sessions_opened": self.sessions_opened,
                "admission_rejects": self.admission_rejects,
                "results_delivered": self.results_delivered,
                "results_dropped": self.results_dropped,
                "replicas": sum(len(v)
                                for v in self._replicas.values()),
                "p50_tick_ms": pct(lats, 0.50),
                "p99_tick_ms": pct(lats, 0.99),
                "p99_fanout_ms": pct(fan, 0.99),
            }

    def _observe(self) -> None:
        self.bd.monitor.observe_serve(self.stats())

    def close(self) -> None:
        """Tear the front door down: stop fan-out, close every session,
        deregister the shared queries.  Idempotent.  Replicas are left
        in place (they are engine objects an operator may still
        inspect)."""
        if self.closed:
            return
        self.closed = True
        self.bd.streams.remove_tick_listener(self._on_tick)
        with self._lock:
            sessions = list(self.sessions.values())
        for session in sessions:
            self._close_session(session)
        self._observe()
