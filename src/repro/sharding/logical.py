"""Logical-axis sharding: every parameter / activation is labeled with logical
axis names; a rules table maps logical names onto physical mesh axes.

This is the mechanism that gives the polystore *location independence*
(DESIGN.md §2): model code never names a mesh axis, only logical roles.
The catalog's engine assignment for an object resolves to a rules table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Canonical logical axis names used throughout the model zoo.
# ---------------------------------------------------------------------------
BATCH = "batch"            # global batch             -> (pod, data)
SEQ = "seq"                # sequence (activations)   -> None (or sp)
RESID = "resid_seq"        # block-boundary residual  -> model under SP
KV_SEQ = "kv_seq"          # KV-cache sequence        -> model iff heads don't divide
EMBED = "embed"            # d_model (PARAMS)         -> data (FSDP)
ACT_EMBED = "act_embed"    # d_model (ACTIVATIONS)    -> None (gathered)
HEADS = "heads"            # q heads                  -> model (TP)
KV_HEADS = "kv_heads"      # kv heads                 -> model iff divisible
HEAD_DIM = "head_dim"      # per-head dim             -> None
MLP = "mlp"                # ffn hidden               -> model (TP)
VOCAB = "vocab"            # vocab rows               -> model (TP)
EXPERT = "expert"          # MoE experts              -> model (EP)
CAPACITY = "capacity"      # MoE per-expert capacity  -> None
LAYER = "layer"            # stacked scan axis        -> None (never sharded)
STATE = "state"            # SSM state dim            -> None
CONV = "conv"              # conv kernel width        -> None


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis -> mesh axis (or tuple of mesh axes, or None).

    Carries the mesh so ``constrain`` can build NamedShardings directly —
    bare-PartitionSpec with_sharding_constraint requires an ambient mesh
    context and otherwise raises; silently losing activation constraints
    was §Perf finding A1/A4 (SPMD propagation alone replicates S² scores).
    """

    rules: Tuple[Tuple[str, Union[None, str, Tuple[str, ...]]], ...]
    mesh: Optional[Mesh] = dataclasses.field(default=None, compare=False)

    def mesh_axes(self, logical: Optional[str]):
        if logical is None:
            return None
        for name, target in self.rules:
            if name == logical:
                return target
        return None

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        return P(*(self.mesh_axes(ax) for ax in logical_axes))

    def replace(self, **updates) -> "AxisRules":
        new = dict(self.rules)
        new.update(updates)
        return AxisRules(tuple(new.items()), mesh=self.mesh)


def default_rules(mesh: Mesh, *, shard_kv_seq: bool = False,
                  seq_parallel: bool = False) -> AxisRules:
    """Production rules for the (pod?, data, model) mesh.

    ``batch``/``embed`` ride the (pod,)data axes (DP + FSDP); head/mlp/vocab/
    expert dims ride model (TP/EP).  When an arch's kv_heads don't divide the
    model axis, the KV cache is sequence-sharded instead (``shard_kv_seq``);
    XLA SPMD inserts the softmax all-reduces.  ``seq_parallel`` shards the
    block-boundary residual stream over model (Megatron-SP expressed purely
    as a sharding constraint: XLA all-gathers at block entry and
    reduce-scatters at exit), dividing saved-activation memory by the TP
    degree (DESIGN.md §5).
    """
    axes = mesh.axis_names
    batch_axes: Union[str, Tuple[str, ...]]
    if "pod" in axes:
        batch_axes = ("pod", "data")
    else:
        batch_axes = "data"
    return AxisRules(
        (
            (BATCH, batch_axes),
            (SEQ, None),
            (RESID, "model" if seq_parallel else None),
            (ACT_EMBED, None),
        ) + _default_tail(shard_kv_seq), mesh=mesh)


def _default_tail(shard_kv_seq: bool):
    return (
            (KV_SEQ, "model" if shard_kv_seq else None),
            (EMBED, "data"),
            (HEADS, "model"),
            (KV_HEADS, "model" if not shard_kv_seq else None),
            (HEAD_DIM, None),
            (MLP, "model"),
            (VOCAB, "model"),
            (EXPERT, "model"),
            (CAPACITY, "data"),       # dispatch slots ride the FSDP axis
            (LAYER, None),
            (STATE, None),
            (CONV, None),
    )


def single_device_rules() -> AxisRules:
    """Rules that map everything to None — CPU smoke tests."""
    return AxisRules(tuple((name, None) for name in (
        BATCH, SEQ, RESID, KV_SEQ, EMBED, ACT_EMBED, HEADS, KV_HEADS,
        HEAD_DIM, MLP, VOCAB, EXPERT, CAPACITY, LAYER, STATE, CONV)))


# ---------------------------------------------------------------------------
# ParamSpec: declarative parameter description (shape, dtype, logical axes,
# initializer).  Model code builds pytrees of these; the launcher turns them
# into either real arrays (init) or ShapeDtypeStructs (dry-run).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"      # normal | zeros | ones | embed_normal
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def num_params(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def spec_tree_structs(spec_tree) -> Any:
    return jax.tree.map(
        lambda s: s.struct(), spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree_axes(spec_tree) -> Any:
    return jax.tree.map(
        lambda s: s.axes, spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def sharding_for(spec: ParamSpec, mesh: Mesh, rules: AxisRules
                 ) -> NamedSharding:
    """NamedSharding for a spec, dropping axes that don't divide evenly
    (e.g. 12 q-heads on a 16-wide model axis fall back to replicated;
    recorded as a hillclimb opportunity in EXPERIMENTS.md §Perf)."""
    parts = []
    for dim, ax in zip(spec.shape, spec.axes):
        target = rules.mesh_axes(ax)
        if target is None:
            parts.append(None)
            continue
        axes = target if isinstance(target, tuple) else (target,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        parts.append(target if dim % size == 0 else None)
    return NamedSharding(mesh, P(*parts))


def spec_tree_shardings(spec_tree, mesh: Mesh, rules: AxisRules):
    return jax.tree.map(
        lambda s: sharding_for(s, mesh, rules), spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(spec_tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)):
        total += leaf.num_params()
    return total


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = spec.init_scale / max(1.0, float(fan_in)) ** 0.5
        return (scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "embed_normal":
        return (spec.init_scale * 0.02
                * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init}")


def init_params(key: jax.Array, spec_tree):
    """Materialize a ParamSpec tree into arrays (CPU smoke / real training)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def constrain(x: jax.Array, rules: Optional[AxisRules],
              logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op when rules is None.

    Axes whose dimension does not divide the mapped mesh-axis size fall
    back to replicated (same policy as ``sharding_for``)."""
    if rules is None:
        return x
    if rules.mesh is not None:
        parts = []
        for dim, ax in zip(x.shape, logical_axes):
            target = rules.mesh_axes(ax)
            if target is None:
                parts.append(None)
                continue
            axes = target if isinstance(target, tuple) else (target,)
            size = 1
            for a in axes:
                size *= rules.mesh.shape[a]
            parts.append(target if dim % size == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, P(*parts)))
    spec = rules.spec(logical_axes)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        # Outside a mesh context (CPU smoke tests) constraints are a no-op.
        return x
