"""Streaming island (paper §III; arXiv:1609.07548 §"S-Store"): the
BigDAWG architecture papers define a streaming engine as a first-class
polystore member alongside the relational, array and text engines.  This
package is that member for the reproduction:

  engine.py     — ``Stream`` (append-only bounded ring buffer) and
                  ``StreamEngine`` (S-Store analog, Catalog-registered)
  shim.py       — the streaming island language (append / window /
                  aggregate / rate / snapshot), windows materialized as
                  ``dm.ArrayObject`` / ``dm.Table``
  continuous.py — standing queries: ``register_continuous`` compiles a BQL
                  query once and re-executes it per tick through the
                  Planner's signature plan cache + concurrent Executor
"""
from repro.stream.continuous import ContinuousQuery, StreamRuntime
from repro.stream.engine import Stream, StreamEngine

__all__ = ["ContinuousQuery", "Stream", "StreamEngine", "StreamRuntime"]
