"""Compiled standing-query path: streaming sub-plans lowered onto XLA.

The BQL interpreter evaluates every streaming expression with numpy on
the caller's thread, so per-tick standing queries are GIL-bound no
matter how concurrent the ingest side got (arXiv:1905.10336's point
that polystores need accelerator offload).  This module compiles the
streaming op family —

  window(S, n)             tumbling gather     (device dynamic-slice)
  window(S, n, s)          sliding gather      (one 2-D device gather
                                               replacing the Python
                                               stacking loop)
  ewindow(S, span[, s])    event-time gather   (host binary search for
                                               the bounds, device
                                               gather for the rows)
  aggregate(window(S,n),f) rolling aggregate   (lowered to the O(1)
                                               cumulative-ring lookup —
                                               already the optimal plan
                                               stage — or the Pallas
                                               min/max scan kernel)
  aggregate(<window>, f)   windowed aggregate  (compiled gather feeding
                                               the data model's jnp
                                               reduction unchanged)
  join(W1, W2, on, tol)    banded interval join (device searchsorted /
                                               Pallas bound search +
                                               pair expansion over
                                               padded buckets)

— into jitted functions over the stream's exported ring arrays.  A
standing query compiles once per (stream, normalized sub-query) — the
streaming analog of the Planner's signature-keyed plan cache, and the
two compose: the PlanCache skips plan enumeration, this cache skips
re-lowering, and jax's jit cache keys the residual static shapes.

House invariant: the compiled path is **bit-identical** to the
interpreter.  Every lowering is exact by construction — gathers and
dynamic slices move bits, the join matcher is integer index math over
the same widened float64 keys the interpreter searches, the rolling
aggregate reuses the same cumulative-ring subtraction (sum/avg are
order-sensitive, so they never leave it; min/max are exactly
associative, so the Pallas scan may take them), and windowed aggregates
feed the identical jnp reduction the interpreter calls — and every
output passes through the same dtype canonicalization the interpreter
applies.  The jit-parity CI lane runs the property + event-time suites
under both backends and diffs results.

x64/platform config (the bayespec exemplar): stream rings are float64,
and jax downcasts to float32 by default, so compiled computation runs
inside a **scoped** ``jax.experimental.enable_x64`` context — exact
float64 in the kernels, zero config leakage into the rest of the
process — and outputs cast back to the ambient default dtype inside
the jitted function, which is bitwise what the interpreter's
``jnp.asarray`` does to its float64 numpy results.  This module is the
only place allowed to touch jax config (ruff TID251 bans
``jax.config.update`` everywhere else; ``jax_enable_x64`` /
``set_platform`` below are the explicit process-wide switches for
operators who want global x64 or a TPU backend).

Backend selection: ``REPRO_QUERY_BACKEND=interpreter`` (default) or
``jit``, read per query so tests can flip it per-case.  Queries outside
the family stay on the interpreter by design (the ``interpreted``
counter); family queries that cannot compile — jax absent, non-finite
join keys — fall back and are counted in ``stats()`` (fed to the
Monitor every tick and surfaced by ``admin.status()``).
"""
from __future__ import annotations

import functools
import os
import re
import threading
import weakref
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import datamodel as dm
from repro.obs import metrics, trace
from repro.stream import kernels
from repro.stream.engine import (_COMBINABLE_AGGS, ShardedStream, Stream,
                                 StreamException, _latest_closed_ewindow)

try:                                         # gate: jax may be absent
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64 as _x64_scope
    JAX_AVAILABLE = True
except Exception:                            # noqa: BLE001 — optional dep
    jax = jnp = _x64_scope = None            # type: ignore
    JAX_AVAILABLE = False

BACKEND_ENV = "REPRO_QUERY_BACKEND"
BACKENDS = ("interpreter", "jit")

# -- lifetime counters (reset via reset_stats; surfaced through Monitor) ----
_STATS_LOCK = threading.Lock()
_STATS: Dict[str, int] = {}
_FALLBACK_REASONS: Dict[str, int] = {}


def _reset_locked() -> None:
    _STATS.clear()
    _STATS.update(compiles=0, cache_hits=0, executions=0,
                  fallbacks=0, interpreted=0)
    _FALLBACK_REASONS.clear()


_reset_locked()


def backend() -> str:
    """The active query backend (env-driven, read per query)."""
    value = os.environ.get(BACKEND_ENV, "interpreter").strip().lower()
    return value if value in BACKENDS else "interpreter"


def stats() -> Dict[str, Any]:
    """Compiled-path health: plan compiles vs cache hits, jitted
    executions, interpreter fallbacks (with reasons), and queries the
    interpreter serves by design (ops outside the compiled family)."""
    with _STATS_LOCK:
        out: Dict[str, Any] = dict(_STATS)
        out["backend"] = backend()
        out["jax_available"] = JAX_AVAILABLE
        out["fallback_reasons"] = dict(_FALLBACK_REASONS)
        return out


def reset_stats() -> None:
    with _STATS_LOCK:
        _reset_locked()


def _bump(key: str, n: int = 1) -> None:
    with _STATS_LOCK:
        _STATS[key] += n
    metrics.counter("repro_compile_events_total",
                    "compiled query path events (compiles, cache hits, "
                    "executions, by-design interpreted)",
                    event=key).inc(n)


def _fallback(reason: str) -> None:
    with _STATS_LOCK:
        _STATS["fallbacks"] += 1
        _FALLBACK_REASONS[reason] = _FALLBACK_REASONS.get(reason, 0) + 1
    metrics.counter("repro_compile_fallbacks_total",
                    "compiled plans that fell back to the interpreter",
                    reason=reason).inc()


# -- explicit process-wide config switches (operator-facing; the per-tick
# path never calls these — it uses the scoped x64 context instead) ----------
def jax_enable_x64(use_x64: Optional[bool] = None) -> None:
    """Flip jax's global float64 mode, honoring ``JAX_ENABLE_X64`` when
    no explicit value is given (the bayespec idiom).  Affects the whole
    process — every jnp array created afterwards defaults to 64-bit."""
    if not JAX_AVAILABLE:
        return
    if use_x64 is None:
        use_x64 = bool(int(os.environ.get("JAX_ENABLE_X64", "0")))
    jax.config.update("jax_enable_x64", bool(use_x64))


def set_platform(platform: Optional[str] = None) -> None:
    """Pin jax's platform, honoring ``JAX_PLATFORMS`` when no explicit
    value is given (CI sets ``JAX_PLATFORMS=cpu``; on a TPU host pass
    ``"tpu"``)."""
    if not JAX_AVAILABLE:
        return
    if platform is None:
        platform = os.environ.get("JAX_PLATFORMS", "cpu")
    jax.config.update("jax_platform_name", platform.split(",")[0])


def _out_dtype():
    """The dtype the interpreter's ``jnp.asarray`` canonicalizes float64
    to under the *current global* config — compiled outputs cast to the
    same, so parity holds with or without process-wide x64.  Pure host
    dtype math (no device dispatch: this runs on every tick)."""
    return jax.dtypes.canonicalize_dtype(np.float64)


def _pow2(n: int) -> int:
    """Static-shape bucket: next power of two >= max(n, 16), so varying
    data sizes re-trace the jitted functions O(log n) times, not O(n)."""
    b = 16
    while b < n:
        b <<= 1
    return b


# -- jitted primitives ------------------------------------------------------
# All of these trace under the scoped x64 context (float64 in, exact),
# and cast to the interpreter's canonical dtype as the last op.

@functools.partial(jax.jit if JAX_AVAILABLE else lambda f, **k: f,
                   static_argnames=("size", "out_dtype"))
def _jit_tumbling(cols, off, size, out_dtype):
    """(F, capacity) ordered ring -> (F, size) window at offset ``off``
    (always fully in bounds: the eviction check ran on the host)."""
    out = jax.lax.dynamic_slice(cols, (0, off), (cols.shape[0], size))
    return out.astype(out_dtype)


@functools.partial(jax.jit if JAX_AVAILABLE else lambda f, **k: f,
                   static_argnames=("size", "slide", "max_windows",
                                    "out_dtype"))
def _jit_sliding(cols, size, slide, max_windows, out_dtype):
    """(F, capacity) ordered ring -> (F, max_windows, size) stacked
    sliding windows — replacing the interpreter's Python stacking loop.
    Every window start is static (``max_windows`` keeps the last slice
    inside the ring by construction), so XLA lowers the stack of slices
    to straight copies — no per-element gather index math.  Windows
    past the live count hold garbage the host slices away."""
    wins = [jax.lax.slice_in_dim(cols, i * slide, i * slide + size,
                                 axis=1) for i in range(max_windows)]
    return jnp.stack(wins, axis=1).astype(out_dtype)


@functools.partial(jax.jit if JAX_AVAILABLE else lambda f, **k: f,
                   static_argnames=("length", "out_dtype"))
def _jit_rows(cols, off, length, out_dtype):
    """(F, capacity) -> (F, length) rows starting at ``off`` — the
    ewindow gather, clip-indexed so the static padded length never
    reads out of bounds; the host slices the live prefix."""
    idx = off + jnp.arange(length, dtype=jnp.int32)
    idx = jnp.clip(idx, 0, cols.shape[1] - 1)
    return cols[:, idx].astype(out_dtype)


@functools.partial(jax.jit if JAX_AVAILABLE else lambda f, **k: f)
def _jit_join_bounds(lt, rt, tol):
    """Per-left-row match bounds against the sorted right keys.

    Both key arrays are float64 (widened exactly like the interpreter's
    ``np.asarray(v, np.float64)``) padded with +inf, so the stable sort
    parks padding at the tail and real searches never reach it.  jax's
    searchsorted/stable-argsort match numpy's bit for bit (the parity
    suite pins this), so (lo, hi, order) equal the interpreter's."""
    order = jnp.argsort(rt, stable=True)
    rs = rt[order]
    lo = jnp.searchsorted(rs, lt - tol, side="left")
    hi = jnp.searchsorted(rs, lt + tol, side="right")
    return lo, hi, order


@functools.partial(jax.jit if JAX_AVAILABLE else lambda f, **k: f)
def _jit_join_bounds_pallas(lt, rt, tol):
    """The Pallas lowering of the bound search (REPRO_STREAM_PALLAS=1):
    same (lo, hi, order) by construction — the kernel's bisection is
    bit-identical to searchsorted on sorted keys."""
    order = jnp.argsort(rt, stable=True)
    rs = rt[order]
    lo, hi = kernels.join_bounds(lt, rs, tol)
    return lo.astype(order.dtype), hi.astype(order.dtype), order


@functools.partial(jax.jit if JAX_AVAILABLE else lambda f, **k: f,
                   static_argnames=("pairs", "out_dtype"))
def _jit_join_gather(lcols, rcols, lt, rt, lo, cum, order,
                     pairs, out_dtype):
    """Expand (lo, counts) into the interpreter's pair list — ordered by
    left row, then right timestamp — and gather both sides plus
    ``dt = r.on - l.on``.  Pure integer index math and one float64
    subtraction of the same operands the interpreter subtracts, so the
    result is bitwise identical; pad pairs are clipped garbage the host
    slices away."""
    k = jnp.arange(pairs, dtype=cum.dtype)
    row = jnp.searchsorted(cum, k, side="right")
    row = jnp.clip(row, 0, lt.shape[0] - 1)
    prev = jnp.where(row > 0, cum[jnp.maximum(row - 1, 0)], 0)
    slot = jnp.clip(lo[row] + (k - prev), 0, order.shape[0] - 1)
    ri = order[slot]
    l_out = lcols[:, row].astype(out_dtype)
    r_out = rcols[:, ri].astype(out_dtype)
    dt = (rt[ri] - lt[row]).astype(out_dtype)
    return l_out, r_out, dt


# -- query parsing (the compiled op family) ---------------------------------
_WINDOW_RE = re.compile(
    r"^window\(\s*([\w\.]+)\s*,\s*(\d+)\s*(?:,\s*(\d+)\s*)?\)$",
    re.IGNORECASE)
_EWINDOW_RE = re.compile(
    r"^ewindow\(\s*([\w\.]+)\s*,\s*([\d\.eE+-]+)\s*"
    r"(?:,\s*([\d\.eE+-]+)\s*)?\)$", re.IGNORECASE)
_AGG_RE = re.compile(r"^(count|sum|avg|min|max)\(\s*(\*|[\w\.]+)\s*\)$",
                     re.IGNORECASE)
_KWARG_RE = re.compile(r"^(\w+)\s*=\s*(.+)$")
_TOKEN_RE = re.compile(r"[\w\.]+")

# one compiled-plan dict per live stream object (dies with the stream);
# inside, plans key on the normalized sub-query text — the streaming
# analog of the Planner's signature key
_PLANS: "weakref.WeakValueDictionary[int, Any]" = \
    weakref.WeakValueDictionary()
_PLAN_CACHE: Dict[int, Dict[str, "CompiledStreamQuery"]] = {}
_PLAN_LOCK = threading.Lock()


def _normalize(q: str) -> str:
    return re.sub(r"\s+", "", q).lower()


def _plan_cache_for(stream) -> Dict[str, "CompiledStreamQuery"]:
    """The stream's compiled-plan dict, garbage-collected with it."""
    key = id(stream)
    with _PLAN_LOCK:
        if _PLANS.get(key) is not stream:
            # new stream (or an id reused by a successor): fresh plans
            _PLANS[key] = stream
            _PLAN_CACHE[key] = {}
            for dead in [k for k in _PLAN_CACHE if k not in _PLANS]:
                del _PLAN_CACHE[dead]
        return _PLAN_CACHE[key]


class Uncompilable(Exception):
    """The expression is outside the compiled op family — the
    interpreter serves it by design (not a fallback)."""


class CompiledStreamQuery:
    """One lowered streaming sub-plan bound to its stream object.

    ``execute()`` runs per tick: the host stage takes the stream lock
    only to export the point-in-time ring arrays (and resolve window
    bounds with the interpreter's own arithmetic, so every data-
    dependent StreamException — window not complete, evicted, watermark
    not started — raises identically), then the jitted stage runs
    outside every lock and off the GIL."""

    def __init__(self, kind: str, run: Callable[[], Any]) -> None:
        self.kind = kind
        self._run = run

    def execute(self) -> Any:
        return self._run()


# -- window lowerings -------------------------------------------------------
def _export_stacked(stream: Stream) -> Tuple[int, int, np.ndarray]:
    """(total_appended, count, (F, capacity) zero-padded oldest-first
    rows) — one point-in-time ring export; the lock is held only for
    the gather copy, exactly like the interpreter's ``_ordered`` reads."""
    with stream._lock:
        count = stream._count
        total = stream.total_appended
        out = np.zeros((len(stream.fields), stream.capacity), np.float64)
        for j, f in enumerate(stream.fields):
            out[j, :count] = stream._ordered(f)
    return total, count, out


def _compile_window(stream, size: int,
                    slide: Optional[int]) -> CompiledStreamQuery:
    if not isinstance(stream, Stream):
        raise Uncompilable("sharded window gathers stay interpreted")
    if size <= 0 or (slide is not None and slide <= 0):
        raise Uncompilable("non-positive window size/slide")
    fields = stream.fields

    if slide is None:
        def run() -> dm.ArrayObject:
            total, count, stacked = _export_stacked(stream)
            first_seq = total - count
            k = total // size - 1
            if k < 0:
                raise StreamException(
                    f"stream {stream.name!r}: no complete window of "
                    f"size {size} yet ({total} rows)")
            s = k * size
            if s < first_seq:
                raise StreamException(
                    f"stream {stream.name!r}: window [{s},{s + size}) "
                    f"already evicted (buffer starts at {first_seq})")
            out_dtype = _out_dtype()         # ambient, outside the scope
            with _x64_scope():
                out = _jit_tumbling(stacked, s - first_seq, size=size,
                                    out_dtype=out_dtype)
            # zero-copy np view, numpy slicing, one device_put per
            # field: eager jax slicing on the host path costs ~0.5ms
            # *per op* in dispatch, which would swamp the jitted gather
            arr = np.asarray(out)
            return dm.ArrayObject(
                {f: jnp.asarray(arr[j]) for j, f in enumerate(fields)},
                ("tick",))

        return CompiledStreamQuery("window", run)

    max_windows = (stream.capacity - size) // slide + 1
    if max_windows < 1:
        raise Uncompilable("window larger than ring capacity")

    def run_sliding() -> dm.ArrayObject:
        _, count, stacked = _export_stacked(stream)
        if count < size:
            raise StreamException(
                f"stream {stream.name!r}: {count} rows < window "
                f"size {size}")
        num = (count - size) // slide + 1
        out_dtype = _out_dtype()             # ambient, outside the scope
        with _x64_scope():
            out = _jit_sliding(stacked, size=size, slide=slide,
                               max_windows=max_windows,
                               out_dtype=out_dtype)
        arr = np.asarray(out)                # zero-copy; slice in numpy
        return dm.ArrayObject(
            {f: jnp.asarray(arr[j, :num]) for j, f in enumerate(fields)},
            ("window", "tick"))

    return CompiledStreamQuery("window", run_sliding)


def _compile_ewindow(stream, span: float,
                     slide: Optional[float]) -> CompiledStreamQuery:
    if not isinstance(stream, Stream):
        raise Uncompilable("sharded ewindow gathers stay interpreted")
    if stream.ts_field is None:
        raise Uncompilable("ewindow over a stream with no ts_field")
    fields = stream.fields

    def run() -> dm.ArrayObject:
        start, end = _latest_closed_ewindow(stream, span, slide)
        with stream._lock:
            if start <= stream._evicted_ts:
                raise StreamException(
                    f"stream {stream.name!r}: ewindow [{start},{end}) "
                    f"already evicted (rows up to ts "
                    f"{stream._evicted_ts} overwritten)")
            a, b = stream._seq_bounds_locked(stream.ts_field, start, end)
            count = stream._count
            stacked = np.zeros((len(fields), stream.capacity),
                               np.float64)
            for j, f in enumerate(fields):
                stacked[j, :count] = stream._ordered(f)
        m = b - a
        out_dtype = _out_dtype()             # ambient, outside the scope
        with _x64_scope():
            out = _jit_rows(stacked, a, length=_pow2(max(m, 1)),
                            out_dtype=out_dtype)
        arr = np.asarray(out)                # zero-copy; slice in numpy
        return dm.ArrayObject(
            {f: jnp.asarray(arr[j, :m]) for j, f in enumerate(fields)},
            ("tick",))

    return CompiledStreamQuery("ewindow", run)


# -- aggregate lowerings ----------------------------------------------------
def _compile_aggregate(engine, expr: str, fn: str,
                       target: str) -> CompiledStreamQuery:
    win = _WINDOW_RE.match(expr)
    if win and win.group(3) is None:
        stream = _get_stream(engine, win.group(1))
        size = int(win.group(2))
        field = stream.fields[0] if target == "*" else target
        if fn not in _COMBINABLE_AGGS or field not in stream.fields:
            raise Uncompilable("non-rolling tumbling aggregate")
        if size <= 0:
            raise Uncompilable("non-positive window size")

        if (fn in ("min", "max") and kernels.enabled()
                and isinstance(stream, Stream)):
            # the Pallas rolling scan: min/max are exactly associative,
            # so the kernel's evaluation order cannot diverge from the
            # interpreter's window-slice reduction
            def run_kernel() -> dm.ArrayObject:
                with stream._lock:
                    total = stream.total_appended
                    count = stream._count
                    k = total // size - 1
                    if k < 0:
                        raise StreamException(
                            f"stream {stream.name!r}: no complete "
                            f"window of size {size} yet ({total} rows)")
                    s, e = k * size, (k + 1) * size
                    first_seq = total - count
                    if s < first_seq:
                        raise StreamException(
                            f"stream {stream.name!r}: window [{s},{e}) "
                            f"already evicted (buffer starts at "
                            f"{first_seq})")
                    sl = stream._ordered(field)[s - first_seq:
                                                e - first_seq]
                with _x64_scope():
                    value = float(np.asarray(kernels.window_minmax(
                        jnp.asarray(sl[None, :]), fn == "max"))[0])
                return dm.ArrayObject(
                    {f"{fn}_{field}": jnp.asarray([value])}, ("i",))

            return CompiledStreamQuery("rolling", run_kernel)

        # rolling fast path: lowered to the O(1) cumulative-ring lookup
        # (already the optimal plan stage — identical memo, identical
        # value; sum/avg are order-sensitive, so no device reduction
        # could match them bit for bit)
        def run_rolling() -> dm.ArrayObject:
            value = stream.window_aggregate(size, fn, field)
            return dm.ArrayObject(
                {f"{fn}_{field}": jnp.asarray([value])}, ("i",))

        return CompiledStreamQuery("rolling", run_rolling)

    # windowed aggregate: compiled gather + the data model's own jnp
    # reduction (the interpreter's exact code path over bit-identical
    # window attrs, so the reduction order cannot diverge)
    window_plan = _compile_expr(engine, expr)

    def run() -> dm.ArrayObject:
        value = window_plan.execute()
        field = target
        if field == "*":
            field = next(iter(value.attrs))
        return value.aggregate(fn, field)

    return CompiledStreamQuery("aggregate", run)


# -- join lowering ----------------------------------------------------------
def _operand(engine, expr: str) -> Callable[[], dm.ArrayObject]:
    """A join operand evaluator: the compiled gather when the operand
    is in the family, else the interpreter's (sharded ewindows, bare
    snapshots — their host gathers are the lowering either way; the
    jitted matcher still runs on the result)."""
    try:
        plan = _compile_expr(engine, expr)
        return plan.execute
    except Uncompilable:
        pass

    def run() -> dm.ArrayObject:
        from repro.stream import shim
        return shim._as_window(shim.execute_stream(engine, expr))

    return run


def _compile_join(engine, left_expr: str, right_expr: str,
                  on: str, tol: float) -> CompiledStreamQuery:
    left_eval = _operand(engine, left_expr)
    right_eval = _operand(engine, right_expr)

    def run() -> dm.Table:
        from repro.stream import shim
        bands = shim._colocated_bands(engine, left_expr, right_expr)
        left = left_eval()
        right = right_eval()
        # the interpreter's exact operand widening + validation order
        la = {f: np.asarray(v, np.float64)
              for f, v in left.attrs.items()}
        ra = {f: np.asarray(v, np.float64)
              for f, v in right.attrs.items()}
        if on not in la or on not in ra:
            raise StreamException(
                f"join on={on!r}: both windows need that attribute "
                f"(have {sorted(la)} and {sorted(ra)})")
        t = float(tol)
        if t < 0:
            raise StreamException(f"join tol must be >= 0, got {t}")
        lt, rt = la[on], ra[on]
        if not (np.isfinite(lt).all() and np.isfinite(rt).all()):
            # +inf padding would collide with real keys; the numpy
            # interpreter handles these, so hand the query back
            raise Uncompilable("non-finite join keys")
        nl, nr = lt.shape[0], rt.shape[0]
        # the banded decomposition is bit-identical to the full join
        # (interval_join's contract), so one compiled matcher serves
        # both; only the partial-join accounting follows the bands
        bands_eff = max(1, min(int(bands), nl or 1))
        out_dtype = _out_dtype()
        if nl == 0 or nr == 0:
            l_out = np.zeros((len(la), 0), np.float64)
            r_out = np.zeros((len(ra), 0), np.float64)
            dt = np.zeros(0, np.float64)
        else:
            lb, rb = _pow2(nl), _pow2(nr)
            lt_pad = np.full(lb, np.inf)
            lt_pad[:nl] = lt
            rt_pad = np.full(rb, np.inf)
            rt_pad[:nr] = rt
            lcols = np.zeros((len(la), lb), np.float64)
            for j, f in enumerate(la):
                lcols[j, :nl] = la[f]
            rcols = np.zeros((len(ra), rb), np.float64)
            for j, f in enumerate(ra):
                rcols[j, :nr] = ra[f]
            bounds = (_jit_join_bounds_pallas if kernels.enabled()
                      else _jit_join_bounds)
            with _x64_scope():
                lo, hi, order = bounds(lt_pad, rt_pad, t)
                # zero-copy np views + numpy slicing (eager jax host
                # slices cost ~0.5ms/op in dispatch)
                lo_np = np.asarray(lo)[:nl]
                counts = np.asarray(hi)[:nl] - lo_np
                cum = np.cumsum(counts)
                pairs = int(cum[-1]) if nl else 0
                if pairs == 0:
                    l_out = np.zeros((len(la), 0), np.float64)
                    r_out = np.zeros((len(ra), 0), np.float64)
                    dt = np.zeros(0, np.float64)
                else:
                    l_dev, r_dev, dt_dev = _jit_join_gather(
                        lcols, rcols, lt_pad, rt_pad,
                        jnp.asarray(lo_np), jnp.asarray(cum), order,
                        pairs=_pow2(pairs), out_dtype=out_dtype)
                    l_out = np.asarray(l_dev)[:, :pairs]
                    r_out = np.asarray(r_dev)[:, :pairs]
                    dt = np.asarray(dt_dev)[:pairs]
        if bands_eff > 1:
            shim.JOIN_STATS["partial_joins"] += 1
            metrics.counter("repro_stream_joins_total",
                            "interval joins executed",
                            kind="partial").inc()
        shim.JOIN_STATS["joins"] += 1
        metrics.counter("repro_stream_joins_total",
                        "interval joins executed", kind="full").inc()
        cols = {}
        for j, f in enumerate(la):
            cols[f"l_{f}"] = jnp.asarray(l_out[j])
        for j, f in enumerate(ra):
            cols[f"r_{f}"] = jnp.asarray(r_out[j])
        cols["dt"] = jnp.asarray(dt)
        return dm.Table(cols)

    return CompiledStreamQuery("join", run)


# -- plan builder -----------------------------------------------------------
def _get_stream(engine, name: str):
    from repro.stream import shim
    return shim._get_stream(engine, name)


def _compile_expr(engine, query: str) -> CompiledStreamQuery:
    """Lower one streaming expression, or raise Uncompilable when the
    op is outside the compiled family."""
    from repro.stream import shim
    q = query.strip()
    m = re.match(r"^(\w+)\s*\(", q)
    if not m:
        raise Uncompilable("bare snapshot stays interpreted")
    fn = m.group(1).lower()
    body, _ = shim._balanced(q[m.end() - 1:])
    args = shim._split_args(body)
    if fn == "window":
        w = _WINDOW_RE.match(q)
        if not w:
            raise Uncompilable("unparsed window arguments")
        return _compile_window(
            _get_stream(engine, w.group(1)), int(w.group(2)),
            int(w.group(3)) if w.group(3) else None)
    if fn == "ewindow":
        e = _EWINDOW_RE.match(q)
        if not e:
            raise Uncompilable("unparsed ewindow arguments")
        try:
            span = float(e.group(2))
            slide = float(e.group(3)) if e.group(3) else None
        except ValueError:
            raise Uncompilable("unparsed ewindow bounds") from None
        return _compile_ewindow(_get_stream(engine, e.group(1)),
                                span, slide)
    if fn == "aggregate":
        if len(args) != 2:
            raise Uncompilable("malformed aggregate")
        agg = _AGG_RE.match(args[1].strip())
        if not agg:
            raise Uncompilable("malformed aggregate function")
        return _compile_aggregate(engine, args[0].strip(),
                                  agg.group(1).lower(), agg.group(2))
    if fn == "join":
        if len(args) < 2:
            raise Uncompilable("malformed join")
        on, tol = "ts", 0.0
        for extra in args[2:]:
            kw = _KWARG_RE.match(extra.strip())
            if not kw or kw.group(1).lower() not in ("on", "tol"):
                raise Uncompilable("unknown join argument")
            if kw.group(1).lower() == "on":
                on = kw.group(2).strip()
            else:
                try:
                    tol = float(kw.group(2))
                except ValueError:
                    raise Uncompilable("unparsed join tol") from None
        return _compile_join(engine, args[0].strip(), args[1].strip(),
                             on, tol)
    raise Uncompilable(f"{fn} stays interpreted")


def _plan_anchor(engine, query: str):
    """The stream object anchoring the compiled-plan cache: the first
    token of the expression that resolves to a live stream.  Plans die
    with their stream, so a re-registered stream of the same name
    compiles fresh plans against the new ring."""
    for tok in _TOKEN_RE.findall(query):
        try:
            obj = engine.get(tok)
        except Exception:                    # noqa: BLE001 — not a name
            continue
        if isinstance(obj, (Stream, ShardedStream)):
            return obj
    return None


def maybe_execute(engine, query: str) -> Tuple[bool, Any]:
    """The shim's jit dispatch hook: under ``REPRO_QUERY_BACKEND=jit``
    try the compiled path.  Returns ``(True, value)`` when the compiled
    plan served the query, ``(False, None)`` when the interpreter
    should (op outside the family, jax missing, or a compile/runtime
    fallback — the latter counted).  Data-dependent StreamExceptions
    propagate exactly as the interpreter raises them."""
    if backend() != "jit":
        return False, None
    if not JAX_AVAILABLE:
        _fallback("jax_unavailable")
        return False, None
    key = _normalize(query)
    try:
        with trace.span("compile/plan") as sp:
            anchor = _plan_anchor(engine, query)
            if anchor is None:
                _bump("interpreted")
                return False, None
            cache = _plan_cache_for(anchor)
            plan = cache.get(key)
            if plan is None:
                plan = _compile_expr(engine, query)
                cache[key] = plan
                _bump("compiles")
                sp.set(cache_hit=False, op=plan.kind)
            else:
                _bump("cache_hits")
                sp.set(cache_hit=True, op=plan.kind)
    except Uncompilable:
        _bump("interpreted")
        return False, None
    except StreamException:
        raise
    except Exception as exc:                 # noqa: BLE001 — fall back
        _fallback(type(exc).__name__)
        return False, None
    try:
        with trace.span("compile/execute", op=plan.kind):
            value = plan.execute()
    except Uncompilable as exc:
        # the plan compiled but this tick's *data* defeated it (e.g.
        # non-finite join keys): a real fallback, not a by-design skip
        _fallback(str(exc) or "uncompilable")
        return False, None
    except StreamException:
        raise
    except Exception as exc:                 # noqa: BLE001 — fall back
        _fallback(type(exc).__name__)
        return False, None
    _bump("executions")
    return True, value
