"""Continuous (standing) queries over the polystore (arXiv:1602.08791
§streaming: S-Store's standing queries; paper §III's streaming island).

``StreamRuntime.register_continuous(bql, every_n_ticks)`` registers a BQL
query that re-executes as new data lands.  The query is parsed/validated
once at registration and then always submitted in *lean* mode, so its
first tick populates the Planner's signature-keyed plan cache and every
later tick skips plan enumeration entirely (the PR-1 fast path); stage
execution rides the concurrent DAG Executor.

Per-tick metrics — execution latency, plan-cache hit, rows dropped by
ring-buffer backpressure since the previous execution, and whether the
query fell behind the arrival cadence — are kept per query and fed to the
Monitor (``observe_stream``), surfacing in ``admin.status()``.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import re
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import bql, signatures
from repro.obs import metrics, trace

_CQ_IDS = itertools.count()

# a standing query is event-time-gated when its streaming sub-queries use
# watermark-driven ops: re-running it before the watermark moves cannot
# change its answer
_EVENT_TIME_OPS_RE = re.compile(r"\b(ewindow|join)\s*\(", re.IGNORECASE)


@dataclasses.dataclass
class ContinuousQuery:
    """One standing query: BQL text + cadence + rolling metrics."""
    name: str
    bql: str
    every_n_ticks: int = 1
    executions: int = 0
    cache_hits: int = 0
    errors: int = 0              # failed executions (tick carries on)
    last_error: Optional[str] = None
    drops_seen: int = 0          # ring-buffer rows lost between executions
    backpressure: int = 0        # executions slower than their own cadence
    # event-time standing queries (ewindow/join over ts streams) run only
    # when a referenced stream's low watermark advanced — a tick that
    # couldn't change their answer is skipped, not executed
    event_time: bool = False
    wm_skips: int = 0            # due ticks skipped: watermark unchanged
    late_seen: int = 0           # late rows on referenced streams
    _dropped_at_last_exec: int = 0
    _late_at_last_exec: int = 0
    # per-referenced-stream watermarks at the last execution (ref-name ->
    # watermark): the gate re-runs when ANY advances — a join must re-run
    # when one side's window closes even while the other side stalls
    _wm_at_last_exec: Optional[Dict[str, float]] = None
    _last_exec_start: float = 0.0
    _root: Any = None            # parsed plan tree (set at registration)
    # memoized stream-name resolution for _dropped_for: the referenced
    # names only change when the deployment's stream set does
    _stream_set: Optional[frozenset] = None
    _stream_refs: Tuple[str, ...] = ()
    last_value: Any = None
    last_latency_seconds: float = 0.0
    latencies: "collections.deque[float]" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=256))

    def metrics(self) -> Dict[str, Any]:
        lat = sorted(self.latencies)
        p50 = lat[len(lat) // 2] if lat else 0.0
        return {"bql": self.bql, "every_n_ticks": self.every_n_ticks,
                "executions": self.executions,
                "cache_hits": self.cache_hits,
                "errors": self.errors,
                "last_error": self.last_error,
                "drops_seen": self.drops_seen,
                "backpressure": self.backpressure,
                "event_time": self.event_time,
                "wm_skips": self.wm_skips,
                "late_seen": self.late_seen,
                "last_latency_ms": round(
                    self.last_latency_seconds * 1e3, 3),
                "p50_latency_ms": round(p50 * 1e3, 3)}


class StreamRuntime:
    """Drives the registered continuous queries.

    ``tick()`` is the unit of progress: a data feed appends a batch to its
    stream(s), then calls ``tick()``; every standing query whose cadence
    divides the tick counter re-executes.  Ticks are cooperative (caller's
    thread) so results are deterministic and tests stay in control; a
    background driver can simply call ``tick()`` from its own loop.
    """

    def __init__(self, planner, monitor, engines: Dict[str, Any]) -> None:
        self.planner = planner
        self.monitor = monitor
        self.engines = engines
        self.queries: Dict[str, ContinuousQuery] = {}
        self.ticks = 0
        self._last_tick_time: Optional[float] = None
        self._tick_gap_seconds = 0.0
        self._lock = threading.RLock()
        # serializes whole-tick execution: the background driver and a
        # cooperative caller may tick concurrently, and per-query
        # between-execution state (drop baselines, latency budgets) must
        # not be read/written by two ticks at once.  Separate from
        # self._lock so registration/status stay non-blocking while a
        # long standing query executes.
        self._tick_lock = threading.Lock()
        # opt-in background tick driver (wall-clock-paced feeds); ticks
        # stay cooperative unless start() is called
        self._driver_thread: Optional[threading.Thread] = None
        self._driver_stop: Optional[threading.Event] = None
        self._driver_interval = 0.0
        self.driver_ticks = 0
        self.driver_errors = 0
        self.last_driver_error: Optional[str] = None
        # live shard rebalances performed through rebalance()
        self.rebalances: List[Dict[str, Any]] = []
        # durable streams (register_stream(durability=...) /
        # recover_stream): tick drives their checkpoint cadence and
        # feeds their log/checkpoint stats to the Monitor
        self._durable_streams: List[Any] = []
        # tick listeners: fn(tick_no, ran) called after every tick with
        # the results that ran, regardless of who drove it (cooperative
        # caller or the background driver) — the serving front door
        # fans results out to tenant subscriptions through this
        self._tick_listeners: List[Any] = []
        self.listener_errors = 0
        self.last_listener_error: Optional[str] = None

    def register_durable(self, stream) -> None:
        if stream not in self._durable_streams:
            self._durable_streams.append(stream)

    def add_tick_listener(self, fn) -> None:
        """Call ``fn(tick_no, ran)`` after every tick (``ran`` is the
        [(query name, Response)] list that tick produced).  Listener
        errors are recorded, never propagated into the tick."""
        with self._lock:
            if fn not in self._tick_listeners:
                self._tick_listeners.append(fn)

    def remove_tick_listener(self, fn) -> None:
        with self._lock:
            if fn in self._tick_listeners:
                self._tick_listeners.remove(fn)

    # -- registration ---------------------------------------------------------
    def register_continuous(self, query: str, every_n_ticks: int = 1,
                            name: Optional[str] = None) -> ContinuousQuery:
        """Register a standing BQL query; parse errors surface here, not
        on the first tick.  Returns the ContinuousQuery handle."""
        assert every_n_ticks >= 1
        root = bql.parse(query)            # validate once, at registration
        with self._lock:
            cq_name = name or f"cq{next(_CQ_IDS)}"
            if cq_name in self.queries:
                raise ValueError(f"continuous query {cq_name!r} exists")
            cq = ContinuousQuery(name=cq_name, bql=query,
                                 every_n_ticks=every_n_ticks)
            cq._root = root
            cq.event_time = any(
                isinstance(node, bql.IslandQueryNode)
                and node.island in ("streaming", "ml")
                and _EVENT_TIME_OPS_RE.search(node.query)
                for node in root.walk())
            # only count drops/lates that happen within this query's
            # lifetime
            cq._dropped_at_last_exec = self._dropped_for(cq)
            cq._late_at_last_exec = self._late_for(cq)
            self.queries[cq_name] = cq
            return cq

    def deregister(self, name: str) -> None:
        with self._lock:
            self.queries.pop(name, None)

    # -- the tick loop --------------------------------------------------------
    def _streams_map(self) -> Dict[str, Any]:
        """Every stream any StreamEngine serves (plain rings, shard
        rings, and sharded handles — handles deduped by name)."""
        from repro.stream.engine import StreamEngine
        streams: Dict[str, Any] = {}
        for engine in self.engines.values():
            if isinstance(engine, StreamEngine):
                streams.update(engine.streams())
        return streams

    def _refs_for(self, cq: ContinuousQuery,
                  streams: Dict[str, Any]) -> Tuple[str, ...]:
        """The stream names this query's BQL actually reads.  The
        parse-tree walk + name regex only reruns when the deployment's
        stream set changes."""
        names = frozenset(streams)
        if cq._stream_set != names:
            refs = set()
            for node in cq._root.walk():
                # ml nodes (infer over window/ewindow) read streams too:
                # their drops/lates/watermarks gate the query like a
                # streaming node's would
                if (isinstance(node, bql.IslandQueryNode)
                        and node.island in ("streaming", "ml")):
                    refs.update(signatures._referenced_objects(
                        node, engines_have=lambda tok: tok in streams))
            cq._stream_refs = tuple(sorted(refs & names))
            cq._stream_set = names
        return cq._stream_refs

    def _dropped_for(self, cq: ContinuousQuery,
                     streams: Optional[Dict[str, Any]] = None) -> int:
        """Cumulative ring-buffer drops on the streams this query reads
        (a query over a stable stream must not be charged with another
        stream's overflow)."""
        if streams is None:
            streams = self._streams_map()
        return sum(streams[r].total_dropped
                   for r in self._refs_for(cq, streams))

    def _late_for(self, cq: ContinuousQuery,
                  streams: Optional[Dict[str, Any]] = None) -> int:
        """Cumulative late rows (arrived below the watermark, dropped)
        on the streams this query reads — data an event-time standing
        query can never see."""
        if streams is None:
            streams = self._streams_map()
        return sum(getattr(streams[r], "total_late", 0)
                   for r in self._refs_for(cq, streams))

    def _watermarks_for(self, cq: ContinuousQuery,
                        streams: Optional[Dict[str, Any]] = None
                        ) -> Optional[Dict[str, float]]:
        """Low watermark of every event-time stream this query reads
        (name -> watermark), or None when it reads none (the query is
        then not watermark-gated)."""
        if streams is None:
            streams = self._streams_map()
        marks = {r: streams[r].watermark
                 for r in self._refs_for(cq, streams)
                 if getattr(streams[r], "ts_field", None) is not None}
        return marks or None

    def tick(self) -> List[Tuple[str, Any]]:
        """Advance one tick; run every due standing query in lean mode.
        A failing query is recorded on its own metrics (``errors`` /
        ``last_error``) and never aborts the tick or the other queries.
        Concurrent ticks (background driver + cooperative caller)
        serialize — logical time advances one tick at a time.
        Returns [(query name, Response)] for the queries that ran."""
        with self._tick_lock:
            return self._tick_locked()

    def _tick_locked(self) -> List[Tuple[str, Any]]:
        with self._lock:
            now = time.monotonic()
            if self._last_tick_time is not None:
                self._tick_gap_seconds = now - self._last_tick_time
            self._last_tick_time = now
            self.ticks += 1
            tick_no = self.ticks
        # the tick is the trace unit: every span below (planner,
        # executor, compile, committer...) links into one tick-id trace
        t_tick = time.perf_counter()
        with trace.span("stream/tick", trace_id=f"tick-{tick_no}",
                        tick=tick_no) as sp:
            ran = self._run_tick()
            sp.set(ran=len(ran))
            with self._lock:
                listeners = list(self._tick_listeners)
            for fn in listeners:
                try:
                    fn(tick_no, ran)
                except Exception as exc:                 # noqa: BLE001
                    with self._lock:
                        self.listener_errors += 1
                        self.last_listener_error = \
                            f"{type(exc).__name__}: {exc}"
        metrics.histogram(
            "repro_stream_tick_seconds",
            "wall time per StreamRuntime tick").observe(
                time.perf_counter() - t_tick)
        return ran

    def _run_tick(self) -> List[Tuple[str, Any]]:
        with self._lock:
            due = [cq for cq in self.queries.values()
                   if self.ticks % cq.every_n_ticks == 0]
        ran: List[Tuple[str, Any]] = []
        # one engine->streams snapshot serves every query this tick (the
        # per-query drop/late/watermark lookups below all read it)
        streams_map = self._streams_map()
        # idle-timeout punctuation runs BEFORE the standing queries, so
        # a watermark advance it produces unsticks watermark-gated
        # queries on this very tick (not the next one)
        for name, stream in streams_map.items():
            if "@shard" in name:
                continue
            if (getattr(stream, "idle_timeout", None) is not None
                    and getattr(stream, "ts_field", None) is not None):
                stream.advance_idle_watermark()
        for cq in due:
            if cq.event_time:
                # watermark gating: an ewindow/join answer can only
                # change when a referenced stream's low watermark moves —
                # a due tick where NO referenced watermark advanced is
                # skipped (and counted), not executed into the same
                # closed windows.  Any single side advancing re-runs a
                # join: its window may have closed while the other side
                # stalls
                marks = self._watermarks_for(cq, streams_map)
                last = cq._wm_at_last_exec
                if (marks is not None and last is not None
                        and all(r in last and wm <= last[r]
                                for r, wm in marks.items())):
                    with self._lock:
                        cq.wm_skips += 1
                        skips = cq.wm_skips
                    metrics.counter(
                        "repro_stream_wm_skips_total",
                        "due ticks skipped: no referenced watermark "
                        "advanced", query=cq.name).set_total(skips)
                    continue
                cq._wm_at_last_exec = marks
            # a query's latency budget is its own cadence: the gap since
            # its previous execution (~ every_n_ticks x the tick gap)
            exec_start = time.monotonic()
            budget = (exec_start - cq._last_exec_start
                      if cq._last_exec_start else 0.0)
            cq._last_exec_start = exec_start
            t0 = time.perf_counter()
            try:
                with trace.span("stream/query", query=cq.name) as qsp:
                    response = self.planner.process_query(
                        cq.bql, is_training_mode=False)
                    qsp.set(cache_hit=response.plan_cache_hit)
            except Exception as exc:                     # noqa: BLE001
                # isolate failures (e.g. a tumbling window not complete
                # yet): the feed and the other standing queries carry on
                with self._lock:
                    cq.errors += 1
                    cq.last_error = f"{type(exc).__name__}: {exc}"
                continue
            latency = time.perf_counter() - t0
            # rows this query's ring buffers dropped since it last looked
            # (data the standing query never got to see), and late rows
            # that arrived below the watermark (same: invisible to it)
            dropped_total = self._dropped_for(cq, streams_map)
            drops = dropped_total - cq._dropped_at_last_exec
            cq._dropped_at_last_exec = dropped_total
            late_total = self._late_for(cq, streams_map)
            lates = late_total - cq._late_at_last_exec
            cq._late_at_last_exec = late_total
            with self._lock:
                cq.executions += 1
                cq.last_value = response.value
                cq.last_latency_seconds = latency
                cq.latencies.append(latency)
                if response.plan_cache_hit:
                    cq.cache_hits += 1
                cq.drops_seen += drops
                cq.late_seen += lates
                lagging = budget > 0 and latency > budget
                if lagging:
                    cq.backpressure += 1
            self.monitor.observe_stream(cq.name, latency, dropped=drops,
                                        lagging=lagging, late=lates)
            ran.append((cq.name, response))
        # per-shard ingest/drop snapshots land in the Monitor every tick —
        # the admin rebalance hook reads them to spot lopsided placements
        for name, handle in self._sharded_streams().items():
            self.monitor.observe_shards(name, handle.shard_stats())
        # per-stream low watermarks land there too (event-time health:
        # admin.status()["streams"] and the Monitor agree by construction)
        for name, stream in streams_map.items():
            if "@shard" in name:
                continue
            # multi-producer ingest counters for every logical stream
            ic = getattr(stream, "ingest_concurrency", None)
            if ic is not None:
                self.monitor.observe_ingest(name, ic())
            if getattr(stream, "ts_field", None) is None:
                continue
            self.monitor.observe_watermark(
                name, stream.watermark, late=stream.total_late,
                pending=stream._pending_rows)
            # event-time eviction horizon: rows at or below this ts have
            # been overwritten — windows over them raise (a gauge, so
            # alerting can catch consumers falling behind the ring)
            ev = (stream._evicted_ts if hasattr(stream, "_evicted_ts")
                  else max(s._evicted_ts for s in stream._shards))
            if ev != float("-inf"):
                metrics.gauge(
                    "repro_stream_eviction_ts",
                    "event-time eviction horizon (windows at or below "
                    "this ts are gone)", stream=name).set(ev)
        # durability cadence: checkpoint any durable stream that has
        # logged checkpoint_every_rows rows since its last checkpoint
        # (async save — the tick thread never blocks on .npy I/O), and
        # mirror log/checkpoint stats into the Monitor
        for stream in self._durable_streams:
            durable = stream._durable
            if durable is None:
                continue
            durable.maybe_checkpoint()
            self.monitor.observe_durability(stream.name,
                                            durable.stats())
        # compiled-query-path counters (backend, compiles, cache hits,
        # fallbacks) — one global block, refreshed every tick so the
        # Monitor/admin view tracks the jit lane's health live
        from repro.stream import compile as query_compile
        self.monitor.observe_jit(query_compile.stats())
        # ml-island inference counters (waves, windows scored, params
        # cache, fallbacks) — same cadence and shape as the jit block.
        # sys.modules, not an import: the ml module pulls in the model
        # registry, a cost deployments without an ml engine never pay
        import sys
        query_ml = sys.modules.get("repro.stream.ml")
        if query_ml is not None:
            self.monitor.observe_ml(query_ml.stats())
        return ran

    def run_ticks(self, n: int) -> List[List[Tuple[str, Any]]]:
        return [self.tick() for _ in range(n)]

    def _sharded_streams(self) -> Dict[str, Any]:
        """Logical name -> ShardedStream handle (deduped: the handle is
        registered on every participating StreamEngine)."""
        from repro.stream.engine import ShardedStream, StreamEngine
        out: Dict[str, Any] = {}
        for engine in self.engines.values():
            if isinstance(engine, StreamEngine):
                for sname, obj in engine.streams().items():
                    if isinstance(obj, ShardedStream):
                        out[sname] = obj
        return out

    # -- background tick driver (opt-in) --------------------------------------
    def start(self, interval_seconds: float = 0.05) -> None:
        """Start a daemon thread calling ``tick()`` every
        ``interval_seconds`` — wall-clock-paced standing queries, so the
        backpressure counter measures real sustained load.  Cooperative
        ticking (callers invoking ``tick()`` themselves) keeps working
        alongside it; ``stop()`` joins the thread (leak-free)."""
        assert interval_seconds > 0
        with self._lock:
            if self._driver_thread is not None \
                    and self._driver_thread.is_alive():
                raise RuntimeError("background tick driver already running")
            stop = threading.Event()

            def loop() -> None:
                # the driver must outlive any single bad tick: per-query
                # failures are already isolated inside tick(), and an
                # unexpected error outside that isolation is recorded
                # here instead of silently killing the daemon thread
                while not stop.wait(interval_seconds):
                    try:
                        self.tick()
                    except Exception as exc:             # noqa: BLE001
                        with self._lock:
                            self.driver_errors += 1
                            self.last_driver_error = \
                                f"{type(exc).__name__}: {exc}"
                    with self._lock:
                        self.driver_ticks += 1

            self._driver_stop = stop
            self._driver_interval = interval_seconds
            self._driver_thread = threading.Thread(
                target=loop, name="stream-tick-driver", daemon=True)
            self._driver_thread.start()

    def stop(self) -> bool:
        """Stop the background driver.  Returns False when no driver is
        running, or when a long tick keeps the thread alive past the
        join timeout — in that case the driver stays registered (so
        ``start()`` cannot spawn a second concurrent loop) and a later
        ``stop()`` reaps it once the tick drains."""
        with self._lock:
            thread, stop = self._driver_thread, self._driver_stop
        if thread is None:
            return False
        if stop is not None:
            stop.set()
        if thread.is_alive():
            thread.join(timeout=5.0)
            if thread.is_alive():
                return False              # still draining a long tick
        with self._lock:
            if self._driver_thread is thread:
                self._driver_thread = None
                self._driver_stop = None
        return True

    @property
    def driver_running(self) -> bool:
        thread = self._driver_thread
        return thread is not None and thread.is_alive()

    # -- live shard rebalancing ------------------------------------------------
    def rebalance(self, stream: str, shard: Optional[int] = None,
                  to_engine: Optional[str] = None) -> Dict[str, Any]:
        """Move one shard of ``stream`` to another StreamEngine through
        the Migrator's ``stream`` route (live state: ring data + seq
        watermark + drop counters travel; standing queries keep running).

        With ``shard``/``to_engine`` unset, picks the move that best evens
        per-engine ingest load: the busiest engine donates whichever of
        its shards minimizes the post-move spread.  Raises ValueError if
        no move improves the placement.
        """
        handle = self._sharded_streams().get(stream)
        if handle is None:
            raise ValueError(f"{stream!r} is not a sharded stream")
        from repro.stream.engine import StreamEngine
        stream_engines = [n for n, e in self.engines.items()
                          if isinstance(e, StreamEngine)]
        if shard is not None and not 0 <= shard < handle.num_shards:
            raise ValueError(
                f"{stream!r} has no shard {shard} "
                f"(0..{handle.num_shards - 1})")
        if to_engine is not None and to_engine not in stream_engines:
            raise ValueError(
                f"{to_engine!r} is not a StreamEngine "
                f"(have: {sorted(stream_engines)})")
        stats = handle.shard_stats()
        # current per-shard loads (per-tick EWMA — lifetime counters only
        # before the first tick), so a donor engine is weighed by what
        # its shards ingest *now*, not by their history
        shard_loads = self.monitor.shard_loads(stream)

        def _weight(i: int, st: Dict[str, Any]) -> float:
            return shard_loads.get(i, self.monitor.shard_load(st))

        loads: Dict[str, float] = {n: 0.0 for n in stream_engines}
        for i, st in stats.items():
            loads[st["engine"]] += _weight(i, st)
        if shard is None or to_engine is None:
            # consider every (donor shard, destination) pair — a move off
            # a non-busiest engine can still shrink the spread (e.g. the
            # busiest engine's single hot shard is unmovable but another
            # engine can hand a shard to an idle one)
            spread = max(loads.values()) - min(loads.values())
            best: Optional[Tuple[float, int, str]] = None
            for i, st in stats.items():
                if shard is not None and i != shard:
                    continue
                w = _weight(i, st)
                for dest in stream_engines:
                    if to_engine is not None and dest != to_engine:
                        continue
                    if dest == st["engine"]:
                        continue
                    after = dict(loads)
                    after[st["engine"]] -= w
                    after[dest] += w
                    new_spread = max(after.values()) - min(after.values())
                    if new_spread < spread and (
                            best is None or new_spread < best[0]):
                        best = (new_spread, i, dest)
            if best is None:
                raise ValueError(
                    f"no rebalancing move improves {stream!r} "
                    f"(per-engine loads: {loads})")
            _, shard, to_engine = best
        result = handle.migrate_shard(
            shard, self.planner.migrator, self.engines, to_engine)
        move = {"stream": stream, "shard": shard,
                "from": result.engine_from, "to": result.engine_to,
                "rows": result.rows, "bytes": result.bytes_moved,
                "seconds": round(result.seconds, 6)}
        with self._lock:
            self.rebalances.append(move)
        self.monitor.observe_shards(stream, handle.shard_stats())
        return move

    # -- introspection --------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        from repro.stream.engine import ShardedStream, StreamEngine
        with self._lock:
            out: Dict[str, Any] = {
                "ticks": self.ticks,
                "background": {
                    "running": self.driver_running,
                    "interval_seconds": self._driver_interval
                    if self.driver_running else None,
                    "driver_ticks": self.driver_ticks,
                    "driver_errors": self.driver_errors,
                    "last_driver_error": self.last_driver_error},
                "rebalances": list(self.rebalances),
                "queries": {n: cq.metrics()
                            for n, cq in self.queries.items()},
                "streams": {}}
        for ename, engine in self.engines.items():
            if not isinstance(engine, StreamEngine):
                continue
            for sname, stream in engine.streams().items():
                if "@shard" in sname:
                    continue          # shard rings report under the handle
                if sname in out["streams"]:
                    continue          # a handle lives on several engines;
                    #                   gather its stats only once
                info = stream.stats()
                if isinstance(stream, ShardedStream):
                    info["engine"] = stream.shard_engines()
                    info["shard_key"] = stream.shard_key
                    info["agg_cache_hits"] = stream.agg_cache_hits
                    info["agg_computes"] = stream.agg_computes
                else:
                    info["engine"] = ename
                info["rows_per_second"] = round(stream.rate(), 1)
                out["streams"][sname] = info
        return out
