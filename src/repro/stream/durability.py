"""Crash-safe streams: per-shard append-only segment log + checkpoint /
recover / replay (ROADMAP direction 5).

Durability is **opt-in per stream** (``register_stream(...,
durability=dir)`` or :func:`attach`).  Three pieces:

- **Segment log** (``<dir>/wal/<lane>/seg_*.log``): an append-only
  binary record log written *write-behind* from the PR-5 ordered
  committers — a batch is logged inside its lane's ordered commit
  section, after the ring write published, so the ingest hot path
  gains no locks and readers never wait on log I/O.  Lanes:

  * seq-ordered ``Stream``: one lane of ``APPEND`` records;
  * seq-ordered ``ShardedStream``: one lane **per shard** of ``SHARD``
    records, each carrying its block's bounds so recovery can cut a
    block whose shards were not all logged before a crash;
  * event-time streams (both kinds): ingest is lock-serialized, so one
    lane of ``ARRIVE`` records (the raw arrival batches, logged before
    late classification so replay reproduces ``total_late`` and the
    dead-letter sink) plus ``FLUSH`` records for explicit/idle
    punctuation (external input a replay cannot re-derive).

  Records are CRC-checked and length-framed; a torn tail (real kill or
  an armed ``runtime.fault`` crash point) is detected and truncated on
  recovery.

- **Checkpoints** through the seed's ``checkpoint/manager.py`` (atomic
  manifest promote, keep-last-k): the stream's full ``export_state``
  plus the per-lane log positions, captured at one coherent instant
  (reservations frozen, lanes drained — see
  ``Stream._checkpoint_snapshot``).  After a checkpoint, log segments
  no retained checkpoint needs are pruned.

- **recover()**: restore the latest checkpoint (or a fresh stream),
  replay the log tail through the *same* ingest code paths, and hand
  back a stream whose ``total_appended``, seq assignment, watermarks,
  eviction counters, pending buffers, and rolling aggregates are
  bit-identical to the pre-crash stream's durable prefix — the house
  invariant gains ``recovered ≡ original``.  Replay doubles as a
  deterministic load generator (``replay(S)`` in BQL; the
  ``stream/replay_rate`` bench row measures replayed rows/sec against
  live ingest).

Determinism caveat (documented in docs/OPERATIONS.md): with
``idle_timeout`` set, idle-watermark punctuation is wall-clock input —
it is durable *as logged* (tick-driven advances write ``FLUSH``
records), but an idle exclusion coinciding with an arrival is not
re-derived by replay.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import threading
import time
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.obs import metrics, trace
from repro.runtime.fault import crash_point
from repro.stream.engine import (SEQ_FIELD, ShardedStream, Stream,
                                 StreamException)

# -- record framing ----------------------------------------------------------
# little-endian: lsn u64 | kind u8 | block i64 | block_total i64 |
#                nrows u32 | payload_len u32 | crc32(payload) u32
_HDR = struct.Struct("<QBqqIII")

KIND_APPEND = 1      # plain seq-ordered batch      payload: fields
KIND_SHARD = 2       # one shard's slice of a block payload: fields+__seq
KIND_ARRIVE = 3      # raw event-time arrival batch payload: fields
KIND_FLUSH = 4       # punctuation                  payload: target ts

_META_KEY = "meta"   # checkpoint leaf holding the JSON-encoded structure


@dataclasses.dataclass
class Record:
    lsn: int
    kind: int
    block: int
    total: int
    nrows: int
    cols: Optional[Dict[str, np.ndarray]]   # None for FLUSH
    target: float                           # FLUSH only
    size: int                               # bytes on disk


class SegmentLog:
    """One lane's append-only record log, split into size-rolled
    segment files ``seg_<first_lsn>.log``.  Writers are externally
    serialized (the lane's ordered committer / the stream lock), so
    ``append`` takes no lock of its own."""

    def __init__(self, directory: str, fields: Tuple[str, ...],
                 segment_bytes: int = 1 << 20,
                 fsync: bool = False) -> None:
        self.directory = directory
        self.fields = tuple(fields)
        self.segment_bytes = int(segment_bytes)
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)
        self._file = None
        self._file_size = 0
        self.next_lsn = 0
        self.records = 0          # records written by THIS handle
        self.rows = 0             # data rows written by this handle
        self.bytes = 0
        self._open_at_end()

    # -- file plumbing ---------------------------------------------------------
    def _segments(self) -> List[Tuple[int, str]]:
        out = []
        if not os.path.isdir(self.directory):
            # the WAL was pruned/removed out from under a closed handle;
            # readers (Monitor stats) must see "empty", not crash a tick
            return out
        for name in os.listdir(self.directory):
            if name.startswith("seg_") and name.endswith(".log"):
                out.append((int(name[4:-4]),
                            os.path.join(self.directory, name)))
        return sorted(out)

    def _open_at_end(self) -> None:
        """Position for appending: scan existing segments (repairing a
        torn tail) to find the next lsn, then open the last segment."""
        segs = self._segments()
        if not segs:
            return
        for first, path in segs:
            recs, clean_end, torn = _scan_segment(path, first,
                                                  self.fields)
            if torn:
                os.truncate(path, clean_end)
            self.next_lsn = first + len(recs)
            if torn:
                break
        last_path = [p for f, p in segs if f <= self.next_lsn][-1]
        self._file = open(last_path, "ab")
        self._file_size = os.path.getsize(last_path)

    def _writer(self, incoming: int):
        if self._file is None or (self._file_size > 0
                                  and self._file_size + incoming
                                  > self.segment_bytes):
            if self._file is not None:
                self._file.close()
            path = os.path.join(self.directory,
                                f"seg_{self.next_lsn:012d}.log")
            self._file = open(path, "ab")
            self._file_size = os.path.getsize(path)
        return self._file

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    # -- write -----------------------------------------------------------------
    def append(self, kind: int, block: int, total: int,
               cols: Optional[Dict[str, np.ndarray]], nrows: int,
               target: float = 0.0) -> int:
        """Serialize one record.  Crash points bracket the two writes so
        an armed kill produces exactly the on-disk states a real kill
        could: nothing, a torn (header-only) record, or a whole record
        with the in-memory successor state lost."""
        if kind == KIND_FLUSH:
            payload = np.float64(target).tobytes()
        else:
            payload = b"".join(
                np.ascontiguousarray(cols[f], np.float64).tobytes()
                for f in self.fields)
        lsn = self.next_lsn
        hdr = _HDR.pack(lsn, kind, block, total, nrows, len(payload),
                        zlib.crc32(payload))
        crash_point("stream/log:before")
        f = self._writer(len(hdr) + len(payload))
        f.write(hdr)
        crash_point("stream/log:torn", flush=f.flush)
        f.write(payload)
        f.flush()
        if self.fsync:
            os.fsync(f.fileno())
        crash_point("stream/log:after", flush=None)
        self.next_lsn = lsn + 1
        self._file_size += len(hdr) + len(payload)
        self.records += 1
        self.rows += nrows
        self.bytes += len(hdr) + len(payload)
        return lsn

    # -- read ------------------------------------------------------------------
    def scan(self, start_lsn: int = 0,
             repair: bool = False) -> List[Record]:
        """Records with ``lsn >= start_lsn`` in order, stopping at (and
        with ``repair=True`` physically truncating) the first torn or
        corrupt record.  ``repair=False`` is the live-replay mode: a
        concurrent writer's half-flushed tail is skipped, not cut."""
        out: List[Record] = []
        for first, path in self._segments():
            recs, clean_end, torn = _scan_segment(path, first,
                                                  self.fields)
            out.extend(r for r in recs if r.lsn >= start_lsn)
            if torn:
                if repair:
                    os.truncate(path, clean_end)
                break
        return out

    def truncate_from(self, lsn: int) -> int:
        """Physically discard record ``lsn`` and everything after it
        (recovery's cut for blocks that did not fully log before a
        crash).  Returns the number of records discarded."""
        self.close()
        discarded = 0
        for first, path in self._segments():
            if first >= lsn:
                recs, _, _ = _scan_segment(path, first, self.fields)
                discarded += len(recs)
                os.remove(path)
                continue
            recs, _, _ = _scan_segment(path, first, self.fields)
            keep = [r for r in recs if r.lsn < lsn]
            if len(keep) < len(recs):
                discarded += len(recs) - len(keep)
                os.truncate(path, sum(r.size for r in keep))
        self.next_lsn = min(self.next_lsn, lsn)
        self._open_at_end()
        return discarded

    def prune_below(self, lsn: int) -> int:
        """Delete whole segments every record of which is below ``lsn``
        (already covered by every retained checkpoint).  Returns the
        number of segments removed."""
        segs = self._segments()
        removed = 0
        for i, (first, path) in enumerate(segs):
            nxt = segs[i + 1][0] if i + 1 < len(segs) else None
            if nxt is not None and nxt <= lsn:
                os.remove(path)
                removed += 1
        return removed


def _scan_segment(path: str, first_lsn: int, fields: Tuple[str, ...]
                  ) -> Tuple[List[Record], int, bool]:
    """(records, clean end offset, torn?) for one segment file.  Any
    short header, short payload, CRC mismatch, or lsn discontinuity
    marks the tail torn from that offset on."""
    with open(path, "rb") as f:
        data = f.read()
    out: List[Record] = []
    off, expected = 0, first_lsn
    while off + _HDR.size <= len(data):
        lsn, kind, block, total, nrows, paylen, crc = \
            _HDR.unpack_from(data, off)
        end = off + _HDR.size + paylen
        if lsn != expected or end > len(data):
            return out, off, True
        payload = data[off + _HDR.size:end]
        if zlib.crc32(payload) != crc:
            return out, off, True
        if kind == KIND_FLUSH:
            cols, target = None, float(np.frombuffer(payload,
                                                     np.float64)[0])
        else:
            flat = np.frombuffer(payload, np.float64)
            if flat.shape[0] != nrows * len(fields):
                return out, off, True
            cols = {f: flat[i * nrows:(i + 1) * nrows].copy()
                    for i, f in enumerate(fields)}
            target = 0.0
        out.append(Record(lsn, kind, block, total, nrows, cols, target,
                          end - off))
        off, expected = end, expected + 1
    return out, off, off < len(data)


# -- checkpoint state <-> flat-array encoding --------------------------------
#
# export_state dicts mix ndarrays with scalars/lists/tuples.  Arrays
# become individual checkpoint leaves (CheckpointManager saves each as
# .npy); everything else lands in one JSON spec with $-tagged wrappers,
# stored as a 0-d unicode array leaf — self-describing, so recovery
# needs no template pytree.

def _encode(obj, path: str, arrays: Dict[str, np.ndarray]):
    if isinstance(obj, np.ndarray):
        arrays[path] = obj
        return {"$a": path}
    if isinstance(obj, dict):
        return {"$d": {k: _encode(v, f"{path}/{k}", arrays)
                       for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        enc = [_encode(v, f"{path}/{i}", arrays)
               for i, v in enumerate(obj)]
        return {"$t" if isinstance(obj, tuple) else "$l": enc}
    if isinstance(obj, (np.integer, np.floating)):
        obj = obj.item()
    return {"$v": obj}


def _decode(spec, arrays: Dict[str, np.ndarray]):
    if "$a" in spec:
        return arrays[spec["$a"]]
    if "$d" in spec:
        return {k: _decode(v, arrays) for k, v in spec["$d"].items()}
    if "$l" in spec:
        return [_decode(v, arrays) for v in spec["$l"]]
    if "$t" in spec:
        return tuple(_decode(v, arrays) for v in spec["$t"])
    return spec["$v"]


# -- the per-stream durability handle ----------------------------------------

class StreamDurability:
    """Owns one durable stream's lanes, checkpoint manager, and cadence
    bookkeeping.  Installed as ``stream._durable`` by :func:`attach`;
    the engine hot paths call ``log_append``/``log_shard``/
    ``log_arrive``/``log_flush`` (each from within the serialization
    domain that makes its lane single-writer)."""

    def __init__(self, stream, directory: str, *,
                 checkpoint_every_rows: Optional[int] = None,
                 keep: int = 3, segment_bytes: int = 1 << 20,
                 fsync: Optional[bool] = None) -> None:
        self.stream = stream
        self.directory = directory
        self.checkpoint_every_rows = checkpoint_every_rows
        self.keep = int(keep)
        if fsync is None:
            fsync = os.environ.get("REPRO_LOG_FSYNC", "0") == "1"
        os.makedirs(directory, exist_ok=True)
        self.sharded = isinstance(stream, ShardedStream)
        wal = os.path.join(directory, "wal")
        if self.sharded and stream.ts_field is None:
            self.lanes = {
                f"shard{i}": SegmentLog(
                    os.path.join(wal, f"shard{i}"),
                    tuple(stream.fields) + (SEQ_FIELD,),
                    segment_bytes=segment_bytes, fsync=fsync)
                for i in range(stream.num_shards)}
        else:
            self.lanes = {"lane0": SegmentLog(
                os.path.join(wal, "lane0"), tuple(stream.fields),
                segment_bytes=segment_bytes, fsync=fsync)}
        self.manager = CheckpointManager(
            os.path.join(directory, "ckpt"), keep=self.keep)
        latest = self.manager.latest_step()
        self._step = latest if latest is not None else 0
        self._rows_at_ckpt = 0
        self.checkpoints = 0
        self.recovered = 0       # bumped by BigDawg.recover_stream
        self.last_recovery: Optional[Dict[str, Any]] = None
        self._ckpt_lock = threading.Lock()
        self._write_meta()

    # -- meta.json: everything needed to rebuild the stream fresh -------------
    def _write_meta(self) -> None:
        path = os.path.join(self.directory, "meta.json")
        if os.path.exists(path):
            return
        s = self.stream
        meta = {"name": s.name, "fields": list(s.fields),
                "ts_field": s.ts_field, "max_delay": s.max_delay,
                "idle_timeout": s.idle_timeout,
                "keep": self.keep,
                "checkpoint_every_rows": self.checkpoint_every_rows,
                "dead_letter": s._late_sink is not None}
        if self.sharded:
            # record the *logical* registration capacity too: per-shard
            # capacities are a ceil-division of it, so summing them back
            # would inflate the figure and break the StreamSpec
            # manifest round-trip (spec ≡ from_manifest(meta))
            spec = getattr(s, "spec", None)
            meta.update(kind="sharded",
                        shard_key=s.shard_key,
                        block_rows=s.block_rows,
                        engines=s.shard_engines(),
                        shard_capacities=[sh.capacity
                                          for sh in s._shards],
                        rolling=s._shards[0].rolling,
                        capacity=(spec.capacity if spec is not None
                                  else sum(sh.capacity
                                           for sh in s._shards)))
        else:
            meta.update(kind="stream", capacity=s.capacity,
                        rolling=s.rolling)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=1)
        os.replace(tmp, path)

    # -- write-behind log hooks (called from engine.py) ------------------------
    def log_append(self, seq_start: int,
                   cols: Dict[str, np.ndarray], n: int) -> None:
        with trace.span("stream/log_append", stream=self.stream.name,
                        rows=n):
            self.lanes["lane0"].append(KIND_APPEND, seq_start, n,
                                       cols, n)
        self._count_rows(n)

    def log_shard(self, shard: int, block: int, total: int,
                  payload: Dict[str, np.ndarray]) -> None:
        n = payload[SEQ_FIELD].shape[0]
        with trace.span("stream/log_append", stream=self.stream.name,
                        shard=shard, rows=n):
            self.lanes[f"shard{shard}"].append(KIND_SHARD, block,
                                               total, payload, n)
        self._count_rows(n)

    def log_arrive(self, cols: Dict[str, np.ndarray], n: int) -> None:
        with trace.span("stream/log_append", stream=self.stream.name,
                        rows=n):
            self.lanes["lane0"].append(KIND_ARRIVE, -1, n, cols, n)
        self._count_rows(n)

    def log_flush(self, target: float) -> None:
        self.lanes["lane0"].append(KIND_FLUSH, -1, 0, None, 0,
                                   target=target)

    def _count_rows(self, n: int) -> None:
        metrics.counter("repro_stream_log_records_total",
                        "segment-log records written",
                        stream=self.stream.name).inc()
        metrics.counter("repro_stream_log_rows_total",
                        "data rows written to the segment log",
                        stream=self.stream.name).inc(n)

    def lane_lsns(self) -> Dict[str, int]:
        return {lane: log.next_lsn for lane, log in self.lanes.items()}

    def rows_logged(self) -> int:
        return sum(log.rows for log in self.lanes.values())

    # -- checkpoint ------------------------------------------------------------
    def maybe_checkpoint(self) -> bool:
        """Cadence hook (StreamRuntime.tick): checkpoint once
        ``checkpoint_every_rows`` data rows have been logged since the
        last one.  Async save — the tick never blocks on .npy I/O."""
        if self.checkpoint_every_rows is None:
            return False
        if (self.rows_logged() - self._rows_at_ckpt
                < self.checkpoint_every_rows):
            return False
        self.checkpoint(blocking=False)
        return True

    def checkpoint(self, blocking: bool = True) -> int:
        """Capture (state, lane positions, dead-letter sink) at one
        coherent instant and save through the CheckpointManager; then
        prune log segments no retained checkpoint needs."""
        with self._ckpt_lock, \
                trace.span("stream/checkpoint", stream=self.stream.name):
            crash_point("stream/checkpoint:begin")
            self.manager.wait()
            self._prune_wal()

            def capture():
                caps = {"lsns": self.lane_lsns(),
                        "rows_logged": self.rows_logged(),
                        "late_sink": None}
                sink = self.stream._late_sink
                if sink is not None:
                    with sink._lock:
                        caps["late_sink"] = sink._export_locked()
                return caps

            state, caps = self.stream._checkpoint_snapshot(capture)
            payload = {"state": state, "lsns": caps["lsns"],
                       "late_sink": caps["late_sink"]}
            arrays: Dict[str, np.ndarray] = {}
            spec = _encode(payload, "a", arrays)
            flat = {_META_KEY: np.array(json.dumps(spec)), **arrays}
            self._step += 1
            self.manager.save(self._step, flat, blocking=blocking)
            self._rows_at_ckpt = caps["rows_logged"]
            self.checkpoints += 1
            metrics.counter("repro_stream_checkpoints_total",
                            "stream durability checkpoints",
                            stream=self.stream.name).inc()
            crash_point("stream/checkpoint:saved")
            if blocking:
                self._prune_wal()
            return self._step

    def _prune_wal(self) -> None:
        """Drop segments wholly below the minimum lane position across
        every retained (promoted) checkpoint — older segments can never
        be replayed again."""
        floors: Dict[str, int] = {}
        for step in self.manager.all_steps():
            lsns = _checkpoint_lsns(self.manager, step)
            if lsns is None:
                return                   # unreadable: prune nothing
            for lane, lsn in lsns.items():
                floors[lane] = min(floors.get(lane, lsn), lsn)
        if not floors:
            return
        for lane, log in self.lanes.items():
            log.prune_below(floors.get(lane, 0))
        crash_point("stream/checkpoint:pruned")

    # -- status ---------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {"directory": self.directory,
                "lanes": len(self.lanes),
                "log_records": sum(log.records
                                   for log in self.lanes.values()),
                "log_rows": self.rows_logged(),
                "log_bytes": sum(log.bytes
                                 for log in self.lanes.values()),
                "segments": sum(len(log._segments())
                                for log in self.lanes.values()),
                "checkpoints": self.checkpoints,
                "checkpoint_every_rows": self.checkpoint_every_rows,
                "last_checkpoint_step": self._step or None,
                "recovered": self.recovered,
                "last_recovery": self.last_recovery}

    def close(self) -> None:
        self.manager.wait()
        for log in self.lanes.values():
            log.close()


def _checkpoint_lsns(manager: CheckpointManager,
                     step: int) -> Optional[Dict[str, int]]:
    """The per-lane log positions of one checkpoint, read from its meta
    leaf only (no array loads)."""
    path = os.path.join(manager.directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        meta_file = manifest["leaves"][_META_KEY]["file"]
        spec = json.loads(str(np.load(os.path.join(path, meta_file))))
        # decode just the lsns subtree — the full payload holds $a array
        # refs we have not (and need not have) loaded
        return {k: int(v) for k, v in
                _decode(spec["$d"]["lsns"], {}).items()}
    except (OSError, KeyError, ValueError):
        return None


# -- attach / recover / replay ------------------------------------------------

def attach(stream, directory: str, *,
           checkpoint_every_rows: Optional[int] = None,
           keep: int = 3, segment_bytes: int = 1 << 20,
           fsync: Optional[bool] = None) -> StreamDurability:
    """Make ``stream`` durable: open (or create) its log directory and
    install the write-behind hook.  Idempotent per stream object."""
    if stream._durable is not None:
        return stream._durable
    durable = StreamDurability(
        stream, directory, checkpoint_every_rows=checkpoint_every_rows,
        keep=keep, segment_bytes=segment_bytes, fsync=fsync)
    stream._durable = durable
    return durable


@dataclasses.dataclass
class RecoveryResult:
    stream: Any                       # Stream | ShardedStream (detached)
    late_sink: Optional[Stream]
    checkpoint_step: Optional[int]
    records_replayed: int
    rows_replayed: int
    seconds: float
    truncated_records: int            # cut as unrecoverable (torn/partial)


def recover(directory: str, *, repair: bool = True) -> RecoveryResult:
    """Rebuild the durable stream from ``directory``: latest checkpoint
    (or a fresh stream per ``meta.json``), then replay the log tail
    through the live ingest code paths.  With ``repair=True`` (the
    post-crash mode) torn tails and incompletely-logged blocks are
    physically truncated so the next recovery sees a consistent log;
    ``repair=False`` is the read-only mode ``replay(S)`` uses against a
    live stream's directory.

    The result's stream is detached (not registered, no durability
    hook) — ``BigDawg.recover_stream`` does both."""
    t0 = time.perf_counter()
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    manager = CheckpointManager(os.path.join(directory, "ckpt"),
                                keep=int(meta.get("keep", 3)))
    step = manager.latest_step()
    with trace.span("stream/replay", stream=meta["name"],
                    checkpoint=step if step is not None else -1):
        if step is not None:
            flat = manager.restore_flat(step)
            spec = json.loads(str(flat.pop(_META_KEY)))
            payload = _decode(spec, flat)
            state = payload["state"]
            if meta["kind"] == "sharded":
                stream = ShardedStream.from_state(state)
            else:
                stream = Stream.from_state(state)
            sink = (Stream.from_state(payload["late_sink"])
                    if payload.get("late_sink") is not None else None)
            lsns = {k: int(v) for k, v in payload["lsns"].items()}
        else:
            stream = _fresh_stream(meta)
            sink = (_fresh_sink(meta) if meta.get("dead_letter")
                    else None)
            lsns = {}
        if sink is None and meta.get("dead_letter"):
            sink = _fresh_sink(meta)
        stream._late_sink = sink

        lanes = _open_lanes(meta, directory)
        records = {lane: log.scan(lsns.get(lane, 0), repair=repair)
                   for lane, log in lanes.items()}
        if meta["kind"] == "sharded" and meta["ts_field"] is None:
            replayed, rows, cut = _replay_sharded(stream, lanes,
                                                  records, repair)
        else:
            replayed, rows, cut = _replay_single(stream,
                                                 records["lane0"])
        for log in lanes.values():
            log.close()
    seconds = time.perf_counter() - t0
    metrics.counter("repro_stream_recoveries_total",
                    "stream recover() invocations",
                    stream=meta["name"]).inc()
    metrics.counter("repro_stream_replay_rows_total",
                    "rows re-applied from the segment log",
                    stream=meta["name"]).inc(rows)
    return RecoveryResult(stream=stream, late_sink=sink,
                          checkpoint_step=step,
                          records_replayed=replayed, rows_replayed=rows,
                          seconds=seconds, truncated_records=cut)


def _fresh_stream(meta: Dict[str, Any]):
    if meta["kind"] == "sharded":
        pairs = []
        for i, (ename, cap) in enumerate(zip(meta["engines"],
                                             meta["shard_capacities"])):
            shard = Stream(f"{meta['name']}@shard{i}",
                           tuple(meta["fields"]) + (SEQ_FIELD,),
                           cap, rolling=meta.get("rolling", True))
            pairs.append((ename, shard))
        return ShardedStream(meta["name"], meta["fields"], pairs,
                             shard_key=meta.get("shard_key"),
                             block_rows=meta.get("block_rows", 64),
                             ts_field=meta.get("ts_field"),
                             max_delay=meta.get("max_delay", 0.0),
                             idle_timeout=meta.get("idle_timeout"))
    return Stream(meta["name"], meta["fields"], meta["capacity"],
                  rolling=meta.get("rolling", True),
                  ts_field=meta.get("ts_field"),
                  max_delay=meta.get("max_delay", 0.0),
                  idle_timeout=meta.get("idle_timeout"))


def _fresh_sink(meta: Dict[str, Any]) -> Stream:
    capacity = (meta["capacity"] if meta["kind"] == "stream"
                else sum(meta["shard_capacities"]))
    return Stream(f"{meta['name']}.__late", meta["fields"], capacity)


def _open_lanes(meta: Dict[str, Any],
                directory: str) -> Dict[str, SegmentLog]:
    wal = os.path.join(directory, "wal")
    if meta["kind"] == "sharded" and meta["ts_field"] is None:
        return {f"shard{i}": SegmentLog(
            os.path.join(wal, f"shard{i}"),
            tuple(meta["fields"]) + (SEQ_FIELD,))
            for i in range(len(meta["engines"]))}
    return {"lane0": SegmentLog(os.path.join(wal, "lane0"),
                                tuple(meta["fields"]))}


def _apply_plain(stream: Stream, cols: Dict[str, np.ndarray],
                 n: int) -> None:
    """Re-apply one committed batch to a (shard) ring exactly as
    ``_append_prepared``'s publish would have — same counters, same
    single write path."""
    with stream._lock:
        stream.blocks_reserved += 1
        stream.rows_reserved += n
        stream._ingest_locked(cols, n)
        stream._append_times.append((time.monotonic(), n))


def _replay_single(stream, records: List[Record]
                   ) -> Tuple[int, int, int]:
    """Replay a single-lane log (plain stream, or any event-time
    stream) in lsn order.  Returns (records, rows, records cut)."""
    replayed = rows = 0
    for i, rec in enumerate(records):
        if rec.kind == KIND_APPEND:
            if rec.block != stream.total_appended:
                # seq discontinuity: the record belongs to a different
                # history than the restored state — unrecoverable tail
                return replayed, rows, len(records) - i
            _apply_plain(stream, rec.cols, rec.nrows)
        elif rec.kind == KIND_ARRIVE:
            stream._append_event_time(rec.cols, rec.nrows)
        elif rec.kind == KIND_FLUSH:
            with stream._lock:
                stream._flush_locked(rec.target)
        replayed += 1
        rows += rec.nrows
    return replayed, rows, 0


def _replay_sharded(stream: ShardedStream,
                    lanes: Dict[str, SegmentLog],
                    records: Dict[str, List[Record]],
                    repair: bool) -> Tuple[int, int, int]:
    """Replay per-shard lanes by reassembling blocks: a block is
    applied only when the records across lanes account for every one
    of its rows, and only in contiguous seq order from the restored
    frontier.  Everything after the first incomplete block (a crash
    landed between its shard commits, or between ring publish and log
    append) is cut — per lane those records are a suffix, truncated
    physically with ``repair=True`` so the next recovery agrees."""
    blocks: Dict[int, Dict[str, Any]] = {}
    for lane, recs in records.items():
        shard = int(lane[len("shard"):])
        for rec in recs:
            entry = blocks.setdefault(rec.block,
                                      {"total": rec.total, "parts": []})
            entry["parts"].append((shard, rec))
    replayed = rows = 0
    frontier = stream.total_appended
    while frontier in blocks:
        entry = blocks[frontier]
        total = entry["total"]
        if sum(r.nrows for _, r in entry["parts"]) != total:
            break
        for shard, rec in sorted(entry["parts"]):
            _apply_plain(stream._shards[shard], rec.cols, rec.nrows)
            replayed += 1
            rows += rec.nrows
        with stream._frontier:
            stream.total_appended += total
        stream.reserved = stream.total_appended
        stream.blocks_reserved += 1
        stream.rows_reserved += total
        frontier = stream.total_appended
    # cut: every lane record belonging to a block at/after the frontier
    cut = 0
    for lane, recs in records.items():
        bad = [r for r in recs if r.block >= frontier]
        if bad:
            cut += len(bad)
            if repair:
                lanes[lane].truncate_from(bad[0].lsn)
    return replayed, rows, cut


# -- fingerprint: the recovered ≡ original equality ---------------------------

def fingerprint(stream) -> Dict[str, Any]:
    """A comparable digest of everything ``recovered ≡ original``
    promises: counters, watermarks, ring contents (exact bytes, in seq
    order), pending buffers, and the dead-letter sink.  Wall-clock-only
    state (append-time history, idle arrival stamps) is excluded."""
    import hashlib

    def ring_digest(s: Stream) -> Dict[str, Any]:
        h = hashlib.sha256()
        with s._lock:
            for f in s.fields:
                h.update(s._ordered(f).tobytes())
            pend = hashlib.sha256()
            for b in s._pending:
                for f in s.fields:
                    pend.update(np.ascontiguousarray(
                        b[f], np.float64).tobytes())
            return {"name": s.name, "rows": s._count, "next": s._next,
                    "total_appended": s.total_appended,
                    "total_dropped": s.total_dropped,
                    "blocks_reserved": s.blocks_reserved,
                    "rows_reserved": s.rows_reserved,
                    "watermark": s.watermark,
                    "max_ts_seen": s.max_ts_seen,
                    "min_ts_seen": s.min_ts_seen,
                    "total_late": s.total_late,
                    "pending_rows": s._pending_rows,
                    "evicted_ts": s._evicted_ts,
                    "ring": h.hexdigest(), "pending": pend.hexdigest()}

    if isinstance(stream, ShardedStream):
        with stream._lock:
            pend = hashlib.sha256()
            for b in stream._pending:
                for f in stream.fields:
                    pend.update(np.ascontiguousarray(
                        b[f], np.float64).tobytes())
            for a in stream._pending_arrivals:
                pend.update(np.ascontiguousarray(a, np.int64).tobytes())
            out = {"name": stream.name,
                   "total_appended": stream.total_appended,
                   "total_dropped": stream.total_dropped,
                   "blocks_reserved": stream.blocks_reserved,
                   "rows_reserved": stream.rows_reserved,
                   "blocks_abandoned": stream.blocks_abandoned,
                   "watermark": stream.watermark,
                   "max_ts_seen": stream.max_ts_seen,
                   "min_ts_seen": stream.min_ts_seen,
                   "total_late": stream.total_late,
                   "pending_rows": stream._pending_rows,
                   "arrivals": stream._arrivals,
                   "shard_max_ts": list(stream._shard_max_ts),
                   "pending": pend.hexdigest(),
                   "shards": [ring_digest(s) for s in stream._shards]}
    else:
        out = ring_digest(stream)
    if stream._late_sink is not None:
        out["late_sink"] = ring_digest(stream._late_sink)
    return out


# -- replica catch-up ---------------------------------------------------------

def catch_up(replica, durable: StreamDurability) -> Dict[str, Any]:
    """Bring a read replica (Migrator stream-route *copy* mode) up to
    date with its primary by replaying the primary's live segment log
    from the per-lane positions stored on the replica at copy time
    (``replica._replica_lsns``, captured inside
    ``_checkpoint_snapshot`` so state and log position agree exactly).

    Read-only against the log (``repair=False`` — a concurrent
    writer's half-flushed tail is skipped, never cut) and incremental:
    the replica's lane floors advance past every applied record, so
    repeated calls replay only the delta.  For seq-sharded primaries a
    block is applied only once every shard slice of it has been
    logged; an incomplete tail block stays pending until the next
    call."""
    t0 = time.perf_counter()
    floors: Dict[str, int] = dict(
        getattr(replica, "_replica_lsns", None) or {})
    with trace.span("stream/catch_up", stream=replica.name):
        records = {lane: log.scan(floors.get(lane, 0), repair=False)
                   for lane, log in durable.lanes.items()}
        if (isinstance(replica, ShardedStream)
                and replica.ts_field is None):
            replayed, rows, applied = _catch_up_sharded(replica,
                                                        records)
        else:
            recs = records["lane0"]
            replayed, rows, _ = _replay_single(replica, recs)
            applied = {"lane0": (recs[replayed - 1].lsn + 1
                                 if replayed else None)}
    for lane in durable.lanes:
        if applied.get(lane) is not None:
            floors[lane] = max(floors.get(lane, 0), applied[lane])
        else:
            floors.setdefault(lane, 0)
    replica._replica_lsns = floors
    metrics.counter("repro_stream_replica_catchup_rows_total",
                    "rows applied to read replicas from the primary's "
                    "segment log",
                    stream=replica.name).inc(rows)
    return {"records": replayed, "rows": rows,
            "seconds": time.perf_counter() - t0, "lsns": dict(floors)}


def _catch_up_sharded(stream: ShardedStream,
                      records: Dict[str, List[Record]]
                      ) -> Tuple[int, int, Dict[str, Optional[int]]]:
    """The incremental (non-repairing) sibling of ``_replay_sharded``:
    apply complete blocks in contiguous seq order from the replica's
    frontier, and report per-lane the first *unapplied* lsn (the next
    catch-up floor) — ``None`` when the lane had no records to scan."""
    blocks: Dict[int, Dict[str, Any]] = {}
    for lane, recs in records.items():
        shard = int(lane[len("shard"):])
        for rec in recs:
            entry = blocks.setdefault(rec.block,
                                      {"total": rec.total, "parts": []})
            entry["parts"].append((shard, rec))
    replayed = rows = 0
    frontier = stream.total_appended
    while frontier in blocks:
        entry = blocks[frontier]
        total = entry["total"]
        if sum(r.nrows for _, r in entry["parts"]) != total:
            break                      # incomplete tail block: wait
        for shard, rec in sorted(entry["parts"]):
            _apply_plain(stream._shards[shard], rec.cols, rec.nrows)
            replayed += 1
            rows += rec.nrows
        with stream._frontier:
            stream.total_appended += total
        stream.reserved = stream.total_appended
        stream.blocks_reserved += 1
        stream.rows_reserved += total
        frontier = stream.total_appended
    applied: Dict[str, Optional[int]] = {}
    for lane, recs in records.items():
        pending = [r.lsn for r in recs if r.block >= frontier]
        applied[lane] = (pending[0] if pending
                         else (recs[-1].lsn + 1 if recs else None))
    return replayed, rows, applied


# -- replay-as-loadgen --------------------------------------------------------

def replay_clone(stream) -> Dict[str, float]:
    """Rebuild the durable stream from its on-disk log into a detached
    clone (read-only scan — the live log is never repaired), timing the
    rebuild: the segment log doubling as a deterministic load
    generator.  Returns the stats row the BQL ``replay(S)`` op and the
    ``stream/replay_rate`` bench report: replayed records/rows,
    seconds, rows/sec, and whether the clone is bit-identical to the
    live stream right now (1.0 exactly when no ingest raced the
    replay)."""
    durable = stream._durable
    if durable is None:
        raise StreamException(
            f"stream {stream.name!r} has no durability attached "
            f"(register it with durability=<dir>)")
    result = recover(durable.directory, repair=False)
    identical = float(fingerprint(result.stream) == fingerprint(stream))
    rate = (result.rows_replayed / result.seconds
            if result.seconds > 0 else 0.0)
    return {"checkpoint_step": float(result.checkpoint_step or 0),
            "records": float(result.records_replayed),
            "rows": float(result.rows_replayed),
            "seconds": result.seconds,
            "rows_per_second": rate,
            "identical": identical}
