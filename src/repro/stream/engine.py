"""StreamEngine — the S-Store analog of the polystore (paper §III lists a
streaming island among BigDAWG's islands; the v0.1 release ships without
one, this module adds it).

A ``Stream`` is an append-only, bounded ring buffer of rows over a fixed
set of float64 fields.  When the buffer is full the oldest rows are
overwritten (drop-oldest backpressure) and counted in ``total_dropped``.
Window views over the buffer materialize as island data-model objects:

  snapshot  — every buffered row, oldest first, as a ``dm.Table``
              (with a ``seq`` column of global sequence numbers)
  tumbling  — the most recent *complete* seq-aligned window of ``size``
              rows as a 1-D ``dm.ArrayObject`` (dims ``("tick",)``)
  sliding   — windows of ``size`` rows every ``slide`` rows over the
              buffer as a 2-D ``dm.ArrayObject`` (dims ``("window",
              "tick")``)

Materialized windows then ride the existing Migrator casts into the array
island (binary) or the relational island (staged) — see
``core/api.default_deployment``.

Scale-out (arXiv:1609.07548 §streams-across-engines): a ``ShardedStream``
hash-partitions one logical stream across multiple ``StreamEngine``s —
scatter appends, seq-ordered gather reads — so the BQL ops stay
shard-transparent.  Shard ring buffers are *live-migratable* between
StreamEngines (the Migrator's ``stream`` route moves data + seq watermark
+ drop counters) without interrupting standing queries.

Multi-producer ingest (arXiv:1905.10336's observation that polystore
throughput dies at serialized ingest boundaries): appends no longer
serialize on one coordinator lock.  A producer atomically *reserves* a
contiguous block of global sequence numbers under a micro-lock (counter
bumps only — no ring work ever runs inside it), stages its rows into
per-shard payloads on its own thread, and publishes each payload through
that shard's **ordered committer**, which admits blocks strictly in
reservation order — so every shard ring stays seq-sorted and gathers,
rolling sums, watermark flushes and drop accounting are bit-identical to
the old serial path.  Reads see the *committed frontier*: a seq is
visible only once every block below it has fully published, so a gather
can never observe a half-written batch.  ``Stream.producer()`` hands out
per-producer handles and ``ingest_concurrency()`` reports the
reservation/contention counters (surfaced via Monitor/admin.status()).
Event-time streams keep their insertion buffer serialized — there the
global seq is *reserved at flush time* (ts order), and concurrent
producers contend only for the cheap buffer parking.

Event time (arXiv:1609.07548 makes S-Store the polystore's time-ordered
engine): a stream declared with ``ts_field`` accepts bounded out-of-order
ingest.  Arriving rows park in an insertion buffer until the stream's
**low watermark** — ``max(ts seen) - max_delay``, and the *minimum across
shards* for key-hashed sharded streams — passes them; they are then
flushed into the ring in timestamp order, with the global ``seq``
assigned *at flush time*, so seq order and event-time order coincide and
every seq-aligned op keeps working.  Rows arriving below the watermark
are **late**: dropped and counted (``total_late``), never silently
reordered.  ``ewindow(span[, slide])`` is the event-time window view,
closed only once the watermark passes its end.  Streams without
``ts_field`` keep the exact append-ordered semantics of before.
"""
from __future__ import annotations

import collections
import contextlib
import math
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm
from repro.core.engines import ENGINE_KINDS, Engine
from repro.core.executor import DataUnavailableException
from repro.obs import trace

# reserved per-row field carrying the logical stream's global sequence
# number inside shard ring buffers (float64 is exact for seq < 2**53)
SEQ_FIELD = "__seq"

# aggregates that decompose into per-shard partials / rolling sums
_ROLLING_AGGS = ("count", "sum", "avg")
_COMBINABLE_AGGS = _ROLLING_AGGS + ("min", "max")


def _memoized_window_aggregate(stream, size: int, fn: str, field: str,
                               compute) -> float:
    """Shared memo scheme for tumbling-window aggregates (Stream and
    ShardedStream): resolve the latest complete window index k, return
    the cached value when this window was already folded (repeat ticks
    are O(1), and the value survives ring eviction), else call
    ``compute(s, e)`` for global seqs [s, e) and cache it.  The caller
    holds the stream's lock; ``stream`` provides ``total_appended``,
    ``_agg_cache``, ``agg_cache_hits``/``agg_computes`` and ``name``."""
    assert fn in _COMBINABLE_AGGS, fn
    k = stream.total_appended // size - 1
    if k < 0:
        raise StreamException(
            f"stream {stream.name!r}: no complete window of "
            f"size {size} yet ({stream.total_appended} rows)")
    key = (fn, field, size)
    cached = stream._agg_cache.get(key)
    if cached is not None and cached[0] == k:
        stream.agg_cache_hits += 1
        return cached[1]
    value = compute(k * size, (k + 1) * size)
    stream.agg_computes += 1
    stream._agg_cache[key] = (k, value)
    return value


def _latest_closed_ewindow(stream, span: float,
                           slide: Optional[float]) -> Tuple[float, float]:
    """(start, end) of the latest *closed* event-time window of ``stream``
    — windows are aligned to multiples of ``slide`` (default ``span``) on
    the ts axis, and closed means the low watermark has passed the end.
    Shared by Stream and ShardedStream; raises while no window is closed
    or the stream has no event-time field."""
    span = float(span)
    step = float(slide) if slide is not None else span
    if span <= 0 or step <= 0:
        raise StreamException(
            f"stream {stream.name!r}: ewindow span/slide must be "
            f"positive, got ({span}, {step})")
    if stream.ts_field is None:
        raise StreamException(
            f"stream {stream.name!r} has no event-time field "
            f"(declare it with ts_field=...)")
    wm = stream.watermark
    if wm == float("-inf"):
        raise StreamException(
            f"stream {stream.name!r}: watermark has not started, "
            f"no closed ewindow yet")
    k = math.floor((wm - span) / step)
    start = k * step
    while start + span > wm:                  # float-rounding guard
        k -= 1
        start = k * step
    if start + span <= stream.min_ts_seen:
        # the window axis is unbounded, but a window that ends before
        # the first row ever seen says nothing about the stream yet
        raise StreamException(
            f"stream {stream.name!r}: no closed ewindow covering data "
            f"yet (watermark {wm}, first ts {stream.min_ts_seen})")
    return start, start + span


def _classify_late(stream, cols: Dict[str, np.ndarray],
                   n: int) -> Tuple[Dict[str, np.ndarray], int, int]:
    """Split an arriving batch against ``stream``'s low watermark
    (caller holds the lock): rows strictly below it can no longer be
    inserted in timestamp order, so they are dropped and counted on
    ``total_late``.  Returns (kept columns, kept count, late count).
    The single definition of lateness — Stream and ShardedStream must
    never disagree on the boundary (``ts == watermark`` is NOT late:
    the ring's flushed rows all have ts <= watermark, so an equal row
    still appends in order).

    With a dead-letter sink attached (``register_stream(...,
    dead_letter=True)``), late rows additionally land in the
    ``{name}.__late`` side stream — queryable history instead of only
    a counter.  The sink is a plain leaf stream with its own locks, so
    appending to it under the caller's lock cannot deadlock, and the
    sink append is in arrival order (the caller's lock serializes
    arrivals), so replay reproduces it deterministically."""
    ts = cols[stream.ts_field]
    late_mask = ts < stream.watermark
    nlate = int(late_mask.sum())
    if nlate:
        stream.total_late += nlate
        if stream._late_sink is not None:
            stream._late_sink._append_prepared(
                {f: v[late_mask] for f, v in cols.items()}, nlate)
        keep = ~late_mask
        cols = {f: v[keep] for f, v in cols.items()}
    return cols, n - nlate, nlate


def _key_owners(values: np.ndarray, num_shards: int) -> np.ndarray:
    """Shard owner of each row under key-hash partitioning:
    ``floor(|v|) mod N``.  Non-finite key values (NaN/±inf — missing
    vitals, sensor saturation) route deterministically to shard 0
    instead of through the C-undefined float->int64 cast."""
    return np.floor(np.abs(np.nan_to_num(
        values, nan=0.0, posinf=0.0, neginf=0.0))
    ).astype(np.int64) % num_shards


def _event_time_stats(stream) -> Dict[str, Any]:
    """The event-time health block shared by Stream and ShardedStream
    stats (caller holds the owning lock).  The watermark is reported as
    None until it starts, keeping status() JSON-serializable."""
    wm = stream.watermark
    return {"ts_field": stream.ts_field,
            "max_delay": stream.max_delay,
            "idle_timeout": stream.idle_timeout,
            "watermark": None if wm == float("-inf") else wm,
            "late": stream.total_late,
            "pending": stream._pending_rows}


def _recent_rate(append_times: "collections.deque[Tuple[float, int]]"
                 ) -> float:
    """Rows/second over the recent (wall_time, rows) append history —
    0.0 with fewer than two appends (shared by Stream and
    ShardedStream.rate; caller holds the owning lock)."""
    if len(append_times) < 2:
        return 0.0
    t0, _ = append_times[0]
    t1, _ = append_times[-1]
    if t1 <= t0:
        return 0.0
    rows = sum(n for _, n in list(append_times)[1:])
    return rows / (t1 - t0)


class StreamException(DataUnavailableException):
    """Data-dependent streaming error (window not complete / evicted,
    schema mismatch on append).  Subclasses the core's transient marker
    so cached plans survive it."""


class _OrderedCommitter:
    """FIFO block publisher for one commit lane (a plain ring, or one
    shard of a ShardedStream).

    Tickets are issued in seq-reservation order — the caller issues
    while holding its reservation micro-lock, so ticket order == global
    seq order on this lane.  ``commit(ticket, fn)`` blocks until every
    earlier ticket has published, runs ``fn`` (the ring write), then
    releases the next block: the lane's ring receives blocks strictly
    in seq order even when producers finish staging out of order.

    Because tickets are issued under ONE micro-lock, the wait-for graph
    across lanes always follows global reservation order (an earlier
    producer never waits on a later one), so committing multiple lanes
    in any per-producer order cannot deadlock.

    ``pause()`` is the live-migration barrier: it drains every already-
    issued ticket (in-flight blocks publish to the old ring) and holds
    later tickets back until ``resume()`` — those blocks carry over to
    whatever object the commit closure resolves after the swap.

    Stall stealing: a producer that reserved a ticket and then died
    (hard-killed thread, crashed process stage) would otherwise park
    every later ticket on its lane forever.  Any waiter (commit,
    quiesce, pause) that sees **zero lane progress** for
    ``stall_timeout`` seconds steals the head ticket if its owner never
    entered ``commit()`` — the lane advances over the hole and the
    stolen ticket's ``commit``, should the owner revive, raises instead
    of double-advancing the lane.  Tickets whose owners are alive
    (waiting, or running their ring write) are never stolen.
    ``REPRO_COMMIT_STALL_TIMEOUT`` (seconds, default 5.0) tunes it;
    0 disables stealing."""

    def __init__(self) -> None:
        self._cond = threading.Condition(threading.Lock())
        self._next_ticket = 0
        self._committed = 0
        self._pause_at: Optional[int] = None
        self._entered: set = set()     # tickets with a live owner inside commit
        self._stolen: set = set()      # tickets advanced over after a stall
        self.waits = 0             # commits that had to block (contention)
        self.steals = 0            # tickets stolen from presumed-dead owners
        self.stall_timeout = float(os.environ.get(
            "REPRO_COMMIT_STALL_TIMEOUT", "5.0"))

    def issue(self) -> int:
        with self._cond:
            ticket = self._next_ticket
            self._next_ticket += 1
            return ticket

    def _turn(self, ticket: int) -> bool:
        return (self._committed == ticket
                and (self._pause_at is None or ticket < self._pause_at))

    def _wait_or_steal_locked(self, done, limit: int) -> None:
        """Wait (holding the condition) until ``done()``; whenever a
        full stall interval passes with no lane progress at all, steal
        never-entered tickets from the head up to ``limit``.  With
        stealing disabled this is a plain ``wait_for``."""
        timeout = self.stall_timeout if self.stall_timeout > 0 else None
        while not done():
            if not self._cond.wait(timeout=timeout):
                # a timeout means no notify — so no commit on this lane
                # — for stall_timeout seconds: the head owner is dead
                # or wedged; steal it if it never entered commit()
                self._steal_stalled_locked(limit)

    def _steal_stalled_locked(self, limit: int) -> None:
        stole = False
        while (self._committed < limit
               and self._committed < self._next_ticket
               and (self._pause_at is None
                    or self._committed < self._pause_at)
               and self._committed not in self._entered):
            self._stolen.add(self._committed)
            self._committed += 1
            self.steals += 1
            stole = True
        if stole:
            self._cond.notify_all()

    def commit(self, ticket: int, fn):
        """Publish ticket's block: wait for its turn, run ``fn``, release
        the next.  ``fn``'s return value is passed through; the lane
        advances even when ``fn`` raises (a poisoned block must not wedge
        every later producer forever).  Raises StreamException — without
        running ``fn`` or advancing the lane — when the ticket was
        stolen after a stall (the lane already moved past it).

        ``fn`` runs OUTSIDE the condition lock: once it is ticket's turn
        no other commit can run on this lane until ``_committed``
        advances (in the finally), so mutual exclusion holds — and
        ``issue()`` (called under the owner's reservation micro-lock)
        never blocks behind an in-progress ring write, keeping the
        reservation path counter-bumps-only for real."""
        with self._cond:
            if ticket in self._stolen:
                self._stolen.discard(ticket)
                raise StreamException(
                    f"commit ticket {ticket} was stolen after a "
                    f"{self.stall_timeout:g}s stall (producer presumed "
                    f"dead); its block is a permanent hole")
            self._entered.add(ticket)
            if not self._turn(ticket):
                self.waits += 1
                self._wait_or_steal_locked(
                    lambda: self._turn(ticket), ticket)
        try:
            return fn()
        finally:
            with self._cond:
                self._entered.discard(ticket)
                self._committed += 1
                self._cond.notify_all()

    def consumed(self, ticket: int) -> bool:
        """True once the lane moved past ``ticket`` (committed or
        stolen)."""
        with self._cond:
            return self._committed > ticket

    def was_stolen(self, ticket: int) -> bool:
        with self._cond:
            return ticket in self._stolen

    def quiesce(self) -> None:
        """Drain: wait until every ticket issued so far has committed
        (no pause — new tickets keep flowing afterwards; tickets of
        dead producers are stolen rather than waited on forever)."""
        with self._cond:
            barrier = self._next_ticket
            self._wait_or_steal_locked(
                lambda: self._committed >= barrier, barrier)

    def pause(self) -> None:
        """Drain issued tickets and hold later ones until resume()."""
        with self._cond:
            assert self._pause_at is None, "committer already paused"
            self._pause_at = self._next_ticket
            self._wait_or_steal_locked(
                lambda: self._committed >= self._pause_at,
                self._pause_at)

    def resume(self) -> None:
        with self._cond:
            self._pause_at = None
            self._cond.notify_all()

    @property
    def in_flight(self) -> int:
        with self._cond:
            return self._next_ticket - self._committed


class StreamProducer:
    """One producer's handle onto a stream (``stream.producer()``).

    ``append`` delegates to the stream's reservation path — the handle
    adds no locking of its own — while tracking per-producer counts;
    the stream tracks how many handles are open at once
    (``ingest_concurrency()["producers_open"/"producers_peak"]``).
    Context manager; ``close()`` is idempotent."""

    def __init__(self, stream, name: Optional[str] = None) -> None:
        self.stream = stream
        serial = stream._producer_opened()
        self.name = name or f"{stream.name}#p{serial}"
        self.batches = 0
        self.rows = 0
        self.dropped = 0
        self._closed = False

    def append(self, rows: Dict[str, Iterable[float]]) -> Dict[str, int]:
        counts = self.stream.append(rows)
        self.batches += 1
        self.rows += counts["appended"]
        self.dropped += counts.get("dropped", 0)
        return counts

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.stream._producer_closed()

    def __enter__(self) -> "StreamProducer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _MultiProducerIngest:
    """Shared producer-registry + reservation-stats surface of Stream
    and ShardedStream (the ``ingest_concurrency`` block both report)."""

    def _init_ingest(self) -> None:
        self._reserve_lock = threading.Lock()   # seq/ticket micro-lock
        self.blocks_reserved = 0   # reserve calls (flushes, for ts streams)
        self.rows_reserved = 0     # rows covered by those reservations
        self.producers_open = 0
        self.producers_peak = 0
        self._producer_serial = 0

    def producer(self, name: Optional[str] = None) -> StreamProducer:
        """A handle for one ingest thread; see StreamProducer."""
        return StreamProducer(self, name)

    def _producer_opened(self) -> int:
        with self._reserve_lock:
            self.producers_open += 1
            self.producers_peak = max(self.producers_peak,
                                      self.producers_open)
            self._producer_serial += 1
            return self._producer_serial

    def _producer_closed(self) -> None:
        with self._reserve_lock:
            self.producers_open -= 1

    def _commit_waits(self) -> int:             # per-class override
        raise NotImplementedError

    def _in_flight_rows(self) -> int:           # per-class override
        raise NotImplementedError

    def _commit_steals(self) -> int:            # per-class override
        raise NotImplementedError

    def ingest_concurrency(self) -> Dict[str, int]:
        """Reservation/contention counters of the multi-producer ingest
        path: how many producer handles are (were) open, how many seq
        blocks/rows have been reserved, how many are reserved but not
        yet published (``in_flight_rows``), how many commits had to
        wait for an earlier block (``commit_waits`` — the contention
        signal; 0 under a single producer), and how many tickets were
        stolen from stalled producers (``commit_steals`` — nonzero only
        after a producer died mid-append)."""
        return {"producers_open": self.producers_open,
                "producers_peak": self.producers_peak,
                "blocks_reserved": self.blocks_reserved,
                "rows_reserved": self.rows_reserved,
                "in_flight_rows": self._in_flight_rows(),
                "commit_waits": self._commit_waits(),
                "commit_steals": self._commit_steals()}


class Stream(_MultiProducerIngest):
    """Append-only bounded ring buffer of rows (fixed float64 fields)."""

    def __init__(self, name: str, fields: Sequence[str],
                 capacity: int = 4096, rolling: bool = True,
                 ts_field: Optional[str] = None,
                 max_delay: float = 0.0,
                 idle_timeout: Optional[float] = None) -> None:
        assert fields, "a stream needs at least one field"
        assert capacity > 0, "capacity must be positive"
        self.name = name
        self.fields: Tuple[str, ...] = tuple(fields)
        self.capacity = int(capacity)
        self.rolling = bool(rolling)
        # -- event time (optional): rows buffer until the low watermark
        # (max ts seen - max_delay) passes them, then flush ts-ordered
        if ts_field is not None:
            assert ts_field in self.fields, ts_field
            assert ts_field != SEQ_FIELD
        assert max_delay >= 0.0
        self.ts_field = ts_field
        self.max_delay = float(max_delay)
        self.watermark = float("-inf")    # low watermark (flush boundary)
        self.max_ts_seen = float("-inf")
        self.min_ts_seen = float("inf")   # first event ever accepted
        self.total_late = 0               # rows arriving below the watermark
        self._pending: List[Dict[str, np.ndarray]] = []   # insertion buffer
        self._pending_rows = 0
        # the ring stays sorted on this field (set for event-time streams
        # and for the shard rings of an event-time ShardedStream): track
        # the newest evicted row's value so closed windows that lost rows
        # to ring overflow raise instead of returning silent partials
        self._evict_field: Optional[str] = ts_field
        self._evicted_ts = float("-inf")
        self._cols = {f: np.zeros(self.capacity, np.float64)
                      for f in self.fields}
        # rolling-sum support: _cum[f][pos] is the running total of field
        # f over the buffered rows up to and including pos, so a sum over
        # any buffered range is one subtraction (see range_sum).  Rings
        # are built lazily on a field's first rolling aggregate — pure
        # ingest streams never pay the memory or the per-append cumsum —
        # and ``rolling=False`` disables them outright.
        self._cum: Dict[str, np.ndarray] = {}
        self._running: Dict[str, float] = {}
        self._next = 0                    # ring write position
        self._count = 0                   # valid rows in the buffer
        self.total_appended = 0           # global sequence high-water mark
        self.total_dropped = 0            # rows overwritten before read
        # (wall_time, rows) of recent appends, for rate()
        self._append_times: "collections.deque[Tuple[float, int]]" = \
            collections.deque(maxlen=64)
        # (fn, field, size) -> (window index k, value): repeated ticks over
        # the same complete tumbling window skip recompute entirely
        self._agg_cache: Dict[Tuple[str, str, int], Tuple[int, float]] = {}
        self.agg_cache_hits = 0
        self.agg_computes = 0
        self._lock = threading.Lock()
        # -- multi-producer ingest: seq blocks reserve on the micro-lock,
        # ring writes publish through the ordered committer (FIFO by
        # reservation, so results are bit-identical to the serial path).
        # Event-time streams reserve at flush instead (ts order).
        self._init_ingest()
        self._committer = _OrderedCommitter()
        # -- idle-timeout punctuation: after ``idle_timeout`` seconds
        # with no arrivals, advance_idle_watermark() flushes the whole
        # insertion buffer (the automatic analog of flush())
        assert idle_timeout is None or idle_timeout > 0
        self.idle_timeout = idle_timeout
        self._last_arrival: Optional[float] = None
        self._now = time.monotonic        # injectable for tests
        # -- durability (opt-in, see repro.stream.durability): the
        # write-behind segment-log hook and the late-row dead-letter
        # sink.  Both None by default — the hot path pays one attribute
        # check per batch and nothing else.
        self._durable = None
        self._late_sink: Optional["Stream"] = None
        # registration spec (repro.stream.spec.StreamSpec), set by
        # BigDawg._register_spec / recover_stream; None when the stream
        # was built directly
        self.spec = None

    # -- ingest ---------------------------------------------------------------
    def append(self, rows: Dict[str, Iterable[float]]) -> Dict[str, int]:
        """Append a batch of rows (column dict); returns counts.

        Rows beyond ``capacity`` overwrite the oldest buffered rows; the
        overwritten count is the batch's ``dropped`` (backpressure is
        drop-oldest, never blocking the producer).

        Concurrent producers are safe: each batch reserves the next seq
        block under the reservation micro-lock (no ring work inside it)
        and publishes through the ordered committer, so batches land in
        the ring whole and in reservation order — a single producer sees
        exactly the old serial semantics, result dict included.
        """
        if set(rows) != set(self.fields):
            raise StreamException(
                f"stream {self.name!r} fields {self.fields} != "
                f"appended fields {tuple(rows)}")
        cols = {f: np.asarray(rows[f], np.float64).reshape(-1)
                for f in self.fields}
        n = cols[self.fields[0]].shape[0]
        if any(v.shape[0] != n for v in cols.values()):
            raise StreamException("ragged append batch")
        if n == 0:
            with self._lock:
                counts = {"appended": 0, "dropped": 0, "rows": self._count}
                if self.ts_field is not None:
                    counts.update(late=0, flushed=0,
                                  pending=self._pending_rows)
                return counts
        with trace.span("stream/append", stream=self.name, rows=n):
            if self.ts_field is not None:
                return self._append_event_time(cols, n)
            return self._append_prepared(cols, n)

    def _append_prepared(self, cols: Dict[str, np.ndarray],
                         n: int) -> Dict[str, int]:
        """Reserve-and-publish for payloads already validated and
        converted to float64 columns — the shared tail of the public
        ``append`` and the per-shard entry point of the ShardedStream
        scatter (one validation per logical batch, not one per shard):
        reserve the seq block under the micro-lock, then publish the
        ring write through the ordered committer."""
        with trace.span("stream/reserve", stream=self.name):
            with self._reserve_lock:
                ticket = self._committer.issue()
                self.blocks_reserved += 1
                self.rows_reserved += n

        def write() -> Dict[str, int]:
            with self._lock:
                dropped = self._ingest_locked(cols, n)
                self._append_times.append((time.monotonic(), n))
                self._last_arrival = self._now()
                counts = {"appended": n, "dropped": dropped,
                          "rows": self._count}
                seq_start = self.total_appended - n
            if self._durable is not None:
                # write-behind: the batch is already published to the
                # ring (readers can see it); logging stays inside the
                # committer's ordered section so the log is strictly in
                # seq order, but outside the ring lock so readers never
                # wait on log I/O
                self._durable.log_append(seq_start, cols, n)
            return counts

        with trace.span("committer/commit", lane=self.name,
                        ticket=ticket):
            return self._committer.commit(ticket, write)

    def _ingest_locked(self, cols: Dict[str, np.ndarray], n: int) -> int:
        """Write ``n`` rows into the ring (caller holds the lock).  The
        single write path: seq-ordered appends land here directly; the
        event-time path lands here from ``_flush_locked`` with rows
        already sorted by timestamp.  Returns the overwritten count."""
        dropped = max(0, self._count + n - self.capacity)
        if dropped and self._evict_field is not None:
            # the ring is sorted on the evict field, and so is the
            # concatenation of (buffered rows, this batch) — the newest
            # evicted value is at concat offset dropped-1
            f = self._evict_field
            if dropped <= self._count:
                boundary = float(self._ordered(f)[dropped - 1])
            else:
                boundary = float(cols[f][dropped - self._count - 1])
            self._evicted_ts = max(self._evicted_ts, boundary)
        for f in self.fields:
            src = cols[f][-self.capacity:]        # keep only the tail
            cum = None
            if f in self._cum:
                cum = np.cumsum(src) + self._running[f]
                self._running[f] = float(cum[-1])
            m = src.shape[0]
            end = self._next + m
            if end <= self.capacity:
                self._cols[f][self._next:end] = src
                if cum is not None:
                    self._cum[f][self._next:end] = cum
            else:
                first = self.capacity - self._next
                self._cols[f][self._next:] = src[:first]
                self._cols[f][:end % self.capacity] = src[first:]
                if cum is not None:
                    self._cum[f][self._next:] = cum[:first]
                    self._cum[f][:end % self.capacity] = cum[first:]
        self._next = (self._next + min(n, self.capacity)) % self.capacity
        self._count = min(self.capacity, self._count + n)
        prev_total = self.total_appended
        self.total_appended += n
        self.total_dropped += dropped
        # re-anchor the cumulative rings once per ring generation
        # (amortized O(1)/row): without this the running totals grow
        # for the stream's lifetime and the O(1) range_sum subtraction
        # loses float64 precision for large-magnitude fields (e.g.
        # epoch-millisecond timestamps) under steady small batches
        if (self._cum and self.total_appended // self.capacity
                != prev_total // self.capacity):
            self._reanchor_cums_locked()
        return dropped

    # -- event-time ingest ----------------------------------------------------
    def _append_event_time(self, cols: Dict[str, np.ndarray],
                           n: int) -> Dict[str, int]:
        """Bounded out-of-order ingest: rows at or above the low watermark
        park in the insertion buffer; the watermark then advances to
        ``max_ts_seen - max_delay`` and everything it passed is flushed
        into the ring in timestamp order.  Rows below the watermark are
        late — counted and dropped, never inserted out of order."""
        with trace.span("stream/stage", stream=self.name,
                        rows=n) as sp, self._lock:
            self._last_arrival = self._now()
            if self._durable is not None:
                # log the arrival batch BEFORE late classification: the
                # log carries every row that arrived (late ones
                # included), so replay re-runs classification and
                # reproduces total_late and the dead-letter sink
                self._durable.log_arrive(cols, n)
            cols, kept, nlate = _classify_late(self, cols, n)
            if kept:
                self._pending.append(cols)
                self._pending_rows += kept
                self.max_ts_seen = max(
                    self.max_ts_seen, float(cols[self.ts_field].max()))
                self.min_ts_seen = min(
                    self.min_ts_seen, float(cols[self.ts_field].min()))
            flushed, dropped = self._flush_locked(
                self.max_ts_seen - self.max_delay)
            self._append_times.append((time.monotonic(), kept))
            return {"appended": kept, "dropped": dropped, "late": nlate,
                    "flushed": flushed, "pending": self._pending_rows,
                    "rows": self._count}

    def _flush_locked(self, new_watermark: float) -> Tuple[int, int]:
        """Advance the (monotone) watermark and flush every buffered row
        it passed, sorted by timestamp (stable, so equal-ts rows keep
        arrival order).  Returns (rows flushed, rows dropped by the
        ring)."""
        self.watermark = max(self.watermark, new_watermark)
        if not self._pending or self.watermark == float("-inf"):
            return 0, 0
        cat = {f: np.concatenate([b[f] for b in self._pending])
               for f in self.fields}
        ts = cat[self.ts_field]
        ready = ts <= self.watermark
        m = int(ready.sum())
        if m == 0:
            return 0, 0
        order = np.argsort(ts[ready], kind="stable")
        flush_cols = {f: v[ready][order] for f, v in cat.items()}
        if m < ts.shape[0]:
            hold = ~ready
            self._pending = [{f: v[hold] for f, v in cat.items()}]
        else:
            self._pending = []
        self._pending_rows -= m
        # event-time streams reserve the global seq block HERE, at flush
        # (ts order == seq order); counted so ingest_concurrency stats
        # stay meaningful for both stream kinds
        self.blocks_reserved += 1
        self.rows_reserved += m
        dropped = self._ingest_locked(flush_cols, m)
        return m, dropped

    def flush(self, to_ts: Optional[float] = None) -> Dict[str, Any]:
        """Punctuation: force the watermark up to ``to_ts`` (default: the
        max timestamp seen, flushing the whole insertion buffer).  The
        escape hatch for idle feeds — without new rows the watermark
        never advances on its own."""
        with self._lock:
            if self.ts_field is None:
                raise StreamException(
                    f"stream {self.name!r} has no event-time field")
            target = self.max_ts_seen if to_ts is None else float(to_ts)
            if self._durable is not None and target > self.watermark:
                # punctuation is external input (wall clock / operator),
                # not derivable from arrivals — log the resolved target
                # so replay applies the same watermark advance
                self._durable.log_flush(target)
            flushed, dropped = self._flush_locked(target)
            return {"flushed": flushed, "dropped": dropped,
                    "watermark": self.watermark,
                    "pending": self._pending_rows}

    def advance_idle_watermark(self) -> Dict[str, Any]:
        """Automatic punctuation: when the stream has seen no arrivals
        for ``idle_timeout`` seconds, advance the watermark to the max
        timestamp seen (== ``flush()``), so a quiet feed's buffered rows
        and open windows don't stall forever.  A no-op while traffic
        flows, when no ``idle_timeout`` was configured, or on streams
        without an event-time axis.  ``StreamRuntime.tick`` calls this
        for every registered event-time stream."""
        if self.ts_field is None or self.idle_timeout is None:
            return {"flushed": 0, "dropped": 0}
        with self._lock:
            if (self._last_arrival is None
                    or self._now() - self._last_arrival
                    < self.idle_timeout):
                return {"flushed": 0, "dropped": 0}
            if self._durable is not None \
                    and self.max_ts_seen > self.watermark:
                self._durable.log_flush(self.max_ts_seen)
            flushed, dropped = self._flush_locked(self.max_ts_seen)
            return {"flushed": flushed, "dropped": dropped}

    # -- ingest_concurrency hooks (see _MultiProducerIngest) -------------------
    def _commit_waits(self) -> int:
        return self._committer.waits

    def _commit_steals(self) -> int:
        return self._committer.steals

    def _in_flight_rows(self) -> int:
        # reserved-but-unpublished rows; event-time streams reserve at
        # flush, so for them this is always 0 (pending rows are reported
        # separately, in the event-time stats block)
        return self.rows_reserved - self.total_appended \
            if self.ts_field is None else 0

    def _reanchor_cums_locked(self) -> None:
        """Rewrite every cumulative slot as a prefix sum over the
        *buffered* rows only (base 0 at the oldest row).  All slots are
        rewritten in one epoch, so range_sum differences stay exact, and
        the running totals stay bounded by ~capacity x max|value|."""
        idx = (self._pos(0) + np.arange(self._count)) % self.capacity
        for f in self._cum:
            cum = np.cumsum(self._cols[f][idx])
            self._cum[f][idx] = cum
            self._running[f] = float(cum[-1]) if self._count else 0.0

    # -- views ----------------------------------------------------------------
    def _ordered(self, field: str) -> np.ndarray:
        """Buffered values oldest-first (caller holds the lock)."""
        start = (self._next - self._count) % self.capacity
        idx = (start + np.arange(self._count)) % self.capacity
        return self._cols[field][idx]

    def snapshot(self) -> dm.Table:
        with self._lock:
            first_seq = self.total_appended - self._count
            cols = {"seq": jnp.asarray(
                first_seq + np.arange(self._count))}
            for f in self.fields:
                cols[f] = jnp.asarray(self._ordered(f))
            return dm.Table(cols)

    def window(self, size: int,
               slide: Optional[int] = None) -> dm.ArrayObject:
        """Tumbling (``slide`` is None) or sliding window view."""
        assert size > 0
        with self._lock:
            first_seq = self.total_appended - self._count
            if slide is None:
                # most recent complete seq-aligned tumbling window
                k = self.total_appended // size - 1
                if k < 0:
                    raise StreamException(
                        f"stream {self.name!r}: no complete window of "
                        f"size {size} yet ({self.total_appended} rows)")
                s = k * size
                if s < first_seq:
                    raise StreamException(
                        f"stream {self.name!r}: window [{s},{s + size}) "
                        f"already evicted (buffer starts at {first_seq})")
                off = s - first_seq
                attrs = {f: jnp.asarray(self._ordered(f)[off:off + size])
                         for f in self.fields}
                return dm.ArrayObject(attrs, ("tick",))
            assert slide > 0
            if self._count < size:
                raise StreamException(
                    f"stream {self.name!r}: {self._count} rows < window "
                    f"size {size}")
            starts = np.arange(0, self._count - size + 1, slide)
            attrs = {}
            for f in self.fields:
                buf = self._ordered(f)
                attrs[f] = jnp.asarray(
                    np.stack([buf[s:s + size] for s in starts]))
            return dm.ArrayObject(attrs, ("window", "tick"))

    def ewindow(self, span: float,
                slide: Optional[float] = None) -> dm.ArrayObject:
        """Latest *closed* event-time window as a 1-D ArrayObject.

        Windows are aligned to multiples of ``slide`` (default: ``span``,
        i.e. tumbling) on the timestamp axis; a window ``[s, s + span)``
        is closed only once the low watermark reaches its end, so its
        contents can no longer change (any row that could still land in
        it would be late).  Unlike seq windows the row count varies with
        event density — an empty closed window is legitimate.  Raises
        until the first window closes, and when the ring has already
        evicted rows the window covered (no silent partials)."""
        return self._ewindow_bounds_to_view(
            *_latest_closed_ewindow(self, span, slide))

    def _ewindow_bounds_to_view(self, start: float,
                                end: float) -> dm.ArrayObject:
        with self._lock:
            if start <= self._evicted_ts:
                raise StreamException(
                    f"stream {self.name!r}: ewindow [{start},{end}) "
                    f"already evicted (rows up to ts "
                    f"{self._evicted_ts} overwritten)")
            a, b = self._seq_bounds_locked(self.ts_field, start, end)
            idx = (self._pos(0) + np.arange(a, b)) % self.capacity
            attrs = {f: jnp.asarray(self._cols[f][idx])
                     for f in self.fields}
            return dm.ArrayObject(attrs, ("tick",))

    def rate(self) -> float:
        """Recent ingest rate in rows/second (0.0 with <2 appends)."""
        with self._lock:
            return _recent_rate(self._append_times)

    # -- rolling-aggregate primitives -----------------------------------------
    def _pos(self, offset: int) -> int:
        """Ring position of the ``offset``-th oldest buffered row."""
        return (self._next - self._count + offset) % self.capacity

    def range_sum(self, field: str, a: int, b: int) -> float:
        """Sum of buffered rows at ordered offsets ``[a, b)`` in O(1) via
        the cumulative ring (offset 0 = oldest buffered row)."""
        with self._lock:
            return self._range_sum_locked(field, a, b)

    def _ensure_cum_locked(self, field: str) -> bool:
        """Build the field's cumulative ring on first use (caller holds
        the lock).  Returns False when rolling is disabled."""
        if field in self._cum:
            return True
        if not self.rolling or field == SEQ_FIELD:
            return False
        self._cum[field] = np.zeros(self.capacity, np.float64)
        self._running[field] = 0.0
        self._reanchor_cums_locked()
        return True

    def _range_sum_locked(self, field: str, a: int, b: int) -> float:
        assert 0 <= a <= b <= self._count, (a, b, self._count)
        if a == b:
            return 0.0
        if not self._ensure_cum_locked(field):      # rolling=False
            idx = (self._pos(0) + np.arange(a, b)) % self.capacity
            return float(self._cols[field][idx].sum())
        hi = float(self._cum[field][self._pos(b - 1)])
        if a > 0:
            lo = float(self._cum[field][self._pos(a - 1)])
        else:
            p = self._pos(0)
            lo = float(self._cum[field][p]) - float(self._cols[field][p])
        return hi - lo

    def _seq_bounds_locked(self, field: str, lo: float, hi: float
                           ) -> Tuple[int, int]:
        """Ordered offsets [a, b) of buffered rows whose ``field`` value
        lies in ``[lo, hi)``, assuming the field is non-decreasing in
        append order (true of the reserved seq column).  Binary search
        over the ring's two contiguous segments — no materialization."""
        start = self._pos(0)
        end = start + self._count
        col = self._cols[field]
        if end <= self.capacity:
            seg = col[start:end]
            return (int(np.searchsorted(seg, lo)),
                    int(np.searchsorted(seg, hi)))
        older, newer = col[start:], col[:end % self.capacity]
        n1 = older.shape[0]
        fa, fb = np.searchsorted(older, lo), np.searchsorted(older, hi)
        a = int(fa) if fa < n1 else n1 + int(np.searchsorted(newer, lo))
        b = int(fb) if fb < n1 else n1 + int(np.searchsorted(newer, hi))
        return a, b

    def range_slice(self, field: str, a: int, b: int) -> np.ndarray:
        """Copy of buffered rows at ordered offsets ``[a, b)``."""
        with self._lock:
            assert 0 <= a <= b <= self._count
            idx = (self._pos(0) + np.arange(a, b)) % self.capacity
            return self._cols[field][idx]

    def ordered_arrays(self, fields: Optional[Sequence[str]] = None
                       ) -> Tuple[int, Dict[str, np.ndarray]]:
        """(first buffered seq, {field: oldest-first float64 copy}) — the
        raw gather primitive (no jnp conversion, unlike snapshot())."""
        with self._lock:
            first_seq = self.total_appended - self._count
            return first_seq, {f: self._ordered(f)
                               for f in (fields or self.fields)}

    def window_aggregate(self, size: int, fn: str, field: str) -> float:
        """Aggregate over the latest complete tumbling window without
        re-materializing it: count/sum/avg are O(1) via the cumulative
        ring; min/max reduce over the window slice.  Repeated calls for
        the same window index return the memoized value (the standing-
        query fast path: ticks faster than window completion cost O(1))."""
        with self._lock:
            def compute(s: int, e: int) -> float:
                first_seq = self.total_appended - self._count
                if s < first_seq:
                    raise StreamException(
                        f"stream {self.name!r}: window [{s},{e}) "
                        f"already evicted (buffer starts at {first_seq})")
                a, b = s - first_seq, e - first_seq
                if fn == "count":
                    return float(size)
                if fn in ("sum", "avg"):
                    value = self._range_sum_locked(field, a, b)
                    return value / size if fn == "avg" else value
                idx = (self._pos(0) + np.arange(a, b)) % self.capacity
                sl = self._cols[field][idx]
                return float(sl.min() if fn == "min" else sl.max())

            return _memoized_window_aggregate(self, size, fn, field,
                                              compute)

    # -- live-state migration (Migrator "stream" route) ------------------------
    def export_state(self) -> Dict[str, Any]:
        """Deep-copy the full live state — ring data, cumulative rings,
        write position, seq watermark, drop counters, rate history — so a
        Migrator can rebuild this stream byte-for-byte on another
        StreamEngine without losing standing-query continuity.

        Drains the ordered committer first: every seq block reserved
        before this call publishes into the exported state (in-flight
        reservations are carried, not lost).  Blocks reserved *after*
        the drain still land in this object — for a direct unsharded
        move the caller must pause its producers (documented on the
        Migrator's stream route); shard moves are safe because
        ``ShardedStream.migrate_shard`` holds the shard's committer
        paused across the whole move."""
        self._committer.quiesce()
        with self._lock:
            return self._export_locked()

    def _export_locked(self) -> Dict[str, Any]:
        """The export body (caller holds the lock AND has already
        settled the committer — quiesced for a migration export,
        paused for a durability checkpoint: quiescing under an active
        pause would deadlock on tickets issued after the pause)."""
        return {
                "name": self.name, "fields": self.fields,
                "capacity": self.capacity, "rolling": self.rolling,
                "cols": {f: v.copy() for f, v in self._cols.items()},
                "cum": {f: v.copy() for f, v in self._cum.items()},
                "running": dict(self._running),
                "next": self._next, "count": self._count,
                "total_appended": self.total_appended,
                "total_dropped": self.total_dropped,
                "append_times": list(self._append_times),
                # event-time state: the insertion buffer and watermark
                # must travel with a live move or pending rows are lost
                "ts_field": self.ts_field,
                "max_delay": self.max_delay,
                "watermark": self.watermark,
                "max_ts_seen": self.max_ts_seen,
                "min_ts_seen": self.min_ts_seen,
                "total_late": self.total_late,
                "pending": [{f: v.copy() for f, v in b.items()}
                            for b in self._pending],
                "evict_field": self._evict_field,
                "evicted_ts": self._evicted_ts,
                "idle_timeout": self.idle_timeout,
                "blocks_reserved": self.blocks_reserved,
                "rows_reserved": self.rows_reserved,
            }

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> "Stream":
        stream = cls(state["name"], state["fields"], state["capacity"],
                     rolling=state.get("rolling", True),
                     ts_field=state.get("ts_field"),
                     max_delay=state.get("max_delay", 0.0),
                     idle_timeout=state.get("idle_timeout"))
        stream._cols = {f: np.asarray(v, np.float64)
                        for f, v in state["cols"].items()}
        stream._cum = {f: np.asarray(v, np.float64)
                       for f, v in state["cum"].items()}
        stream._running = dict(state["running"])
        stream._next = int(state["next"])
        stream._count = int(state["count"])
        stream.total_appended = int(state["total_appended"])
        stream.total_dropped = int(state["total_dropped"])
        stream._append_times.extend(state["append_times"])
        stream.watermark = float(state.get("watermark", float("-inf")))
        stream.max_ts_seen = float(state.get("max_ts_seen",
                                             float("-inf")))
        stream.min_ts_seen = float(state.get("min_ts_seen",
                                             float("inf")))
        stream.total_late = int(state.get("total_late", 0))
        stream._pending = [{f: np.asarray(v, np.float64)
                            for f, v in b.items()}
                           for b in state.get("pending", [])]
        stream._pending_rows = sum(
            b[stream.fields[0]].shape[0] for b in stream._pending)
        stream._evict_field = state.get("evict_field", stream.ts_field)
        stream._evicted_ts = float(state.get("evicted_ts",
                                             float("-inf")))
        stream.blocks_reserved = int(state.get("blocks_reserved", 0))
        stream.rows_reserved = int(state.get(
            "rows_reserved", stream.total_appended))
        return stream

    def clone(self, name: Optional[str] = None,
              state: Optional[Dict[str, Any]] = None) -> "Stream":
        """A detached deep copy of the live state, optionally renamed —
        what the Migrator's stream-route *copy* mode (read replicas)
        builds on.  The clone shares nothing with this stream: no
        committer, no durability hook, no late sink.  Pass ``state``
        (an ``export_state`` dict captured earlier, e.g. inside
        ``_checkpoint_snapshot``) to clone that instant instead of
        now."""
        state = dict(self.export_state() if state is None else state)
        if name is not None:
            state["name"] = name
        return Stream.from_state(state)

    # -- durability checkpoint hook -------------------------------------------
    def _checkpoint_snapshot(self, capture):
        """Export the full state at an instant where the ring and the
        write-behind segment log agree, running ``capture()`` (the
        durability layer reads its per-lane log positions) at that same
        instant.  Returns (state dict, capture()'s result).

        Event-time streams ingest and log under ``self._lock``, so the
        lock alone is the coherence point.  Seq-ordered streams log
        inside the committer's ordered section *after* the ring write:
        freezing reservations (micro-lock) and draining the lane
        (``pause``) leaves ring and log equal; in-flight reservations
        at the freeze are drained, not lost."""
        if self.ts_field is not None:
            with self._lock:
                return self._export_locked(), capture()
        with self._reserve_lock:
            self._committer.pause()
            try:
                with self._lock:
                    state = self._export_locked()
                return state, capture()
            finally:
                self._committer.resume()

    # -- island data-model plumbing ------------------------------------------
    @property
    def num_rows(self) -> int:
        with self._lock:
            return self._count

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self._cols.values()))

    def stats(self) -> Dict[str, float]:
        with self._lock:
            out: Dict[str, Any] = {
                "rows": self._count, "capacity": self.capacity,
                "appended": self.total_appended,
                "dropped": self.total_dropped,
                "ingest_concurrency": self.ingest_concurrency()}
            if self.ts_field is not None:
                out.update(_event_time_stats(self))
            return out


class ShardedStream(_MultiProducerIngest):
    """One logical stream hash-partitioned across multiple StreamEngines.

    Each shard is an ordinary ``Stream`` named ``{name}@shard{i}`` living
    on its own engine, with the reserved ``__seq`` field carrying the
    logical stream's global sequence number.  The coordinator handle (this
    object) is registered on *every* participating StreamEngine under the
    logical name, so any engine the Planner picks can serve the query —
    shard-transparent scatter appends and seq-ordered gather reads.

    Partitioning: round-robin over contiguous seq *blocks* of
    ``block_rows`` (default — balanced, and the scatter splits a batch
    into zero-copy views) or, with ``shard_key``, by hash of a field's
    value (``floor(|v|) mod N`` — the realistic skew-prone placement the
    rebalance hook exists for).  Either way every row carries its global
    seq, so gathers are bit-identical to the unsharded stream for every
    row the shards still retain; shard rings evict independently, so
    skewed key traffic can evict a hot shard's rows earlier than one big
    ring would have (seq gaps in snapshots, tumbling windows raise).

    Concurrency: producers no longer serialize on the coordinator lock.
    An append reserves its contiguous global seq block under the
    reservation micro-lock (counter + per-shard commit tickets, no ring
    work), stages per-shard payloads on its own thread, and publishes
    each through that shard's ordered committer — blocks enter every
    shard ring strictly in seq order, so rings stay seq-sorted and
    gathers are bit-identical to the serial path.  ``total_appended`` is
    the *committed frontier*: it advances only once every earlier block
    has fully published, and every read (snapshot/window/aggregate)
    sees at most the frontier — never a half-written batch.  Gathers,
    event-time ingest, migration, and stats still take the coordinator
    lock; a single large batch additionally fans its per-shard ring
    writes out to a thread pool (numpy copies release the GIL).
    """

    # fan the per-shard writes out to threads only when the batch is big
    # enough for numpy to dominate (below this the pool overhead wins)
    PARALLEL_APPEND_MIN_ROWS = 2048

    def __init__(self, name: str, fields: Sequence[str],
                 shards: List[Tuple[str, Stream]],
                 shard_key: Optional[str] = None,
                 block_rows: int = 64,
                 ts_field: Optional[str] = None,
                 max_delay: float = 0.0,
                 idle_timeout: Optional[float] = None) -> None:
        assert shards, "a sharded stream needs at least one shard"
        self.name = name
        self.fields: Tuple[str, ...] = tuple(fields)
        self.shard_key = shard_key
        self.block_rows = int(block_rows)
        assert self.block_rows > 0
        if shard_key is not None:
            assert shard_key in self.fields, shard_key
        self._engines: List[str] = [e for e, _ in shards]
        self._shards: List[Stream] = [s for _, s in shards]
        # committed frontier: every seq below it has fully published to
        # its shard ring (multi-producer appends advance it only once
        # all earlier blocks finished, so reads never see half a batch)
        self.total_appended = 0
        # -- multi-producer ingest: seq reservation counter + per-shard
        # ordered committers + the block-completion ledger behind the
        # frontier.  ``reserved`` is the next global seq to hand out.
        self._init_ingest()
        self.reserved = 0
        self._committers = [_OrderedCommitter() for _ in self._shards]
        self._frontier = threading.Condition(threading.Lock())
        self._finished: Dict[int, int] = {}      # block start -> rows
        # block start -> (rows, {shard: ticket}) for reserved-but-not-
        # finished blocks: lets the frontier abandon a block whose
        # producer died mid-stage once its stolen tickets prove it can
        # never complete (same permanent-hole semantics as a staging
        # failure)
        self._pending_blocks: Dict[int, Tuple[int, Dict[int, int]]] = {}
        self.blocks_abandoned = 0
        # the scatter fan-out pool serves ONE producer at a time (pool
        # tasks block on commit order; sharing it across producers could
        # queue an earlier producer's ring write behind a later
        # producer's waiting task — a deadlock); contenders that find
        # the gate held just commit inline, in shard order
        self._pool_gate = threading.Lock()
        self._rate_lock = threading.Lock()       # guards _append_times
        # -- event time: the coordinator owns the insertion buffer — the
        # global seq is assigned at flush time in ts order, so shard rings
        # receive monotone ts bands and stay sorted on both seq and ts
        if ts_field is not None:
            assert ts_field in self.fields, ts_field
        assert max_delay >= 0.0
        self.ts_field = ts_field
        self.max_delay = float(max_delay)
        self.watermark = float("-inf")
        self.max_ts_seen = float("-inf")
        self.min_ts_seen = float("inf")
        self.total_late = 0
        self._pending: List[Dict[str, np.ndarray]] = []
        self._pending_arrivals: List[np.ndarray] = []   # tie-break tags
        self._pending_rows = 0
        self._arrivals = 0
        # per-shard max ts seen (key-hashed streams only: the stream's
        # low watermark is the MINIMUM across shards that have data, so
        # one lagging shard holds every window open)
        self._shard_max_ts = [float("-inf")] * len(self._shards)
        # idle-timeout: a key range that goes quiet for this many
        # seconds stops holding the min-watermark back (and a fully
        # idle stream flushes outright) — the automatic flush()
        assert idle_timeout is None or idle_timeout > 0
        self.idle_timeout = idle_timeout
        self._last_arrival: Optional[float] = None
        self._shard_last_arrival: List[Optional[float]] = \
            [None] * len(self._shards)
        self._now = time.monotonic        # injectable for tests
        if ts_field is not None:
            for shard in self._shards:
                shard._evict_field = ts_field
        self._append_times: "collections.deque[Tuple[float, int]]" = \
            collections.deque(maxlen=64)
        self._agg_cache: Dict[Tuple[str, str, int], Tuple[int, float]] = {}
        self.agg_cache_hits = 0
        self.agg_computes = 0
        self.migrations = 0               # live shard moves (rebalances)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.RLock()
        # -- durability hooks (see repro.stream.durability): None until
        # attached — the hot path pays one attribute check per batch
        self._durable = None
        self._late_sink: Optional[Stream] = None
        # registration spec (repro.stream.spec.StreamSpec), set by
        # BigDawg._register_spec / recover_stream; None when the handle
        # was built directly
        self.spec = None

    # -- topology -------------------------------------------------------------
    @property
    def num_shards(self) -> int:
        return len(self._shards)

    @property
    def home_engine(self) -> str:
        """Engine anchoring shard 0 — the Planner's canonical placement
        for gather reads (all placements are equivalent; pinning one keeps
        plan enumeration from exploding with engine count)."""
        with self._lock:
            return self._engines[0]

    def shard_name(self, idx: int) -> str:
        return f"{self.name}@shard{idx}"

    def shard_engines(self) -> List[str]:
        with self._lock:
            return list(self._engines)

    @property
    def total_dropped(self) -> int:
        return sum(s.total_dropped for s in self._shards)

    @property
    def num_rows(self) -> int:
        return sum(s.num_rows for s in self._shards)

    def nbytes(self) -> int:
        # shard rings are separate engine objects and already counted
        # there; the handle itself holds no row data
        return 0

    # -- ingest: scatter ------------------------------------------------------
    def append(self, rows: Dict[str, Iterable[float]]) -> Dict[str, int]:
        """Scatter-append a batch.  The producer reserves the global seq
        block [t, t+n) under the reservation micro-lock (counter bumps
        and per-shard commit tickets, never ring work), partitions its
        rows into per-shard payloads on its own thread, and publishes
        each payload through that shard's ordered committer — so
        concurrent producers overlap all staging work and serialize only
        the per-shard ring writes, in seq order, keeping every shard
        ring seq-sorted and gathers bit-identical to serial ingest."""
        if set(rows) != set(self.fields):
            raise StreamException(
                f"stream {self.name!r} fields {self.fields} != "
                f"appended fields {tuple(rows)}")
        cols = {f: np.asarray(rows[f], np.float64).reshape(-1)
                for f in self.fields}
        n = cols[self.fields[0]].shape[0]
        if any(v.shape[0] != n for v in cols.values()):
            raise StreamException("ragged append batch")
        with trace.span("stream/append", stream=self.name, rows=n,
                        shards=len(self._shards)):
            if self.ts_field is not None:
                return self._append_event_time(cols, n)
            if n == 0:
                with self._rate_lock:
                    self._append_times.append((time.monotonic(), 0))
                return {"appended": 0, "dropped": 0,
                        "rows": sum(s.num_rows for s in self._shards)}
            nsh = len(self._shards)
            owner = present = None
            if self.shard_key is not None:
                # key-hash owners depend only on the data — computed
                # before reservation so the micro-lock never touches
                # the batch
                owner = _key_owners(cols[self.shard_key], nsh)
                present = np.bincount(owner, minlength=nsh) > 0
            # -- reserve: seq block + per-shard tickets (micro-lock,
            # O(nsh))
            with trace.span("stream/reserve", stream=self.name), \
                    self._reserve_lock:
                t = self.reserved
                self.reserved += n
                if owner is None:
                    touched = self._touched_shards(t, n)
                else:
                    touched = [i for i in range(nsh) if present[i]]
                tickets = {i: self._committers[i].issue()
                           for i in touched}
                self.blocks_reserved += 1
                self.rows_reserved += n
            with self._frontier:
                self._pending_blocks[t] = (n, dict(tickets))
            # -- stage: partition into per-shard payloads (no locks
            # held)
            try:
                with trace.span("stream/stage", stream=self.name,
                                block=t):
                    parts = self._partition(cols, n, t, owner)
            except BaseException:
                # never wedge the lanes: release every issued ticket as
                # an empty publish and complete the block — its seqs
                # become a permanent hole (windows over them raise
                # "evicted"), but every other producer keeps flowing
                for i in sorted(touched):
                    self._committers[i].commit(tickets[i], lambda: None)
                self._complete_block(t, n)
                raise
            # -- publish: per-shard ordered commits (failures release
            # the lane, see _commit_parts)
            results, failure = self._commit_parts(touched, tickets,
                                                  parts, n, t)
            # -- complete: advance the committed frontier over every
            # block whose predecessors have all published (reads only
            # ever see seqs below the frontier, so no gather can
            # observe this batch while an earlier one is still in
            # flight)
            self._complete_block(t, n)
            with self._rate_lock:
                self._append_times.append((time.monotonic(), n))
            if failure is not None:
                raise failure
            dropped = sum(r["dropped"] for r in results)
            return {"appended": n, "dropped": dropped,
                    "rows": sum(s.num_rows for s in self._shards)}

    def _complete_block(self, t: int, n: int) -> None:
        """Record block [t, t+n) as fully published and advance the
        committed frontier over every contiguous finished block — then
        reap any dead block now parked at the frontier, so one killed
        producer can't make every later block invisible forever."""
        with self._frontier:
            self._finished[t] = n
            self._advance_frontier_locked()
            self._reap_stalled_locked()
            self._frontier.notify_all()

    def _advance_frontier_locked(self) -> None:
        while self.total_appended in self._finished:
            t = self.total_appended
            self.total_appended += self._finished.pop(t)
            self._pending_blocks.pop(t, None)

    def _reap_stalled_locked(self) -> int:
        """Abandon frontier-blocking blocks that can never complete:
        every commit ticket consumed, at least one by *stealing* (the
        producer died before publishing).  Their seqs become a
        permanent hole — exactly the staging-failure semantics — and
        every later finished block becomes visible.  Returns the number
        of blocks abandoned."""
        reaped = 0
        while True:
            entry = self._pending_blocks.get(self.total_appended)
            if entry is None or self.total_appended in self._finished:
                break
            n, tickets = entry
            if not all(self._committers[i].consumed(tk)
                       for i, tk in tickets.items()):
                break
            if not any(self._committers[i].was_stolen(tk)
                       for i, tk in tickets.items()):
                break
            self._pending_blocks.pop(self.total_appended)
            self.total_appended += n
            self.blocks_abandoned += 1
            reaped += 1
            self._advance_frontier_locked()
        return reaped

    def reap_stalled(self) -> int:
        """Advance the frontier over blocks abandoned by dead producers
        (see _reap_stalled_locked); safe to call any time."""
        with self._frontier:
            reaped = self._reap_stalled_locked()
            if reaped:
                self._frontier.notify_all()
        return reaped

    def _touched_shards(self, t: int, n: int) -> List[int]:
        """Round-robin shards receiving rows of seq block [t, t+n) —
        pure O(num_shards) arithmetic on the block boundaries, cheap
        enough to run inside the reservation micro-lock."""
        nsh = len(self._shards)
        blk = self.block_rows
        first, last = t // blk, (t + n - 1) // blk
        if last - first + 1 >= nsh:
            return list(range(nsh))
        return sorted({b % nsh for b in range(first, last + 1)})

    def _partition(self, cols: Dict[str, np.ndarray], n: int, t: int,
                   owner: Optional[np.ndarray]) -> List[Dict[str,
                                                             np.ndarray]]:
        """Per-shard payloads (each with the reserved seq column) for
        rows [t, t+n).  Round-robin batches spanning few blocks split
        into contiguous zero-copy views; many-block and key-hash batches
        go through the vectorized owner map.  Pure function of its
        inputs — runs on the producer's thread with no locks held."""
        nsh = len(self._shards)
        seqs = np.arange(t, t + n, dtype=np.float64)
        if owner is None and n // self.block_rows <= 32:
            # round-robin over seq blocks: shard of seq q is
            # (q // block_rows) % N.  A batch spanning few blocks
            # splits into contiguous zero-copy views at block
            # boundaries (the big-batch ingest fast path)
            blk = self.block_rows
            segs: List[List[Tuple[int, int]]] = [[] for _ in range(nsh)]
            off = 0
            while off < n:
                q = t + off
                take = min(n - off, blk - q % blk)
                segs[(q // blk) % nsh].append((off, off + take))
                off += take
            parts = []
            for i in range(nsh):
                if len(segs[i]) == 1:
                    a, b = segs[i][0]
                    payload = {f: v[a:b] for f, v in cols.items()}
                    payload[SEQ_FIELD] = seqs[a:b]
                else:
                    payload = {f: np.concatenate(
                        [v[a:b] for a, b in segs[i]])
                        for f, v in cols.items()} if segs[i] else \
                        {f: v[:0] for f, v in cols.items()}
                    payload[SEQ_FIELD] = np.concatenate(
                        [seqs[a:b] for a, b in segs[i]]) \
                        if segs[i] else seqs[:0]
                parts.append(payload)
            return parts
        if owner is None:
            # many small blocks: a Python per-segment loop would
            # dominate — compute owners vectorized instead
            owner = ((t + np.arange(n)) // self.block_rows) % nsh
        parts = []
        for i in range(nsh):
            idx = np.nonzero(owner == i)[0]
            payload = {f: v[idx] for f, v in cols.items()}
            payload[SEQ_FIELD] = seqs[idx]
            parts.append(payload)
        return parts

    def _commit_parts(self, touched: List[int], tickets: Dict[int, int],
                      parts: List[Dict[str, np.ndarray]], n: int,
                      t: int) -> Tuple[List[Dict[str, int]],
                                       Optional[BaseException]]:
        """Publish each staged payload through its shard's ordered
        committer.  Every issued ticket MUST commit — even on failure —
        or later blocks on that shard would wait forever: a publish
        that raises is recorded (first failure returned for re-raise)
        and its lane still advances (`_OrderedCommitter.commit` runs
        its release in a finally).  The shard object resolves inside
        the closure, so a block reserved before a live shard move
        publishes to wherever the shard lives when its turn comes.

        A single large batch fans its commits out to the pool when no
        other producer holds it; contenders commit inline in shard
        order.  Inline commits cannot deadlock: tickets follow global
        reservation order, so an earlier producer never waits on a
        later one — and the pool is gated to one producer because its
        queue could otherwise park an earlier producer's ring write
        behind a later producer's waiting task."""
        failures: List[BaseException] = []

        def publish(i: int) -> Dict[str, int]:
            payload = parts[i]

            def ring_write() -> Dict[str, int]:
                counts = self._shards[i]._append_prepared(
                    payload, payload[SEQ_FIELD].shape[0])
                if self._durable is not None:
                    # write-behind per-shard log: inside this lane's
                    # ordered section (records stay in seq order per
                    # lane) and after the ring write published; the
                    # record carries the block bounds so recovery can
                    # cut an incompletely-logged block
                    self._durable.log_shard(i, t, n, payload)
                return counts

            try:
                with trace.span("committer/commit", stream=self.name,
                                shard=i, ticket=tickets[i]):
                    return self._committers[i].commit(tickets[i],
                                                      ring_write)
            except BaseException as exc:     # noqa: BLE001 — re-raised
                failures.append(exc)
                return {"appended": 0, "dropped": 0}

        order = sorted(touched)
        if (len(order) > 1 and n >= self.PARALLEL_APPEND_MIN_ROWS
                and self._pool_gate.acquire(blocking=False)):
            try:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=len(self._shards),
                        thread_name_prefix=f"scatter-{self.name}")
                results = list(self._pool.map(trace.bind(publish),
                                              order))
            finally:
                self._pool_gate.release()
        else:
            results = [publish(i) for i in order]
        return results, failures[0] if failures else None

    # -- ingest_concurrency hooks (see _MultiProducerIngest) -------------------
    def _commit_waits(self) -> int:
        return sum(c.waits for c in self._committers)

    def _commit_steals(self) -> int:
        return sum(c.steals for c in self._committers)

    def _in_flight_rows(self) -> int:
        return self.reserved - self.total_appended

    def ingest_concurrency(self) -> Dict[str, int]:
        out = super().ingest_concurrency()
        out["blocks_abandoned"] = self.blocks_abandoned
        return out

    # -- event-time ingest: coordinator insertion buffer ----------------------
    def _append_event_time(self, cols: Dict[str, np.ndarray],
                           n: int) -> Dict[str, int]:
        """Bounded out-of-order scatter: rows park in the coordinator's
        insertion buffer (tagged with arrival order for stable ties) and
        flush once the stream's low watermark passes them — sorted by
        timestamp, global seqs assigned in that order, then partitioned
        to the shard rings, which therefore stay sorted on both seq and
        ts.  Key-hashed streams track a per-shard max timestamp and take
        the *minimum* across shards with data as the watermark basis, so
        one lagging shard holds every window open (use ``flush()`` as
        punctuation for idle shards)."""
        with trace.span("stream/stage", stream=self.name,
                        rows=n), self._lock:
            self._last_arrival = self._now()
            if self._durable is not None:
                # event-time scatter is coordinator-serialized: ONE
                # lane of arrival records (pre-late-classification,
                # so replay reproduces total_late and the dead-letter
                # sink), not per-shard logs
                self._durable.log_arrive(cols, n)
            cols, kept, nlate = _classify_late(self, cols, n)
            ts = cols[self.ts_field]
            if kept:
                self._pending.append(cols)
                self._pending_arrivals.append(
                    np.arange(self._arrivals, self._arrivals + kept))
                self._arrivals += kept
                self._pending_rows += kept
                self.max_ts_seen = max(self.max_ts_seen, float(ts.max()))
                self.min_ts_seen = min(self.min_ts_seen, float(ts.min()))
                if self.shard_key is not None:
                    owner = _key_owners(cols[self.shard_key],
                                        len(self._shards))
                    for i in range(len(self._shards)):
                        sel = owner == i
                        if sel.any():
                            self._shard_max_ts[i] = max(
                                self._shard_max_ts[i],
                                float(ts[sel].max()))
                            self._shard_last_arrival[i] = \
                                self._last_arrival
            flushed, dropped = self._flush_locked(
                self._watermark_candidate_locked())
            with self._rate_lock:
                self._append_times.append((time.monotonic(), kept))
            return {"appended": kept, "dropped": dropped, "late": nlate,
                    "flushed": flushed, "pending": self._pending_rows,
                    "rows": sum(s.num_rows for s in self._shards)}

    def _watermark_candidate_locked(self) -> float:
        """The low-watermark basis: ``min`` across shards that hold data
        for key-hashed streams (a shard that has never seen a row cannot
        declare other rows late and is excluded until it does), the
        global max timestamp for round-robin ones (every shard receives
        interleaved blocks, so the per-shard minima coincide).

        With ``idle_timeout`` set, a key-hashed shard whose key range
        has received nothing for that many seconds is also excluded —
        one quiet shard no longer stalls the stream minimum (the
        ROADMAP idle-timeout; ``flush()`` remains the manual escape
        hatch).  When *every* data-bearing shard has gone idle the
        basis falls back to the global max timestamp, flushing the
        stream out entirely."""
        if self.shard_key is None:
            return self.max_ts_seen - self.max_delay
        now = self._now() if self.idle_timeout is not None else None
        seen, idle_excluded = [], False
        for i, t in enumerate(self._shard_max_ts):
            if t == float("-inf"):
                continue
            last = self._shard_last_arrival[i]
            if (now is not None and last is not None
                    and now - last >= self.idle_timeout):
                idle_excluded = True
                continue
            seen.append(t)
        if not seen:
            if idle_excluded:
                return self.max_ts_seen - self.max_delay
            return float("-inf")
        return min(seen) - self.max_delay

    def _flush_locked(self, new_watermark: float) -> Tuple[int, int]:
        """Advance the monotone watermark; flush every buffered row it
        passed in (ts, arrival) order, assigning global seqs in that
        order and scattering to the shard rings."""
        self.watermark = max(self.watermark, new_watermark)
        if not self._pending or self.watermark == float("-inf"):
            return 0, 0
        cat = {f: np.concatenate([b[f] for b in self._pending])
               for f in self.fields}
        arrivals = np.concatenate(self._pending_arrivals)
        ts = cat[self.ts_field]
        ready = ts <= self.watermark
        m = int(ready.sum())
        if m == 0:
            return 0, 0
        order = np.lexsort((arrivals[ready], ts[ready]))
        flush_cols = {f: v[ready][order] for f, v in cat.items()}
        if m < ts.shape[0]:
            hold = ~ready
            self._pending = [{f: v[hold] for f, v in cat.items()}]
            self._pending_arrivals = [arrivals[hold]]
        else:
            self._pending, self._pending_arrivals = [], []
        self._pending_rows -= m
        t = self.total_appended
        seqs = np.arange(t, t + m, dtype=np.float64)
        # the seq block is reserved HERE, at flush (ts order == seq
        # order); event-time ingest is coordinator-serialized, so the
        # frontier and the reservation counter advance together
        self.total_appended += m
        self.reserved = self.total_appended
        self.blocks_reserved += 1
        self.rows_reserved += m
        nsh = len(self._shards)
        if self.shard_key is not None:
            owner = _key_owners(flush_cols[self.shard_key], nsh)
        else:
            owner = ((t + np.arange(m)) // self.block_rows) % nsh
        dropped = 0
        for i in range(nsh):
            idx = np.nonzero(owner == i)[0]
            if not idx.size:
                continue
            payload = {f: v[idx] for f, v in flush_cols.items()}
            payload[SEQ_FIELD] = seqs[idx]
            dropped += self._shards[i]._append_prepared(
                payload, idx.size)["dropped"]
        return m, dropped

    def flush(self, to_ts: Optional[float] = None) -> Dict[str, Any]:
        """Punctuation: force the watermark up to ``to_ts`` (default: the
        max timestamp seen) — the escape hatch when a shard's key range
        goes idle and would otherwise hold the min-watermark back."""
        with self._lock:
            if self.ts_field is None:
                raise StreamException(
                    f"stream {self.name!r} has no event-time field")
            target = self.max_ts_seen if to_ts is None else float(to_ts)
            if self._durable is not None and target > self.watermark:
                self._durable.log_flush(target)
            flushed, dropped = self._flush_locked(target)
            return {"flushed": flushed, "dropped": dropped,
                    "watermark": self.watermark,
                    "pending": self._pending_rows}

    def advance_idle_watermark(self) -> Dict[str, Any]:
        """Automatic punctuation for quiet key ranges: re-evaluate the
        watermark basis with idle shards excluded (see
        ``_watermark_candidate_locked``) and flush whatever it passes.
        A no-op without ``idle_timeout`` or an event-time axis.
        ``StreamRuntime.tick`` calls this every tick, so the stall
        clears even when no other shard receives a row either."""
        if self.ts_field is None or self.idle_timeout is None:
            return {"flushed": 0, "dropped": 0}
        with self._lock:
            target = self._watermark_candidate_locked()
            if (self._last_arrival is not None
                    and self._now() - self._last_arrival
                    >= self.idle_timeout):
                # the whole stream went quiet: flush it out entirely
                target = max(target, self.max_ts_seen)
            if self._durable is not None and target > self.watermark:
                # idle punctuation is wall-clock input: log the resolved
                # target so replay advances the same watermark without
                # re-evaluating idleness
                self._durable.log_flush(target)
            flushed, dropped = self._flush_locked(target)
            return {"flushed": flushed, "dropped": dropped}

    def ewindow(self, span: float,
                slide: Optional[float] = None) -> dm.ArrayObject:
        """Latest closed event-time window, gathered across shards in
        global seq order (== event-time order, ties by arrival) — bit-
        identical to the unsharded stream's ``ewindow`` over the same
        rows."""
        start, end = _latest_closed_ewindow(self, span, slide)
        with self._lock:
            evicted = max(s._evicted_ts for s in self._shards)
            if start <= evicted:
                raise StreamException(
                    f"stream {self.name!r}: ewindow [{start},{end}) "
                    f"already evicted (rows up to ts {evicted} "
                    f"overwritten)")
            _, cols = self._gather_field_range(self.ts_field, start, end)
            attrs = {f: jnp.asarray(cols[f]) for f in self.fields}
            return dm.ArrayObject(attrs, ("tick",))

    # -- reads: seq-ordered gather --------------------------------------------
    @contextlib.contextmanager
    def _all_shard_locks(self):
        """Hold every shard ring's lock at once (acquired in shard-index
        order) so a multi-shard read is a point-in-time cut: a commit
        landing on one shard mid-read cannot evict sub-frontier rows
        from a shard the reader has not reached yet.  Safe against the
        writers: commits take one shard lock at a time and never while
        holding another, so the index-ordered sweep cannot deadlock."""
        with contextlib.ExitStack() as stack:
            for shard in self._shards:
                stack.enter_context(shard._lock)
            yield

    def _gather(self, upto: Optional[int] = None
                ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """All buffered rows across shards with seq below ``upto``
        (default: the committed frontier), merged in global seq order
        (caller holds the coordinator lock).  The frontier filter is
        what keeps concurrent-producer reads gap-free: a shard ring may
        already hold a later block while an earlier block is still
        publishing to a sibling shard — those rows stay invisible until
        every predecessor committed.  All shard locks are held across
        the sweep (point-in-time cut), so concurrent eviction cannot
        punch holes below the frontier mid-read either."""
        frontier = self.total_appended if upto is None else int(upto)
        seq_parts, col_parts = [], {f: [] for f in self.fields}
        with self._all_shard_locks():
            for shard in self._shards:
                seq_parts.append(shard._ordered(SEQ_FIELD))
                for f in self.fields:
                    col_parts[f].append(shard._ordered(f))
        seqs = np.concatenate(seq_parts) if seq_parts else \
            np.zeros(0, np.float64)
        cols = {f: np.concatenate(v) if v else np.zeros(0, np.float64)
                for f, v in col_parts.items()}
        keep = seqs < frontier
        if not keep.all():
            seqs = seqs[keep]
            cols = {f: v[keep] for f, v in cols.items()}
        order = np.argsort(seqs, kind="stable")
        return seqs[order], {f: v[order] for f, v in cols.items()}

    def _gather_range(self, s: int, e: int
                      ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Rows with global seq in [s, e), merged in seq order."""
        return self._gather_field_range(SEQ_FIELD, float(s), float(e))

    def _gather_field_range(self, field: str, lo: float, hi: float
                            ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Rows whose ``field`` value lies in [lo, hi), merged in global
        seq order — each shard contributes only its slice of the range
        (located by ring binary search), so the cost scales with the
        window size rather than the total buffered rows.  Works for any
        field the shard rings are sorted on: the reserved seq column
        always, and the ts field of an event-time stream (seqs are
        assigned in ts order at flush).  Caller holds the coordinator
        lock; all shard locks are held across the sweep, so the slices
        are one point-in-time cut."""
        seq_parts, col_parts = [], {f: [] for f in self.fields}
        with self._all_shard_locks():
            for shard in self._shards:
                a, b = shard._seq_bounds_locked(field, float(lo),
                                                float(hi))
                if b <= a:
                    continue
                idx = (shard._pos(0) + np.arange(a, b)) % shard.capacity
                seq_parts.append(shard._cols[SEQ_FIELD][idx])
                for f in self.fields:
                    col_parts[f].append(shard._cols[f][idx])
        if not seq_parts:
            return np.zeros(0, np.float64), {f: np.zeros(0, np.float64)
                                             for f in self.fields}
        seqs = np.concatenate(seq_parts)
        order = np.argsort(seqs, kind="stable")
        return seqs[order], {f: np.concatenate(v)[order]
                             for f, v in col_parts.items()}

    def snapshot(self) -> dm.Table:
        with self._lock:
            seqs, cols = self._gather()
            out = {"seq": jnp.asarray(seqs.astype(np.int64))}
            for f in self.fields:
                out[f] = jnp.asarray(cols[f])
            return dm.Table(out)

    def window(self, size: int,
               slide: Optional[int] = None) -> dm.ArrayObject:
        """Tumbling/sliding window over the logical seq space; gathered
        values are bit-identical to the unsharded stream's window."""
        assert size > 0
        with self._lock:
            total = self.total_appended
            if slide is None:
                k = total // size - 1
                if k < 0:
                    raise StreamException(
                        f"stream {self.name!r}: no complete window of "
                        f"size {size} yet ({total} rows)")
                s = k * size
                seqs, cols = self._gather_range(s, s + size)
                if seqs.shape[0] != size:
                    raise StreamException(
                        f"stream {self.name!r}: window [{s},{s + size}) "
                        f"already evicted (shards retain "
                        f"{seqs.shape[0]}/{size} rows)")
                attrs = {f: jnp.asarray(cols[f])
                         for f in self.fields}
                return dm.ArrayObject(attrs, ("tick",))
            assert slide > 0
            # gather against the same frontier snapshot ``total`` — a
            # block committing mid-call must not skew the suffix math
            seqs, cols = self._gather(upto=total)
            # the contiguous suffix of the seq space still fully buffered
            contiguous = np.nonzero(
                seqs != np.arange(total - seqs.shape[0], total))[0]
            a = int(contiguous[-1]) + 1 if contiguous.size else 0
            count = seqs.shape[0] - a
            if count < size:
                raise StreamException(
                    f"stream {self.name!r}: {count} contiguous rows < "
                    f"window size {size}")
            starts = np.arange(0, count - size + 1, slide)
            attrs = {}
            for f in self.fields:
                buf = cols[f][a:]
                attrs[f] = jnp.asarray(
                    np.stack([buf[s0:s0 + size] for s0 in starts]))
            return dm.ArrayObject(attrs, ("window", "tick"))

    def window_aggregate(self, size: int, fn: str, field: str) -> float:
        """Combine per-shard partial aggregates over the latest complete
        tumbling window — no gather, no row materialization.  Round-robin
        shards locate their slice arithmetically (O(1) for count/sum/avg
        via each shard's cumulative ring); key-hashed shards locate it by
        binary search on their seq column.  Memoized per window index."""
        with self._lock:
            def compute(s: int, e: int) -> float:
                partials: List[Tuple[float, int]] = []   # (value, rows)
                with self._all_shard_locks():   # point-in-time cut
                    for shard in self._shards:
                        partials.append(self._shard_partial(
                            shard, fn, field, s, e))
                rows = sum(c for _, c in partials)
                if rows != size:
                    raise StreamException(
                        f"stream {self.name!r}: window [{s},{e}) already "
                        f"evicted (shards retain {rows}/{size} rows)")
                if fn == "count":
                    return float(size)
                if fn in ("sum", "avg"):
                    value = sum(v for v, c in partials if c)
                    return value / size if fn == "avg" else value
                if fn == "min":
                    return min(v for v, c in partials if c)
                return max(v for v, c in partials if c)

            return _memoized_window_aggregate(self, size, fn, field,
                                              compute)

    def _shard_partial(self, shard: Stream, fn: str, field: str,
                       s: int, e: int) -> Tuple[float, int]:
        """One shard's (partial value, row count) for global seqs [s, e).
        Shard rings are seq-sorted (blocks publish in reservation
        order), so the slice bounds come from an O(log n) ring binary
        search.  Caller holds the shard's lock (via
        ``_all_shard_locks``: the partials form one cut)."""
        a_off, b_off = shard._seq_bounds_locked(SEQ_FIELD, float(s),
                                                float(e))
        if b_off <= a_off:
            return 0.0, 0
        count = b_off - a_off
        if fn in ("sum", "avg"):
            return shard._range_sum_locked(field, a_off, b_off), count
        if fn == "count":
            return float(count), count
        idxs = (shard._pos(0) + np.arange(a_off, b_off)) \
            % shard.capacity
        sl = shard._cols[field][idxs]
        return float(sl.min() if fn == "min" else sl.max()), count

    # -- rate & stats ---------------------------------------------------------
    def rate(self) -> float:
        # concurrent producers append rate samples outside the
        # coordinator lock, so the history has its own tiny lock
        with self._rate_lock:
            return _recent_rate(self._append_times)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = {
                "rows": self.num_rows,
                "capacity": sum(s.capacity for s in self._shards),
                "appended": self.total_appended,
                "dropped": self.total_dropped,
                "ingest_concurrency": self.ingest_concurrency(),
                "shards": self.shard_stats(),
            }
            if self.ts_field is not None:
                out.update(_event_time_stats(self))
                if self.shard_key is not None:
                    # per-shard watermark views: the stream watermark is
                    # their minimum (over shards that have data)
                    out["shard_watermarks"] = {
                        i: (None if t == float("-inf")
                            else t - self.max_delay)
                        for i, t in enumerate(self._shard_max_ts)}
            return out

    def shard_stats(self) -> Dict[int, Dict[str, Any]]:
        """Per-shard ingest/drop health (the Monitor's rebalance signal)."""
        with self._lock:
            out = {}
            for i, (ename, shard) in enumerate(
                    zip(self._engines, self._shards)):
                st = shard.stats()
                st["engine"] = ename
                st["rows_per_second"] = round(shard.rate(), 1)
                out[i] = st
            return out

    # -- live shard migration --------------------------------------------------
    def migrate_shard(self, idx: int, migrator, engines: Dict[str, Any],
                      to_engine: str):
        """Move shard ``idx``'s live ring buffer to another StreamEngine
        through the Migrator's ``stream`` route.  The coordinator lock
        keeps standing queries from observing a half-moved shard, and
        the shard's ordered committer is **paused** across the move:
        every seq block reserved before the pause drains into the old
        ring first (it travels with the exported state), blocks
        reserved during the move wait and then publish into the new
        ring — in-flight reservations are carried, never lost.  Seq
        watermark and drop counters travel with the state (the Migrator
        keeps the catalog's placement truthful)."""
        from repro.core.migrator import MigrationParams
        with trace.span("migrator/shard_move", stream=self.name,
                        shard=idx, dst=to_engine), self._lock:
            if not 0 <= idx < len(self._shards):
                raise ValueError(
                    f"{self.name!r} has no shard {idx} "
                    f"(0..{len(self._shards) - 1})")
            if to_engine not in engines:
                raise ValueError(
                    f"migration target engine {to_engine!r} does not "
                    f"exist (shard {idx} of {self.name!r} stays on "
                    f"{self._engines[idx]})")
            src_name = self._engines[idx]
            if to_engine == src_name:
                raise ValueError(
                    f"shard {idx} of {self.name!r} already on {to_engine}")
            obj_name = self.shard_name(idx)
            committer = self._committers[idx]
            committer.pause()        # drain in-flight blocks, hold later
            try:
                result = migrator.migrate(
                    engines[src_name], obj_name, engines[to_engine],
                    obj_name, MigrationParams(method="stream"))
                self._shards[idx] = engines[to_engine].get(obj_name)
                self._engines[idx] = to_engine
            finally:
                committer.resume()   # held blocks publish to the new ring
            self.migrations += 1
            # the destination now participates: it must resolve the
            # logical name too (shard-transparent reads, planner pin)
            if not engines[to_engine].has(self.name):
                engines[to_engine].put(self.name, self)
            return result

    # -- durability checkpoint / state export ----------------------------------
    def _export_locked(self) -> Dict[str, Any]:
        """Full coordinator + shard state (caller holds the coordinator
        lock and has settled every shard committer — see
        ``_checkpoint_snapshot``)."""
        with self._all_shard_locks():
            shard_states = [s._export_locked() for s in self._shards]
        return {
            "kind": "sharded", "name": self.name, "fields": self.fields,
            "shard_key": self.shard_key, "block_rows": self.block_rows,
            "ts_field": self.ts_field, "max_delay": self.max_delay,
            "idle_timeout": self.idle_timeout,
            "engines": list(self._engines),
            "shards": shard_states,
            "total_appended": self.total_appended,
            "blocks_reserved": self.blocks_reserved,
            "rows_reserved": self.rows_reserved,
            "blocks_abandoned": self.blocks_abandoned,
            "watermark": self.watermark,
            "max_ts_seen": self.max_ts_seen,
            "min_ts_seen": self.min_ts_seen,
            "total_late": self.total_late,
            "pending": [{f: v.copy() for f, v in b.items()}
                        for b in self._pending],
            "pending_arrivals": [a.copy()
                                 for a in self._pending_arrivals],
            "arrivals": self._arrivals,
            "shard_max_ts": list(self._shard_max_ts),
            "migrations": self.migrations,
        }

    def export_state(self) -> Dict[str, Any]:
        """Deep-copy the full live state (coordinator + every shard
        ring) — the sharded analog of ``Stream.export_state``, used by
        the durability checkpoint.  Reservations are frozen and every
        shard lane drained first, so the exported frontier equals the
        reservation counter (no in-flight blocks are lost)."""
        state, _ = self._checkpoint_snapshot(lambda: None)
        return state

    def _checkpoint_snapshot(self, capture):
        """Export state at an instant where every shard ring, the
        committed frontier, and the write-behind log agree, running
        ``capture()`` at that instant (see ``Stream`` counterpart).

        Event-time sharded streams do all ring writes and logging under
        the coordinator lock, so that lock is the coherence point.
        Seq-ordered ones freeze reservations, drain every shard lane
        (logs are written inside the lanes' ordered sections), then
        wait for the committed frontier to reach the reservation
        counter — block completion runs on producer threads right
        after their last lane commit, so this wait is bounded."""
        if self.ts_field is not None:
            with self._lock:
                return self._export_locked(), capture()
        with self._reserve_lock:
            for committer in self._committers:
                committer.pause()
            try:
                deadline = time.monotonic() + 60.0
                with self._frontier:
                    while self.total_appended < self.reserved:
                        if not self._frontier.wait(
                                timeout=deadline - time.monotonic()):
                            raise StreamException(
                                f"stream {self.name!r}: checkpoint "
                                f"frontier settle timed out at "
                                f"{self.total_appended}/{self.reserved}")
                        self._reap_stalled_locked()
                with self._lock:
                    state = self._export_locked()
                return state, capture()
            finally:
                for committer in self._committers:
                    committer.resume()

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> "ShardedStream":
        shards = [Stream.from_state(s) for s in state["shards"]]
        stream = cls(state["name"], state["fields"],
                     list(zip(state["engines"], shards)),
                     shard_key=state.get("shard_key"),
                     block_rows=state.get("block_rows", 64),
                     ts_field=state.get("ts_field"),
                     max_delay=state.get("max_delay", 0.0),
                     idle_timeout=state.get("idle_timeout"))
        stream.total_appended = int(state["total_appended"])
        # in-flight reservations at export time were drained into the
        # frontier, so the restored reservation counter IS the frontier
        stream.reserved = stream.total_appended
        stream.blocks_reserved = int(state.get("blocks_reserved", 0))
        stream.rows_reserved = int(state.get("rows_reserved", 0))
        stream.blocks_abandoned = int(state.get("blocks_abandoned", 0))
        stream.watermark = float(state.get("watermark", float("-inf")))
        stream.max_ts_seen = float(state.get("max_ts_seen",
                                             float("-inf")))
        stream.min_ts_seen = float(state.get("min_ts_seen",
                                             float("inf")))
        stream.total_late = int(state.get("total_late", 0))
        stream._pending = [{f: np.asarray(v, np.float64)
                            for f, v in b.items()}
                           for b in state.get("pending", [])]
        stream._pending_arrivals = [
            np.asarray(a, np.int64)
            for a in state.get("pending_arrivals", [])]
        stream._pending_rows = sum(
            b[stream.fields[0]].shape[0] for b in stream._pending)
        stream._arrivals = int(state.get("arrivals", 0))
        stream._shard_max_ts = [float(t) for t in
                                state.get("shard_max_ts",
                                          stream._shard_max_ts)]
        stream.migrations = int(state.get("migrations", 0))
        return stream

    def clone(self, name: Optional[str] = None,
              state: Optional[Dict[str, Any]] = None) -> "ShardedStream":
        """A detached deep copy of the whole sharded state (handle +
        every shard ring), optionally renamed — the sharded analog of
        ``Stream.clone``.  Shard rings are renamed to match so a
        replica's diagnostics never alias the primary's."""
        state = dict(self.export_state() if state is None else state)
        if name is not None:
            state["name"] = name
            renamed = []
            for i, shard_state in enumerate(state["shards"]):
                shard_state = dict(shard_state)
                shard_state["name"] = f"{name}@shard{i}"
                renamed.append(shard_state)
            state["shards"] = renamed
        return ShardedStream.from_state(state)

    def close(self) -> None:
        """Shut down the scatter fan-out pool.  Optional: a dropped
        handle's pool is reclaimed when the executor is garbage
        collected (its workers exit via the stdlib's weakref hook);
        call this for deterministic teardown in tests/benchmarks."""
        with self._lock:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
                self._pool = None


class StreamEngine(Engine):
    """S-Store analog: holds named ``Stream`` objects for the streaming
    island.  Materialized window views (plain Tables/ArrayObjects) pass
    through the inherited binary/staged import/export paths, so the
    Migrator can cast them into the other islands unchanged."""
    kind = "stream_store"
    islands = ("streaming",)

    def create_stream(self, name: str, fields: Sequence[str],
                      capacity: int = 4096) -> Stream:
        stream = Stream(name, fields, capacity)
        self.put(name, stream)
        return stream

    def streams(self) -> Dict[str, Any]:
        """Streams this engine serves: plain ring buffers, shard rings
        (``name@shardN``), and sharded-stream coordinator handles."""
        return {n: o for n, o in self._objects.items()
                if isinstance(o, (Stream, ShardedStream))}


ENGINE_KINDS["stream_store"] = StreamEngine
