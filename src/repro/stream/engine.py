"""StreamEngine — the S-Store analog of the polystore (paper §III lists a
streaming island among BigDAWG's islands; the v0.1 release ships without
one, this module adds it).

A ``Stream`` is an append-only, bounded ring buffer of rows over a fixed
set of float64 fields.  When the buffer is full the oldest rows are
overwritten (drop-oldest backpressure) and counted in ``total_dropped``.
Window views over the buffer materialize as island data-model objects:

  snapshot  — every buffered row, oldest first, as a ``dm.Table``
              (with a ``seq`` column of global sequence numbers)
  tumbling  — the most recent *complete* seq-aligned window of ``size``
              rows as a 1-D ``dm.ArrayObject`` (dims ``("tick",)``)
  sliding   — windows of ``size`` rows every ``slide`` rows over the
              buffer as a 2-D ``dm.ArrayObject`` (dims ``("window",
              "tick")``)

Materialized windows then ride the existing Migrator casts into the array
island (binary) or the relational island (staged) — see
``core/api.default_deployment``.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm
from repro.core.engines import ENGINE_KINDS, Engine
from repro.core.executor import DataUnavailableException


class StreamException(DataUnavailableException):
    """Data-dependent streaming error (window not complete / evicted,
    schema mismatch on append).  Subclasses the core's transient marker
    so cached plans survive it."""


class Stream:
    """Append-only bounded ring buffer of rows (fixed float64 fields)."""

    def __init__(self, name: str, fields: Sequence[str],
                 capacity: int = 4096) -> None:
        assert fields, "a stream needs at least one field"
        assert capacity > 0, "capacity must be positive"
        self.name = name
        self.fields: Tuple[str, ...] = tuple(fields)
        self.capacity = int(capacity)
        self._cols = {f: np.zeros(self.capacity, np.float64)
                      for f in self.fields}
        self._next = 0                    # ring write position
        self._count = 0                   # valid rows in the buffer
        self.total_appended = 0           # global sequence high-water mark
        self.total_dropped = 0            # rows overwritten before read
        # (wall_time, rows) of recent appends, for rate()
        self._append_times: "collections.deque[Tuple[float, int]]" = \
            collections.deque(maxlen=64)
        self._lock = threading.Lock()

    # -- ingest ---------------------------------------------------------------
    def append(self, rows: Dict[str, Iterable[float]]) -> Dict[str, int]:
        """Append a batch of rows (column dict); returns counts.

        Rows beyond ``capacity`` overwrite the oldest buffered rows; the
        overwritten count is the batch's ``dropped`` (backpressure is
        drop-oldest, never blocking the producer).
        """
        if set(rows) != set(self.fields):
            raise StreamException(
                f"stream {self.name!r} fields {self.fields} != "
                f"appended fields {tuple(rows)}")
        cols = {f: np.asarray(rows[f], np.float64).reshape(-1)
                for f in self.fields}
        n = cols[self.fields[0]].shape[0]
        if any(v.shape[0] != n for v in cols.values()):
            raise StreamException("ragged append batch")
        with self._lock:
            dropped = max(0, self._count + n - self.capacity)
            for f in self.fields:
                src = cols[f][-self.capacity:]        # keep only the tail
                m = src.shape[0]
                end = self._next + m
                if end <= self.capacity:
                    self._cols[f][self._next:end] = src
                else:
                    first = self.capacity - self._next
                    self._cols[f][self._next:] = src[:first]
                    self._cols[f][:end % self.capacity] = src[first:]
            self._next = (self._next + min(n, self.capacity)) % self.capacity
            self._count = min(self.capacity, self._count + n)
            self.total_appended += n
            self.total_dropped += dropped
            self._append_times.append((time.monotonic(), n))
            return {"appended": n, "dropped": dropped,
                    "rows": self._count}

    # -- views ----------------------------------------------------------------
    def _ordered(self, field: str) -> np.ndarray:
        """Buffered values oldest-first (caller holds the lock)."""
        start = (self._next - self._count) % self.capacity
        idx = (start + np.arange(self._count)) % self.capacity
        return self._cols[field][idx]

    def snapshot(self) -> dm.Table:
        with self._lock:
            first_seq = self.total_appended - self._count
            cols = {"seq": jnp.asarray(
                first_seq + np.arange(self._count))}
            for f in self.fields:
                cols[f] = jnp.asarray(self._ordered(f))
            return dm.Table(cols)

    def window(self, size: int,
               slide: Optional[int] = None) -> dm.ArrayObject:
        """Tumbling (``slide`` is None) or sliding window view."""
        assert size > 0
        with self._lock:
            first_seq = self.total_appended - self._count
            if slide is None:
                # most recent complete seq-aligned tumbling window
                k = self.total_appended // size - 1
                if k < 0:
                    raise StreamException(
                        f"stream {self.name!r}: no complete window of "
                        f"size {size} yet ({self.total_appended} rows)")
                s = k * size
                if s < first_seq:
                    raise StreamException(
                        f"stream {self.name!r}: window [{s},{s + size}) "
                        f"already evicted (buffer starts at {first_seq})")
                off = s - first_seq
                attrs = {f: jnp.asarray(self._ordered(f)[off:off + size])
                         for f in self.fields}
                return dm.ArrayObject(attrs, ("tick",))
            assert slide > 0
            if self._count < size:
                raise StreamException(
                    f"stream {self.name!r}: {self._count} rows < window "
                    f"size {size}")
            starts = np.arange(0, self._count - size + 1, slide)
            attrs = {}
            for f in self.fields:
                buf = self._ordered(f)
                attrs[f] = jnp.asarray(
                    np.stack([buf[s:s + size] for s in starts]))
            return dm.ArrayObject(attrs, ("window", "tick"))

    def rate(self) -> float:
        """Recent ingest rate in rows/second (0.0 with <2 appends)."""
        with self._lock:
            if len(self._append_times) < 2:
                return 0.0
            t0, _ = self._append_times[0]
            t1, _ = self._append_times[-1]
            if t1 <= t0:
                return 0.0
            rows = sum(n for _, n in list(self._append_times)[1:])
            return rows / (t1 - t0)

    # -- island data-model plumbing ------------------------------------------
    @property
    def num_rows(self) -> int:
        with self._lock:
            return self._count

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self._cols.values()))

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"rows": self._count, "capacity": self.capacity,
                    "appended": self.total_appended,
                    "dropped": self.total_dropped}


class StreamEngine(Engine):
    """S-Store analog: holds named ``Stream`` objects for the streaming
    island.  Materialized window views (plain Tables/ArrayObjects) pass
    through the inherited binary/staged import/export paths, so the
    Migrator can cast them into the other islands unchanged."""
    kind = "stream_store"
    islands = ("streaming",)

    def create_stream(self, name: str, fields: Sequence[str],
                      capacity: int = 4096) -> Stream:
        stream = Stream(name, fields, capacity)
        self.put(name, stream)
        return stream

    def streams(self) -> Dict[str, Stream]:
        return {n: o for n, o in self._objects.items()
                if isinstance(o, Stream)}


ENGINE_KINDS["stream_store"] = StreamEngine
