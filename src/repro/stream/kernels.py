"""Pallas kernels for the compiled streaming path.

Two kernels back the device lowerings in ``stream/compile.py``, written
in the house idiom (``kernels/mamba_scan``: fori_loop carry over a VMEM
block, ``@pl.when`` guards; ``kernels/flash_attention``: per-block
operand narrowing before the inner scan):

  * ``window_minmax`` — the rolling-aggregate scan: per-window min/max
    over stacked window rows ``(W, size)``.  min/max are exactly
    associative, so any evaluation order is bit-identical to numpy's —
    the only rolling aggregates that may leave the cumulative-ring host
    path without breaking the jitted ≡ interpreted invariant (sum/avg
    are order-sensitive and stay on the ring; see compile.py).
  * ``join_bounds`` — the banded interval-join bound search: for every
    left timestamp, the ``[lo, hi)`` slice of the *sorted* right
    timestamps within ``tol``.  A branchless bisection (fori_loop over
    ceil(log2 n) halvings), bit-identical to ``searchsorted``
    left/right because both resolve ties the same way on exact float64
    comparisons.

Each kernel ships with a plain-jnp reference (``*_ref``) used as the
default lowering; the Pallas path is opt-in via ``REPRO_STREAM_PALLAS=1``
because on CPU the kernels run in interpret mode (Mosaic is TPU-only),
which is correct but slower than XLA's fused jnp — the flag exists so
TPU hosts get the real kernels and CI can parity-test both paths.
Gates cleanly when jax is absent (``AVAILABLE`` False).
"""
from __future__ import annotations

import functools
import os

try:                                         # gate: jax may be absent
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    AVAILABLE = True
except Exception:                            # noqa: BLE001 — optional dep
    jax = jnp = pl = None                    # type: ignore
    AVAILABLE = False

PALLAS_ENV = "REPRO_STREAM_PALLAS"
_WINDOW_BLOCK = 8                            # windows per grid step


def enabled() -> bool:
    """True when the Pallas lowerings should replace the jnp refs."""
    return AVAILABLE and bool(os.environ.get(PALLAS_ENV, "").strip())


def _steps(n: int) -> int:
    """Bisection iterations that pin [lo, hi) to width <= 1 from width
    n: ceil(log2(n)) with a floor of 1."""
    s = 1
    while (1 << s) < n:
        s += 1
    return s


# -- rolling-aggregate scan -------------------------------------------------
def window_minmax_ref(windows, is_max: bool):
    """(W, size) stacked windows -> (W,) per-window min or max."""
    return jnp.max(windows, axis=1) if is_max else jnp.min(windows, axis=1)


def _minmax_kernel(vals_ref, out_ref, *, size: int, is_max: bool):
    block = vals_ref[...]                     # (BW, size) in VMEM
    acc = block[:, 0]

    def step(i, acc):
        v = jax.lax.dynamic_slice_in_dim(block, i, 1, axis=1)[:, 0]
        return jnp.maximum(acc, v) if is_max else jnp.minimum(acc, v)

    out_ref[...] = jax.lax.fori_loop(1, size, step, acc)


@functools.partial(jax.jit if AVAILABLE else lambda f, **k: f,
                   static_argnames=("is_max", "interpret"))
def window_minmax(windows, is_max: bool, interpret: bool = True):
    """Pallas per-window min/max scan; pad W to the block multiple and
    slice the result — padded rows reduce over real dtype values and
    are discarded."""
    w, size = windows.shape
    bw = _WINDOW_BLOCK
    wpad = -(-w // bw) * bw
    padded = jnp.zeros((wpad, size), windows.dtype).at[:w].set(windows)
    out = pl.pallas_call(
        functools.partial(_minmax_kernel, size=size, is_max=is_max),
        grid=(wpad // bw,),
        in_specs=[pl.BlockSpec((bw, size), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((wpad,), windows.dtype),
        interpret=interpret,
    )(padded)
    return out[:w]


# -- banded join bound search -----------------------------------------------
def join_bounds_ref(lt, rs, tol):
    """searchsorted bounds of ``[lt - tol, lt + tol]`` in sorted rs."""
    lo = jnp.searchsorted(rs, lt - tol, side="left")
    hi = jnp.searchsorted(rs, lt + tol, side="right")
    return lo, hi


def _bounds_kernel(lt_ref, rs_ref, tol_ref, lo_ref, hi_ref,
                   *, steps: int):
    lt = lt_ref[...]                          # (BL,) left block
    rs = rs_ref[...]                          # (R,) full sorted right
    tol = tol_ref[0]
    n = rs.shape[0]

    def bisect(target, right_side):
        # branchless searchsorted: ties go right iff right_side
        lo = jnp.zeros(target.shape, jnp.int32)
        hi = jnp.full(target.shape, n, jnp.int32)

        def step(_, carry):
            lo, hi = carry
            mid = (lo + hi) // 2
            v = rs[jnp.minimum(mid, n - 1)]
            go = jnp.where(right_side, v <= target, v < target)
            go = jnp.logical_and(go, mid < hi)  # guard empty ranges
            return (jnp.where(go, mid + 1, lo), jnp.where(go, hi, mid))

        lo, hi = jax.lax.fori_loop(0, steps, step, (lo, hi))
        return lo

    lo_ref[...] = bisect(lt - tol, False)
    hi_ref[...] = bisect(lt + tol, True)


@functools.partial(jax.jit if AVAILABLE else lambda f, **k: f,
                   static_argnames=("interpret",))
def join_bounds(lt, rs, tol, interpret: bool = True):
    """Pallas bound search: (lo, hi) int32 per left timestamp.  Left
    rows pad to the block multiple (pad searches are discarded); the
    sorted right side is one VMEM-resident block per grid step, the
    flash-attention-style narrowed operand."""
    nl = lt.shape[0]
    bl = 128
    lpad = -(-nl // bl) * bl
    lt_p = jnp.zeros((lpad,), lt.dtype).at[:nl].set(lt)
    tol_arr = jnp.asarray([tol], lt.dtype)
    steps = _steps(max(int(rs.shape[0]), 2)) + 1
    lo, hi = pl.pallas_call(
        functools.partial(_bounds_kernel, steps=steps),
        grid=(lpad // bl,),
        in_specs=[pl.BlockSpec((bl,), lambda i: (i,)),
                  pl.BlockSpec(rs.shape, lambda i: (0,)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((bl,), lambda i: (i,)),
                   pl.BlockSpec((bl,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((lpad,), jnp.int32),
                   jax.ShapeDtypeStruct((lpad,), jnp.int32)],
        interpret=interpret,
    )(lt_p, rs, tol_arr)
    return lo[:nl], hi[:nl]
