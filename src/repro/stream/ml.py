"""ML inference island (``bdml``): score stream windows through the model
registry.

The island has one operation:

  infer(window(S, n), models.M[, field=f])    -> dm.Table
  infer(ewindow(S, span), models.M[, field=f])
  infer(W, models.M[, field=f])               (W: a window view already
                                              on this engine, e.g.
                                              bdcast-delivered)

Each window's chosen field is quantized into token ids (deterministic
per-window min/max binning over the float64 row values — the same rows
always produce the same tokens, on any shard layout or replay) and run
through ``registry.forward`` on the model's reduced config; the score is
the mean next-token NLL in float32 — an anomaly signal: windows the
model finds unlikely score high.  The result is a relational Table with
one row per window (``window``/``rows``/``score``), so scores ride the
existing staged casts into any island.

Bit-identity contract (the house invariant):

  * ``infer`` over a gathered window ≡ a direct ``registry.forward`` on
    the same rows, **bitwise** — the forward is jit-compiled, and on the
    reduced configs jit ≡ eager is exact; the NLL is computed eagerly in
    f32 from the returned logits, so a test can rebuild the score from
    ``registry.forward`` alone and demand ``err == 0.0``.
  * sharded ≡ unsharded and replayed ≡ original: window gathers are
    bit-identical across shard layouts (stream island contract), params
    come from a fixed PRNG seed cached per (arch, seed), and every
    window executes at the same canonical ``(1, rows)`` batch shape, so
    a score never depends on what else shares its wave (the same
    batch-composition independence the dropless MoE path guarantees).

Execution rides the serve tier's wave model (``TickWaveScheduler``): all
standing ``infer`` queries that run within one StreamRuntime tick join a
single wave — N standing queries cost one wave per tick, sharing the
params/jit caches — with ``ml/wave`` / ``ml/score`` spans and
``repro_ml_*`` metrics.  ``StreamRuntime.tick`` mirrors ``stats()`` into
``Monitor.observe_ml`` so ``admin.status()["ml"]`` tracks it live.

Model handles are registered via ``BigDawg.register_model`` on an
``MLEngine`` (``bd.ensure_ml_engines``); the Planner pins ``infer``
reads to the model's home engine.  Errors about not-yet-complete
windows propagate as ``StreamException`` (transient: standing queries
and cached plans survive them); a missing jax is reported the same
transient way and counted in ``stats()["fallbacks"]``.
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import datamodel as dm
from repro.core.engines import Engine
from repro.obs import metrics, trace
from repro.stream.engine import (SEQ_FIELD, ShardedStream, Stream,
                                 StreamEngine, StreamException)

try:  # pragma: no cover - exercised by monkeypatching JAX_AVAILABLE
    import jax
    import jax.numpy as jnp
    from repro.models import registry
    from repro.serve.engine import TickWaveScheduler
    from repro.sharding import logical as _logical
    JAX_AVAILABLE = True
except Exception:  # noqa: BLE001
    jax = jnp = registry = _logical = None
    TickWaveScheduler = None
    JAX_AVAILABLE = False


class MLException(StreamException):
    """ml-island failure; subclasses the streaming island's transient
    marker so standing queries and cached plans survive it."""


# registry architectures behind the island's short model aliases (there
# is no pure-mamba arch in the pool; jamba is the mamba-hybrid)
ALIASES = {"lm": "qwen2-1.5b", "moe": "olmoe-1b-7b",
           "rwkv6": "rwkv6-7b", "mamba": "jamba-v0.1-52b"}


def resolve_arch(name: str) -> str:
    if name in ALIASES:
        return ALIASES[name]
    if registry is not None and name in registry.ARCH_NAMES:
        return name
    if registry is None and name:  # jax absent: defer validation
        return name
    raise MLException(
        f"unknown model {name!r}: aliases {sorted(ALIASES)} or a "
        f"registry arch name")


@dataclasses.dataclass
class MLModel:
    """Catalog handle for a registered model.  Dotted ``name`` on
    purpose: the Planner's signature extractor treats dotted tokens as
    referenced objects, which is what pins infer reads to this handle's
    home engine."""
    name: str                      # catalog object name, e.g. models.moe
    arch: str                      # registry architecture
    seed: int = 0                  # PRNG seed for the cached params
    home_engine: str = "mlhost0"

    def nbytes(self) -> int:
        return 0                   # the handle itself holds no tensors


class MLEngine(Engine):
    """Model-serving engine of the ml island.  Stores ``MLModel``
    handles (plus any bdcast-delivered window views); keeps
    back-references to the deployment so ``infer`` can resolve inline
    window expressions against the stream's home StreamEngine and join
    the current tick's wave."""
    kind = "mlserve"
    islands: Tuple[str, ...] = ("ml",)

    def __init__(self, name: str, runtime=None, engines=None,
                 mesh=None, rules=None) -> None:
        super().__init__(name, mesh, rules)
        self.runtime = runtime            # StreamRuntime (tick counter)
        self.deployment_engines = engines  # name -> Engine


@dataclasses.dataclass
class _Loaded:
    cfg: Any
    params: Any
    forward: Any                   # jitted (params, tokens) -> logits


_LOADED: Dict[Tuple[str, int], _Loaded] = {}
_WAVE = TickWaveScheduler() if TickWaveScheduler is not None else None
_STATS: Dict[str, int] = {
    "models_loaded": 0, "params_cache_hits": 0, "infer_executions": 0,
    "windows_scored": 0, "fallbacks": 0}


def stats() -> Dict[str, Any]:
    """Process-wide ml-island counters (the Monitor/admin block)."""
    out: Dict[str, Any] = {"jax_available": JAX_AVAILABLE, **_STATS}
    out["waves"] = _WAVE.waves if _WAVE is not None else 0
    out["wave_submissions"] = (_WAVE.submissions
                               if _WAVE is not None else 0)
    return out


def load_model(arch: str, seed: int = 0) -> _Loaded:
    """The per-(arch, seed) params + jitted-forward cache.  Params are
    derived from a fixed PRNGKey, so every deployment that registers
    the same model scores with bit-identical weights."""
    key = (arch, seed)
    if key in _LOADED:
        _STATS["params_cache_hits"] += 1
        return _LOADED[key]
    cfg = registry.get_config(arch, reduced=True)
    params = _logical.init_params(jax.random.PRNGKey(seed),
                                  registry.param_specs(cfg))
    fwd = jax.jit(lambda p, toks: registry.forward(
        p, {"tokens": toks}, cfg, None)[0])
    loaded = _Loaded(cfg=cfg, params=params, forward=fwd)
    _LOADED[key] = loaded
    _STATS["models_loaded"] += 1
    metrics.gauge("repro_ml_models_loaded",
                  "(arch, seed) entries in the params cache").set(
        len(_LOADED))
    return loaded


def quantize(values: np.ndarray, vocab: int) -> np.ndarray:
    """Deterministic per-window tokenization: min/max binning of the
    float64 row values into ``vocab`` ids.  A pure function of the row
    values alone — the same rows quantize identically on any shard
    layout, backend or replay."""
    v = np.asarray(values, np.float64).reshape(-1)
    lo, hi = float(v.min()), float(v.max())
    if hi <= lo:
        return np.zeros(v.shape[0], np.int32)
    ids = np.floor((v - lo) / (hi - lo) * (vocab - 1))
    return np.minimum(ids, vocab - 1).astype(np.int32)


def score_tokens(loaded: _Loaded, tokens: np.ndarray):
    """Mean next-token NLL of one window's token ids, float32.  The
    forward runs jitted at the canonical (1, rows) shape; the NLL is
    computed eagerly from the logits — both bitwise-reproducible, so
    rebuilding this from a direct ``registry.forward`` matches exactly."""
    if tokens.shape[0] < 2:
        raise MLException(
            f"window too short to score: {tokens.shape[0]} row(s), "
            f"need >= 2")
    toks = jnp.asarray(tokens[None, :], jnp.int32)
    logits = loaded.forward(loaded.params, toks)
    logp = jax.nn.log_softmax(logits[0, :-1].astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, toks[0, 1:, None], -1)[..., 0]
    return nll.mean()


# ---------------------------------------------------------------------------
# the shim: infer(<window expr | name>, <model>[, field=...])
# ---------------------------------------------------------------------------
_WINDOW_EXPR_RE = re.compile(r"^(window|ewindow)\s*\(\s*([\w\.]+)\s*,",
                             re.IGNORECASE)
_KWARG_RE = re.compile(r"^(\w+)\s*=\s*(.+)$")


def _find_stream_engine(engines: Dict[str, Engine],
                        name: str) -> Optional[StreamEngine]:
    for ename in sorted(engines):
        e = engines[ename]
        if (isinstance(e, StreamEngine) and e.has(name)
                and isinstance(e.get(name), (Stream, ShardedStream))):
            return e
    return None


def _window_values(engine: MLEngine, expr: str,
                   field: Optional[str]) -> Tuple[List[np.ndarray], int]:
    """Evaluate the window argument to a list of per-window float64 row
    vectors (1 for tumbling/ewindow views, N for sliding 2-D views)."""
    m = _WINDOW_EXPR_RE.match(expr)
    if m:
        sname = m.group(2)
        engines = engine.deployment_engines or {}
        home = _find_stream_engine(engines, sname)
        if home is None:
            raise MLException(f"stream {sname!r} not found on any "
                              f"StreamEngine")
        from repro.stream.shim import execute_stream
        view = execute_stream(home, expr)
        ts_field = getattr(home.get(sname), "ts_field", None)
    elif engine.has(expr):
        view = engine.get(expr)
        ts_field = None
    else:
        raise MLException(
            f"infer needs a window(...)/ewindow(...) expression or a "
            f"window object on {engine.name}; got {expr!r}")
    if not isinstance(view, dm.ArrayObject):
        raise MLException(f"infer scores window views (ArrayObject), "
                          f"got {type(view).__name__}")
    if field is None:
        skip = {ts_field, "ts", SEQ_FIELD, "seq"}
        field = next((a for a in view.attrs if a not in skip),
                     next(iter(view.attrs)))
    if field not in view.attrs:
        raise MLException(f"window has no field {field!r} "
                          f"(have {list(view.attrs)})")
    vals = np.asarray(view.attrs[field], np.float64)
    if vals.ndim == 1:
        return [vals], 1
    # sliding windows: dims ("window", "tick") — one score per row
    return [vals[i] for i in range(vals.shape[0])], vals.shape[0]


def _wave_key(engine: MLEngine) -> Tuple[int, int]:
    """All infer executions between two ticks of the same deployment
    share one wave; the tick counter advances before standing queries
    run, so every standing query due on a tick lands in that tick's
    wave."""
    rt = engine.runtime
    return (id(rt), rt.ticks if rt is not None else 0)


def execute_ml(engine: Engine, query: str) -> dm.Table:
    q = query.strip()
    m = re.match(r"^(\w+)\s*\(", q)
    if not m or m.group(1).lower() != "infer":
        raise ValueError(f"unsupported ml op: {q!r}")
    if not isinstance(engine, MLEngine):
        raise MLException(f"ml island queries need an MLEngine, "
                          f"got {engine.name} ({engine.kind})")
    if not JAX_AVAILABLE:
        _STATS["fallbacks"] += 1
        metrics.counter("repro_ml_fallbacks_total",
                        "infer refused: jax unavailable").inc()
        raise MLException("ml island needs jax for registry.forward; "
                          "jax is unavailable in this process")
    from repro.stream.shim import _balanced, _split_args
    inner, _ = _balanced(q[m.end() - 1:])
    args = _split_args(inner)
    if len(args) < 2:
        raise MLException(f"infer needs (window, model), got {q!r}")
    kwargs: Dict[str, str] = {}
    pos = []
    for a in args:
        kw = _KWARG_RE.match(a)
        if kw and kw.group(1).lower() == "field":
            kwargs["field"] = kw.group(2).strip().strip("'\"")
        else:
            pos.append(a)
    window_expr, model_name = pos[0], pos[1].strip()
    if not engine.has(model_name):
        raise MLException(f"model {model_name!r} is not registered on "
                          f"{engine.name} (bd.register_model)")
    handle = engine.get(model_name)
    if not isinstance(handle, MLModel):
        raise MLException(f"{model_name!r} is not an MLModel handle")

    def run() -> dm.Table:
        loaded = load_model(handle.arch, handle.seed)
        windows, n = _window_values(engine, window_expr, kwargs.get("field"))
        scores, rows = [], []
        for i, vals in enumerate(windows):
            t0 = time.perf_counter()
            with trace.span("ml/score", model=handle.arch, window=i,
                            rows=int(vals.shape[0])):
                toks = quantize(vals, loaded.cfg.vocab_size)
                scores.append(score_tokens(loaded, toks))
            metrics.histogram("repro_ml_score_seconds",
                              "per-window forward + NLL time",
                              model=handle.arch).observe(
                time.perf_counter() - t0)
        _STATS["windows_scored"] += n
        metrics.counter("repro_ml_windows_scored_total",
                        "windows scored").inc(n)
        return dm.Table({
            "window": jnp.arange(n, dtype=jnp.int32),
            "rows": jnp.asarray([w.shape[0] for w in windows], jnp.int32),
            "score": jnp.stack(scores).astype(jnp.float32)})

    _STATS["infer_executions"] += 1
    metrics.counter("repro_ml_infer_total",
                    "infer executions (standing + ad hoc)").inc()
    return _WAVE.submit(_wave_key(engine), run)
