"""Streaming island shim (paper §III: one shim per island/engine pair).

The island language is functional, AFL-flavoured, over ``Stream`` objects
stored on a ``StreamEngine``:

  snapshot(S)                    -> dm.Table   (all buffered rows + seq)
  window(S, size)                -> dm.ArrayObject, dims ("tick",)
                                    (latest complete tumbling window)
  window(S, size, slide)         -> dm.ArrayObject, dims ("window","tick")
  aggregate(<expr>, fn(attr))    -> dm.ArrayObject (fn: count/sum/avg/
                                    min/max over a window expression)
  rate(S)                        -> dm.Table   (rows_per_second + counters)
  append(S, '<json rows>')       -> dm.Table   (appended/dropped counts)

A bare stream name evaluates to its snapshot.  Window views are ordinary
island data-model objects, so ``bdcast`` moves them into the array island
(binary route) or the relational island (staged route) unchanged.

All ops are shard-transparent: a ``ShardedStream`` handle (one logical
stream hash-partitioned across several StreamEngines) answers the same
snapshot/window/aggregate/rate calls with seq-ordered gathers, and
``aggregate(window(S, n), fn(attr))`` over a tumbling window takes the
rolling fast path — per-shard partial aggregates combined, memoized per
window index — instead of materializing the window each tick.
"""
from __future__ import annotations

import json
import re
from typing import List

import jax.numpy as jnp

from repro.core import datamodel as dm
from repro.core.engines import Engine
from repro.stream.engine import (_COMBINABLE_AGGS, ShardedStream, Stream,
                                 StreamException)

_AGG_RE = re.compile(r"^(count|sum|avg|min|max)\(\s*(\*|[\w\.]+)\s*\)$",
                     re.IGNORECASE)
# aggregate(window(S, n), fn(attr)) — the rolling/partial-combine shape:
# a tumbling (no slide) window, directly aggregated
_WINDOW_AGG_RE = re.compile(
    r"^window\(\s*([\w\.]+)\s*,\s*(\d+)\s*\)$", re.IGNORECASE)


def _balanced(s: str):
    depth = 0
    for j, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return s[1:j], j + 1
    raise ValueError(f"unbalanced streaming query: {s!r}")


def _split_args(s: str) -> List[str]:
    parts, depth, quote, cur = [], 0, None, []
    for ch in s:
        if quote:
            cur.append(ch)
            if ch == quote:
                quote = None
            continue
        if ch in ("'", '"'):
            quote = ch
            cur.append(ch)
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _get_stream(engine: Engine, name: str):
    obj = engine.get(name.strip())
    if not isinstance(obj, (Stream, ShardedStream)):
        raise StreamException(f"{name!r} is not a stream on {engine.name}")
    return obj


def execute_stream(engine: Engine, query: str):
    """Evaluate one streaming-island expression against ``engine``."""
    q = query.strip()
    m = re.match(r"^(\w+)\s*\(", q)
    if not m:
        # bare stream name -> snapshot (the natural "scan" of a stream)
        return _get_stream(engine, q).snapshot()
    fn = m.group(1).lower()
    body, _ = _balanced(q[m.end() - 1:])
    args = _split_args(body)

    if fn == "snapshot":
        return _get_stream(engine, args[0]).snapshot()
    if fn == "window":
        if len(args) not in (2, 3):
            raise ValueError(f"window needs (stream, size[, slide]): {q!r}")
        stream = _get_stream(engine, args[0])
        size = int(args[1])
        slide = int(args[2]) if len(args) == 3 else None
        return stream.window(size, slide)
    if fn == "rate":
        stream = _get_stream(engine, args[0])
        stats = stream.stats()
        return dm.Table({
            "rows_per_second": jnp.asarray([stream.rate()]),
            "rows": jnp.asarray([float(stats["rows"])]),
            "appended": jnp.asarray([float(stats["appended"])]),
            "dropped": jnp.asarray([float(stats["dropped"])])})
    if fn == "aggregate":
        if len(args) != 2:
            raise ValueError(f"aggregate needs (expr, fn(attr)): {q!r}")
        agg = _AGG_RE.match(args[1].strip())
        if not agg:
            raise ValueError(f"bad streaming aggregate: {args[1]!r}")
        # rolling fast path: a tumbling window aggregated on a real field
        # never materializes the window — O(1) cumulative-ring partials
        # (per shard for sharded streams), memoized per window index
        win = _WINDOW_AGG_RE.match(args[0].strip())
        agg_fn, target = agg.group(1).lower(), agg.group(2)
        if win and agg_fn in _COMBINABLE_AGGS:
            stream = _get_stream(engine, win.group(1))
            if target == "*":
                target = stream.fields[0]
            if target in stream.fields:
                value = stream.window_aggregate(int(win.group(2)),
                                                agg_fn, target)
                return dm.ArrayObject(
                    {f"{agg_fn}_{target}": jnp.asarray([value])}, ("i",))
        value = execute_stream(engine, args[0])
        if isinstance(value, dm.Table):
            value = dm.ArrayObject(
                {n: v for n, v in value.columns.items() if n != "seq"},
                ("tick",))
        target = agg.group(2)
        if target == "*":
            target = next(iter(value.attrs))
        return value.aggregate(agg_fn, target)
    if fn == "append":
        if len(args) != 2:
            raise ValueError(f"append needs (stream, '<json rows>'): {q!r}")
        stream = _get_stream(engine, args[0])
        payload = json.loads(args[1].strip().strip("'\""))
        if isinstance(payload, dict):
            payload = [payload]
        cols = {f: [row[f] for row in payload] for f in stream.fields}
        counts = stream.append(cols)
        return dm.Table({k: jnp.asarray([float(v)])
                         for k, v in counts.items()})
    raise ValueError(f"unsupported streaming operator: {fn}")
