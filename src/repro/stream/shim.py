"""Streaming island shim (paper §III: one shim per island/engine pair).

The island language is functional, AFL-flavoured, over ``Stream`` objects
stored on a ``StreamEngine``:

  snapshot(S)                    -> dm.Table   (all buffered rows + seq)
  window(S, size)                -> dm.ArrayObject, dims ("tick",)
                                    (latest complete tumbling window)
  window(S, size, slide)         -> dm.ArrayObject, dims ("window","tick")
  ewindow(S, span[, slide])      -> dm.ArrayObject, dims ("tick",)
                                    (latest *closed* event-time window —
                                    closed once the low watermark passes
                                    its end; needs ts_field)
  join(W1, W2[, on=ts][, tol=x]) -> dm.Table   (interval join of two
                                    window views: rows paired when
                                    |l.on - r.on| <= tol; columns
                                    prefixed l_/r_ plus dt = r.on-l.on)
  aggregate(<expr>, fn(attr))    -> dm.ArrayObject (fn: count/sum/avg/
                                    min/max over a window expression)
  rate(S)                        -> dm.Table   (rows_per_second + counters)
  ingest(S)                      -> dm.Table   (multi-producer ingest
                                    health: producers open/peak, seq
                                    blocks reserved, in-flight rows,
                                    ordered-commit waits)
  watermark(S)                   -> dm.Table   (low watermark + late/
                                    pending counters; needs ts_field)
  flush(S[, to_ts])              -> dm.Table   (punctuation: force the
                                    watermark forward; needs ts_field)
  append(S, '<json rows>')       -> dm.Table   (appended/dropped counts,
                                    plus late/flushed/pending on event-
                                    time streams)

A bare stream name evaluates to its snapshot.  Window views are ordinary
island data-model objects, so ``bdcast`` moves them into the array island
(binary route) or the relational island (staged route) unchanged — and a
``join`` emits a plain Table, so joined results migrate to any island
over the existing staged casts.

``join`` of two ewindows over ShardedStreams with *co-located* shards
(identical engine placements) takes a partial-join fast path: the left
window is split into per-shard bands and each band joins against only
the right rows within ``tol`` of it, so the work decomposes the way the
data is placed.  The banded result is bit-identical to the full join
(each left row lives in exactly one band and keeps all its matches).

All ops are shard-transparent: a ``ShardedStream`` handle (one logical
stream hash-partitioned across several StreamEngines) answers the same
snapshot/window/aggregate/rate calls with seq-ordered gathers, and
``aggregate(window(S, n), fn(attr))`` over a tumbling window takes the
rolling fast path — per-shard partial aggregates combined, memoized per
window index — instead of materializing the window each tick.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import datamodel as dm
from repro.core.engines import Engine
from repro.obs import metrics
from repro.stream.engine import (_COMBINABLE_AGGS, ShardedStream, Stream,
                                 StreamException)

_AGG_RE = re.compile(r"^(count|sum|avg|min|max)\(\s*(\*|[\w\.]+)\s*\)$",
                     re.IGNORECASE)
# aggregate(window(S, n), fn(attr)) — the rolling/partial-combine shape:
# a tumbling (no slide) window, directly aggregated
_WINDOW_AGG_RE = re.compile(
    r"^window\(\s*([\w\.]+)\s*,\s*(\d+)\s*\)$", re.IGNORECASE)
# join(ewindow(S, ...), ewindow(T, ...)): when both streams are sharded
# with co-located shards, the join takes the banded partial path
_EWINDOW_RE = re.compile(r"^ewindow\(\s*([\w\.]+)\s*,", re.IGNORECASE)
_KWARG_RE = re.compile(r"^(\w+)\s*=\s*(.+)$")

# lifetime counters for the two join paths (tests/benchmarks read these)
JOIN_STATS = {"joins": 0, "partial_joins": 0}


def _join_pairs(lt: np.ndarray, rt: np.ndarray, tol: float
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Index pairs (li, ri) with ``|lt[li] - rt[ri]| <= tol``, ordered by
    left row then right timestamp.  ``rt`` may arrive unsorted (window
    views are event-time-ordered, snapshots seq-ordered); matching runs
    on a sorted copy and indices map back through the sort."""
    order = np.argsort(rt, kind="stable")
    rs = rt[order]
    lo = np.searchsorted(rs, lt - tol, side="left")
    hi = np.searchsorted(rs, lt + tol, side="right")
    counts = hi - lo
    li = np.repeat(np.arange(lt.shape[0]), counts)
    if li.size:
        ri = np.concatenate([np.arange(a, b)
                             for a, b in zip(lo, hi) if b > a])
    else:
        ri = np.zeros(0, np.int64)
    return li, order[ri]


def interval_join(left: dm.ArrayObject, right: dm.ArrayObject,
                  on: str = "ts", tol: float = 0.0,
                  bands: int = 1) -> dm.Table:
    """Interval join of two window views: every pair of rows whose ``on``
    values lie within ``tol`` of each other, as a Table with the left
    window's attrs prefixed ``l_``, the right's ``r_``, plus
    ``dt = r.on - l.on``.  Output rows are ordered by left row, then by
    right timestamp — deterministic, so results are bit-identical across
    shard configurations (gathered windows are).

    ``bands > 1`` is the partial-join decomposition used when the two
    streams' shards are co-located: the left rows split into ``bands``
    contiguous slices, each joined against only the right rows within
    ``tol`` of its span.  Each left row lives in exactly one band and
    keeps all its matches, so the concatenated result is identical to
    the single-band join."""
    la = {f: np.asarray(v, np.float64) for f, v in left.attrs.items()}
    ra = {f: np.asarray(v, np.float64) for f, v in right.attrs.items()}
    if on not in la or on not in ra:
        raise StreamException(
            f"join on={on!r}: both windows need that attribute "
            f"(have {sorted(la)} and {sorted(ra)})")
    tol = float(tol)
    if tol < 0:
        raise StreamException(f"join tol must be >= 0, got {tol}")
    lt, rt = la[on], ra[on]
    bands = max(1, min(int(bands), lt.shape[0] or 1))
    if bands == 1:
        li, ri = _join_pairs(lt, rt, tol)
    else:
        JOIN_STATS["partial_joins"] += 1
        metrics.counter("repro_stream_joins_total",
                        "interval joins executed",
                        kind="partial").inc()
        rorder = np.argsort(rt, kind="stable")
        rs = rt[rorder]
        li_parts, ri_parts = [], []
        edges = np.linspace(0, lt.shape[0], bands + 1).astype(np.int64)
        for a, b in zip(edges[:-1], edges[1:]):
            if b <= a:
                continue
            blt = lt[a:b]
            # only the right rows that can match this band
            rlo = int(np.searchsorted(rs, blt.min() - tol, side="left"))
            rhi = int(np.searchsorted(rs, blt.max() + tol, side="right"))
            bli, bri = _join_pairs(blt, rs[rlo:rhi], tol)
            li_parts.append(bli + a)
            ri_parts.append(rorder[bri + rlo])
        li = np.concatenate(li_parts) if li_parts else \
            np.zeros(0, np.int64)
        ri = np.concatenate(ri_parts) if ri_parts else \
            np.zeros(0, np.int64)
    JOIN_STATS["joins"] += 1
    metrics.counter("repro_stream_joins_total",
                    "interval joins executed", kind="full").inc()
    cols: Dict[str, np.ndarray] = {}
    for f, v in la.items():
        cols[f"l_{f}"] = v[li]
    for f, v in ra.items():
        cols[f"r_{f}"] = v[ri]
    cols["dt"] = ra[on][ri] - la[on][li]
    return dm.Table({k: jnp.asarray(v) for k, v in cols.items()})


def _as_window(value) -> dm.ArrayObject:
    """Coerce a join operand to a 1-D window view: ArrayObjects pass
    through, Tables (snapshots) drop their seq column."""
    if isinstance(value, dm.ArrayObject):
        return value
    if isinstance(value, dm.Table):
        return dm.ArrayObject(
            {n: v for n, v in value.columns.items() if n != "seq"},
            ("tick",))
    raise StreamException(
        f"join operands must be window views, got {type(value).__name__}")


def _balanced(s: str):
    depth = 0
    for j, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return s[1:j], j + 1
    raise ValueError(f"unbalanced streaming query: {s!r}")


def _split_args(s: str) -> List[str]:
    parts, depth, quote, cur = [], 0, None, []
    for ch in s:
        if quote:
            cur.append(ch)
            if ch == quote:
                quote = None
            continue
        if ch in ("'", '"'):
            quote = ch
            cur.append(ch)
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _get_stream(engine: Engine, name: str):
    obj = engine.get(name.strip())
    if not isinstance(obj, (Stream, ShardedStream)):
        raise StreamException(f"{name!r} is not a stream on {engine.name}")
    return obj


def _colocated_bands(engine: Engine, left_expr: str,
                     right_expr: str) -> int:
    """Partial-join band count: when both join operands are ewindows
    over ShardedStreams whose shards are co-located (identical engine
    placement, shard for shard), decompose the join into one band per
    shard pair; otherwise 1 (the plain full join)."""
    lm = _EWINDOW_RE.match(left_expr.strip())
    rm = _EWINDOW_RE.match(right_expr.strip())
    if not (lm and rm):
        return 1
    try:
        ls = _get_stream(engine, lm.group(1))
        rs = _get_stream(engine, rm.group(1))
    except Exception:                                     # noqa: BLE001
        return 1
    if (isinstance(ls, ShardedStream) and isinstance(rs, ShardedStream)
            and ls.shard_engines() == rs.shard_engines()):
        return ls.num_shards
    return 1


def execute_stream(engine: Engine, query: str):
    """Evaluate one streaming-island expression against ``engine``.

    Under ``REPRO_QUERY_BACKEND=jit`` the compiled path (stream/compile)
    gets first refusal: family ops execute as cached jitted plans over
    exported ring arrays, bit-identical to the interpreter below; every
    other op — and any fallback — continues here unchanged."""
    from repro.stream import compile as query_compile
    handled, value = query_compile.maybe_execute(engine, query)
    if handled:
        return value
    q = query.strip()
    m = re.match(r"^(\w+)\s*\(", q)
    if not m:
        # bare stream name -> snapshot (the natural "scan" of a stream)
        return _get_stream(engine, q).snapshot()
    fn = m.group(1).lower()
    body, _ = _balanced(q[m.end() - 1:])
    args = _split_args(body)

    if fn == "snapshot":
        return _get_stream(engine, args[0]).snapshot()
    if fn == "window":
        if len(args) not in (2, 3):
            raise ValueError(f"window needs (stream, size[, slide]): {q!r}")
        stream = _get_stream(engine, args[0])
        size = int(args[1])
        slide = int(args[2]) if len(args) == 3 else None
        return stream.window(size, slide)
    if fn == "ewindow":
        if len(args) not in (2, 3):
            raise ValueError(
                f"ewindow needs (stream, span[, slide]): {q!r}")
        stream = _get_stream(engine, args[0])
        span = float(args[1])
        slide = float(args[2]) if len(args) == 3 else None
        return stream.ewindow(span, slide)
    if fn == "join":
        if len(args) < 2:
            raise ValueError(
                f"join needs (W1, W2[, on=field][, tol=x]): {q!r}")
        on, tol = "ts", 0.0
        for extra in args[2:]:
            kw = _KWARG_RE.match(extra.strip())
            if not kw or kw.group(1).lower() not in ("on", "tol"):
                raise ValueError(f"bad join argument {extra!r} "
                                 f"(expected on=field or tol=x)")
            if kw.group(1).lower() == "on":
                on = kw.group(2).strip()
            else:
                tol = float(kw.group(2))
        bands = _colocated_bands(engine, args[0], args[1])
        left = _as_window(execute_stream(engine, args[0]))
        right = _as_window(execute_stream(engine, args[1]))
        return interval_join(left, right, on=on, tol=tol, bands=bands)
    if fn == "watermark":
        stream = _get_stream(engine, args[0])
        stats = stream.stats()
        if "watermark" not in stats:
            raise StreamException(
                f"{args[0].strip()!r} has no event-time field")
        wm = stats["watermark"]
        return dm.Table({
            "watermark": jnp.asarray(
                [float("-inf") if wm is None else float(wm)]),
            "late": jnp.asarray([float(stats["late"])]),
            "pending": jnp.asarray([float(stats["pending"])])})
    if fn == "flush":
        if len(args) not in (1, 2):
            raise ValueError(f"flush needs (stream[, to_ts]): {q!r}")
        stream = _get_stream(engine, args[0])
        counts = stream.flush(float(args[1]) if len(args) == 2 else None)
        return dm.Table({k: jnp.asarray([float(v)])
                         for k, v in counts.items()})
    if fn == "rate":
        stream = _get_stream(engine, args[0])
        stats = stream.stats()
        return dm.Table({
            "rows_per_second": jnp.asarray([stream.rate()]),
            "rows": jnp.asarray([float(stats["rows"])]),
            "appended": jnp.asarray([float(stats["appended"])]),
            "dropped": jnp.asarray([float(stats["dropped"])])})
    if fn == "ingest":
        stream = _get_stream(engine, args[0])
        return dm.Table({k: jnp.asarray([float(v)])
                         for k, v in stream.ingest_concurrency().items()})
    if fn == "replay":
        # rebuild the durable stream from its segment log into a
        # detached clone (read-only — the live log is untouched), timing
        # the tail replay: the log as a deterministic load generator.
        # identical=1.0 iff the clone matches the live stream bit-wise.
        from repro.stream.durability import replay_clone
        stream = _get_stream(engine, args[0])
        return dm.Table({k: jnp.asarray([float(v)])
                         for k, v in replay_clone(stream).items()})
    if fn == "aggregate":
        if len(args) != 2:
            raise ValueError(f"aggregate needs (expr, fn(attr)): {q!r}")
        agg = _AGG_RE.match(args[1].strip())
        if not agg:
            raise ValueError(f"bad streaming aggregate: {args[1]!r}")
        # rolling fast path: a tumbling window aggregated on a real field
        # never materializes the window — O(1) cumulative-ring partials
        # (per shard for sharded streams), memoized per window index
        win = _WINDOW_AGG_RE.match(args[0].strip())
        agg_fn, target = agg.group(1).lower(), agg.group(2)
        if win and agg_fn in _COMBINABLE_AGGS:
            stream = _get_stream(engine, win.group(1))
            if target == "*":
                target = stream.fields[0]
            if target in stream.fields:
                value = stream.window_aggregate(int(win.group(2)),
                                                agg_fn, target)
                return dm.ArrayObject(
                    {f"{agg_fn}_{target}": jnp.asarray([value])}, ("i",))
        value = execute_stream(engine, args[0])
        if isinstance(value, dm.Table):
            value = dm.ArrayObject(
                {n: v for n, v in value.columns.items() if n != "seq"},
                ("tick",))
        target = agg.group(2)
        if target == "*":
            target = next(iter(value.attrs))
        return value.aggregate(agg_fn, target)
    if fn == "append":
        if len(args) != 2:
            raise ValueError(f"append needs (stream, '<json rows>'): {q!r}")
        stream = _get_stream(engine, args[0])
        payload = json.loads(args[1].strip().strip("'\""))
        if isinstance(payload, dict):
            payload = [payload]
        cols = {f: [row[f] for row in payload] for f in stream.fields}
        counts = stream.append(cols)
        return dm.Table({k: jnp.asarray([float(v)])
                         for k, v in counts.items()})
    raise ValueError(f"unsupported streaming operator: {fn}")
