"""Declarative stream specs: the registration surface of the streaming
island as data, not a 13-kwarg call.

``register_stream`` accreted one keyword per feature PR (sharding,
event time, durability...) until no serving tier should have to speak
it.  A :class:`StreamSpec` groups those knobs into three orthogonal
sub-configs — :class:`Sharding`, :class:`EventTime`,
:class:`Durability` — and is the *primary* registration form:

    from repro.stream.spec import StreamSpec, Sharding, EventTime
    spec = StreamSpec("icu.abp", ("ts", "abp"), capacity=512,
                      sharding=Sharding(shards=2),
                      event_time=EventTime("ts", max_delay=4.0))
    stream = bd.register_stream("streamstore0", spec)

The legacy kwargs form survives as a thin shim that builds the same
spec (and emits ``DeprecationWarning``); the front door's tenant-facing
registration speaks specs only.  Specs are frozen and hashable, so a
serving config can carry them, and they round-trip losslessly through
the durability layer's ``meta.json`` manifest (``to_manifest`` /
``from_manifest``) — recovery hands back the registration spec instead
of making the caller restate it.

New registration knobs belong HERE (a new field on the right
sub-config), never on the legacy shim — ``tools/check_api_freeze.py``
fails the build otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

#: the legacy ``register_stream`` keyword surface, frozen at the PR that
#: introduced specs.  tools/check_api_freeze.py pins the shim's
#: signature to exactly this set (+ ``spec``): growth happens on the
#: sub-configs above, not on the kwargs form.
LEGACY_KWARGS = ("capacity", "shards", "shard_key", "num_engines",
                 "rolling", "block_rows", "ts_field", "max_delay",
                 "idle_timeout", "durability", "checkpoint_every_rows",
                 "dead_letter")


@dataclasses.dataclass(frozen=True)
class Sharding:
    """Hash-partition the stream into ``shards`` ring buffers spread
    over ``num_engines`` StreamEngines (default: one engine per shard).
    ``shard_key`` hashes rows by a field's value instead of round-robin
    seq blocks of ``block_rows``."""
    shards: int = 2
    shard_key: Optional[str] = None
    num_engines: Optional[int] = None
    block_rows: int = 64

    def __post_init__(self) -> None:
        if self.shards < 2:
            raise ValueError(
                f"Sharding needs shards >= 2, got {self.shards} "
                "(omit the sharding config for a single ring)")
        if self.block_rows < 1:
            raise ValueError(f"block_rows must be >= 1, "
                             f"got {self.block_rows}")
        # None means "one engine per shard"; normalize so value
        # semantics (equality, manifest round-trips) see one spelling
        if self.num_engines is None:
            object.__setattr__(self, "num_engines", self.shards)
        if not 1 <= self.num_engines <= self.shards:
            raise ValueError(
                f"num_engines must be in [1, shards={self.shards}], "
                f"got {self.num_engines}")


@dataclasses.dataclass(frozen=True)
class EventTime:
    """Declare ``ts_field`` as the event-time axis: out-of-order ingest
    bounded by ``max_delay``, watermarks, ``ewindow``/``join`` ops.
    ``idle_timeout`` is automatic punctuation; ``dead_letter`` diverts
    late rows into a queryable ``{name}.__late`` stream."""
    ts_field: str
    max_delay: float = 0.0
    idle_timeout: Optional[float] = None
    dead_letter: bool = False

    def __post_init__(self) -> None:
        if not self.ts_field:
            raise ValueError("EventTime needs a ts_field")
        if self.max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, "
                             f"got {self.max_delay}")
        if self.idle_timeout is not None and self.idle_timeout <= 0:
            raise ValueError(f"idle_timeout must be > 0, "
                             f"got {self.idle_timeout}")


@dataclasses.dataclass(frozen=True)
class Durability:
    """Crash-safety: a write-behind segment log under ``directory``,
    checkpoints every ``checkpoint_every_rows`` logged rows (``None`` =
    explicit only), last ``keep`` checkpoints retained."""
    directory: str
    checkpoint_every_rows: Optional[int] = None
    keep: int = 3

    def __post_init__(self) -> None:
        if not self.directory:
            raise ValueError("Durability needs a directory")
        if (self.checkpoint_every_rows is not None
                and self.checkpoint_every_rows < 1):
            raise ValueError(f"checkpoint_every_rows must be >= 1, "
                             f"got {self.checkpoint_every_rows}")
        if self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {self.keep}")


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Everything ``register_stream`` needs, as one frozen value."""
    name: str
    fields: Tuple[str, ...]
    capacity: int = 4096
    rolling: bool = True
    sharding: Optional[Sharding] = None
    event_time: Optional[EventTime] = None
    durability: Optional[Durability] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "fields", tuple(self.fields))
        if not self.name:
            raise ValueError("StreamSpec needs a name")
        if not self.fields:
            raise ValueError(f"stream {self.name!r} needs fields")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, "
                             f"got {self.capacity}")
        if self.event_time is not None \
                and self.event_time.ts_field not in self.fields:
            raise ValueError(
                f"ts_field {self.event_time.ts_field!r} is not one of "
                f"the stream's fields {self.fields}")
        if self.sharding is not None \
                and self.sharding.shard_key is not None \
                and self.sharding.shard_key not in self.fields:
            raise ValueError(
                f"shard_key {self.sharding.shard_key!r} is not one of "
                f"the stream's fields {self.fields}")

    # -- convenience accessors (the spec path in api.py reads these) ---------
    @property
    def shards(self) -> int:
        return self.sharding.shards if self.sharding else 1

    @property
    def ts_field(self) -> Optional[str]:
        return self.event_time.ts_field if self.event_time else None

    # -- legacy kwargs <-> spec ----------------------------------------------
    @classmethod
    def from_kwargs(cls, name: str, fields, *, capacity: int = 4096,
                    shards: int = 1, shard_key: Optional[str] = None,
                    num_engines: Optional[int] = None,
                    rolling: bool = True, block_rows: int = 64,
                    ts_field: Optional[str] = None,
                    max_delay: float = 0.0,
                    idle_timeout: Optional[float] = None,
                    durability: Optional[str] = None,
                    checkpoint_every_rows: Optional[int] = None,
                    dead_letter: bool = False) -> "StreamSpec":
        """The legacy 13-kwarg surface, folded into a spec (what the
        deprecation shim calls)."""
        sharding = None
        if shards > 1:
            sharding = Sharding(shards=shards, shard_key=shard_key,
                                num_engines=num_engines,
                                block_rows=block_rows)
        event_time = None
        if ts_field is not None:
            event_time = EventTime(ts_field, max_delay=max_delay,
                                   idle_timeout=idle_timeout,
                                   dead_letter=dead_letter)
        elif dead_letter:
            raise ValueError(
                "dead_letter diverts late event-time rows; it needs "
                "ts_field (EventTime) to ever receive one")
        durable = None
        if durability is not None:
            durable = Durability(
                durability, checkpoint_every_rows=checkpoint_every_rows)
        return cls(name, tuple(fields), capacity=capacity,
                   rolling=rolling, sharding=sharding,
                   event_time=event_time, durability=durable)

    def to_kwargs(self) -> Dict[str, Any]:
        """The legacy keyword dict this spec is equivalent to (used by
        the spec<->kwargs equivalence tests; a spec whose ``keep``
        deviates from the attach default has no kwargs spelling)."""
        if self.durability is not None and self.durability.keep != 3:
            raise ValueError(
                "the legacy kwargs form cannot express Durability.keep "
                f"!= 3 (got {self.durability.keep})")
        out: Dict[str, Any] = {"capacity": self.capacity,
                               "rolling": self.rolling}
        if self.sharding is not None:
            out.update(shards=self.sharding.shards,
                       shard_key=self.sharding.shard_key,
                       num_engines=self.sharding.num_engines,
                       block_rows=self.sharding.block_rows)
        if self.event_time is not None:
            out.update(ts_field=self.event_time.ts_field,
                       max_delay=self.event_time.max_delay,
                       idle_timeout=self.event_time.idle_timeout,
                       dead_letter=self.event_time.dead_letter)
        if self.durability is not None:
            out.update(durability=self.durability.directory,
                       checkpoint_every_rows=self.durability
                       .checkpoint_every_rows)
        return out

    # -- durability manifest (meta.json) round-trip ---------------------------
    def manifest_extras(self) -> Dict[str, Any]:
        """Spec-derived keys the durability layer folds into its
        ``meta.json`` (on top of the runtime facts — engines, shard
        capacities — only the live stream knows)."""
        return {"capacity": self.capacity, "keep": self.keep_or_default()}

    def keep_or_default(self) -> int:
        return self.durability.keep if self.durability else 3

    @classmethod
    def from_manifest(cls, meta: Dict[str, Any],
                      directory: Optional[str] = None) -> "StreamSpec":
        """Rebuild the registration spec from a durability directory's
        ``meta.json`` — what ``recover_stream`` returns, so recovery
        never requires the caller to restate registration kwargs.

        ``directory`` overrides the manifest's durability directory
        (the manifest never records it: the directory is where the
        manifest *lives*, and the tree may have been copied)."""
        sharding = None
        if meta["kind"] == "sharded":
            engines = meta["engines"]
            sharding = Sharding(shards=len(engines),
                                shard_key=meta.get("shard_key"),
                                num_engines=len(set(engines)),
                                block_rows=meta.get("block_rows", 64))
            capacity = meta.get("capacity",
                                sum(meta["shard_capacities"]))
            rolling = meta.get("rolling", True)
        else:
            capacity = meta["capacity"]
            rolling = meta.get("rolling", True)
        event_time = None
        if meta.get("ts_field") is not None:
            event_time = EventTime(meta["ts_field"],
                                   max_delay=meta.get("max_delay", 0.0),
                                   idle_timeout=meta.get("idle_timeout"),
                                   dead_letter=bool(
                                       meta.get("dead_letter", False)))
        durable = None
        if directory is not None:
            durable = Durability(
                directory,
                checkpoint_every_rows=meta.get("checkpoint_every_rows"),
                keep=meta.get("keep", 3))
        return cls(meta["name"], tuple(meta["fields"]),
                   capacity=capacity, rolling=rolling,
                   sharding=sharding, event_time=event_time,
                   durability=durable)
