"""Training step factory: loss/grad/clip/update with microbatch gradient
accumulation, buffer donation, and logical-axis sharding constraints.
This is the jitted executable the Planner selects among (sharding plan x
kernel shims are baked in at lower time; DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import logical as L


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    microbatches: int = 1          # grad accumulation steps
    aux_weight: float = 0.01


def loss_and_metrics(params, batch, cfg: ModelConfig, rules,
                     aux_weight: float) -> Tuple[jax.Array, Dict[str, Any]]:
    logits, aux = registry.forward(params, batch, cfg, rules)
    loss = registry.loss_fn(logits, batch["labels"], aux,
                            aux_weight=aux_weight)
    return loss, {"loss": loss, "aux_loss": aux}


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    def re(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(re, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, rules=None):
    """Returns train_step(state, batch) -> (state, metrics), where
    state = {"params": ..., "opt": ...}."""

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_and_metrics, has_aux=True)(
                params, mb, cfg, rules, tcfg.aux_weight)
        return grads, metrics

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if tcfg.microbatches > 1:
            mbs = _split_microbatches(batch, tcfg.microbatches)

            def acc_fn(carry, mb):
                grads, metrics = grads_of(params, mb)
                carry = jax.tree.map(jnp.add, carry, grads)
                return carry, metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(acc_fn, zero, mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            grads, metrics = grads_of(params, batch)

        grads, gnorm = adamw.clip_by_global_norm(
            grads, tcfg.optimizer.grad_clip_norm)
        new_params, new_opt = adamw.apply_updates(
            tcfg.optimizer, params, grads, opt)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = adamw.lr_at(tcfg.optimizer, new_opt["step"])
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = registry.param_specs(cfg)
    params = L.init_params(key, specs)
    return {"params": params, "opt": adamw.init_state(params)}
