"""Test configuration.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 CPU device; only launch/dryrun.py forces 512 host devices."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
