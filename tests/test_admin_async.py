"""Admin interface (paper §IV) + async executor (paper's
executePlanAsync) + monitoring daemon lifecycle."""
import time

import numpy as np

from repro.core import bql
from repro.core.admin import start, status, stop
from repro.core.api import default_deployment
from repro.data.mimic import load_mimic_demo


def _bd():
    bd = default_deployment()
    load_mimic_demo(bd, num_patients=32, num_orders=128)
    return bd


def test_admin_status_reports_engines_and_objects():
    bd = _bd()
    st = status(bd)
    assert st["engines"]["hoststore0"]["objects"] >= 2
    assert st["engines"]["hoststore0"]["bytes"] > 0
    assert "relational" in st["islands"]
    assert "densehbm0" in st["islands"]["array"]
    # v0.1 topology's 5 engines + the PR-2 streaming island's streamstore0
    assert st["catalog"]["engines"] == 6
    assert "streaming" in st["islands"]
    assert st["catalog"]["objects"] >= 5


def test_admin_start_stop_monitoring_daemon():
    bd = _bd()
    start(bd, interval_seconds=0.05)
    assert bd.monitoring_task is not None
    bd.engines["hoststore0"].record("probe", 0.001)
    time.sleep(0.2)                      # let the daemon tick
    ticks = bd.monitoring_task.ticks
    assert ticks >= 1
    stop(bd)
    assert bd.monitoring_task is None


def test_execute_plan_async_returns_future():
    bd = _bd()
    root = bql.parse("bdrel(select * from mimic2v26.d_patients limit 3)")
    plans = bd.planner.enumerate_plans(root)
    fut = bd.planner.executor.execute_plan_async(plans[0])
    res = fut.result(timeout=30)
    assert res.value.num_rows == 3
    assert res.qep_id == plans[0].qep_id


def test_async_plans_run_concurrently():
    bd = _bd()
    root = bql.parse("bdrel(select poe_id, dose from mimic2v26.poe_order"
                     " where dose > 1)")
    plans = bd.planner.enumerate_plans(root)
    futures = [bd.planner.executor.execute_plan_async(plans[0])
               for _ in range(4)]
    results = [f.result(timeout=30) for f in futures]
    rows = {r.value.num_rows for r in results}
    assert len(rows) == 1                # deterministic results
