"""The perf-regression gate (benchmarks/run.py --compare): median
diffing against a committed baseline must fail on a synthetic >=25%
median regression, pass on the baseline itself, pool medians across
samples, and never let a renamed row silently drop out of the gate."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.run import compare_reports, report_medians  # noqa: E402

BASELINE = {
    "suites": {
        "stream": [
            {"name": "stream/ingest", "us_per_call": 100.0, "derived": ""},
            {"name": "stream/join_ew512", "us_per_call": 40.0,
             "derived": ""},
        ],
        "planner": [
            {"name": "planner/lean_hit", "us_per_call": 10.0,
             "derived": ""},
        ],
    },
    "meta": {}, "failures": [],
}


def test_baseline_compared_to_itself_passes():
    cmp = compare_reports(BASELINE, copy.deepcopy(BASELINE),
                          tolerance=0.25)
    assert cmp["regressions"] == [] and cmp["improvements"] == []
    assert len(cmp["rows"]) == 3
    assert all(r["ratio"] == 1.0 for r in cmp["rows"])


def test_synthetic_25pct_median_regression_fails():
    cur = copy.deepcopy(BASELINE)
    cur["suites"]["stream"][0]["us_per_call"] = 130.0    # +30% > 25%
    cmp = compare_reports(BASELINE, cur, tolerance=0.25)
    assert cmp["regressions"] == ["stream/ingest"]
    row = next(r for r in cmp["rows"] if r["name"] == "stream/ingest")
    assert row["regressed"] and row["ratio"] == pytest.approx(1.3)


def test_regression_within_tolerance_passes():
    cur = copy.deepcopy(BASELINE)
    cur["suites"]["stream"][0]["us_per_call"] = 120.0    # +20% <= 25%
    cmp = compare_reports(BASELINE, cur, tolerance=0.25)
    assert cmp["regressions"] == []


def test_medians_pool_across_samples_and_shrug_off_outliers():
    """--samples N repeats row names; the gate diffs medians, so one
    noisy outlier pass cannot fail the build."""
    cur = copy.deepcopy(BASELINE)
    cur["suites"]["stream"] = [
        {"name": "stream/ingest", "us_per_call": v, "derived": ""}
        for v in (95.0, 105.0, 900.0)]                   # median 105
    med = report_medians(cur)
    assert med[("stream", "stream/ingest")] == 105.0
    cmp = compare_reports(BASELINE, cur, tolerance=0.25)
    assert cmp["regressions"] == []
    # ...but a consistently slow row still fails
    cur["suites"]["stream"] = [
        {"name": "stream/ingest", "us_per_call": v, "derived": ""}
        for v in (140.0, 150.0, 160.0)]
    assert compare_reports(BASELINE, cur,
                           tolerance=0.25)["regressions"] \
        == ["stream/ingest"]


def test_improvements_and_row_set_drift_are_reported():
    cur = copy.deepcopy(BASELINE)
    cur["suites"]["stream"][1]["us_per_call"] = 10.0     # 4x faster
    cur["suites"]["stream"][0]["name"] = "stream/ingest_v2"  # renamed
    cmp = compare_reports(BASELINE, cur, tolerance=0.25)
    assert cmp["improvements"] == ["stream/join_ew512"]
    assert cmp["only_in_baseline"] == ["stream/stream/ingest"]
    assert cmp["only_in_current"] == ["stream/stream/ingest_v2"]
    assert cmp["regressions"] == []


def test_committed_baseline_matches_the_ci_invocation():
    """benchmarks/BASELINE.json must exist, parse, and cover the suites
    the bench-smoke job compares (planner, migration, stream)."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BASELINE.json")
    with open(path) as fh:
        baseline = json.load(fh)
    assert {"planner", "migration", "stream"} <= set(baseline["suites"])
    meds = report_medians(baseline)
    assert all(v > 0 for v in meds.values())
    assert any(name == "stream/join_ew512" for _, name in meds)


# -- ratio-type rows (self-normalizing, bigger is better) ---------------------
RATIO_BASE = {
    "suites": {
        "stream": [
            {"name": "stream/ingest", "us_per_call": 100.0,
             "derived": "", "kind": "time"},
            {"name": "stream/ingest_producers4", "us_per_call": 1.5,
             "derived": "", "kind": "ratio"},
        ],
    },
    "meta": {}, "failures": [],
}


def test_ratio_row_regresses_when_ratio_drops():
    """A ratio row (concurrent/serial throughput) regresses when the
    ratio FALLS — the direction is inverted vs wall-clock rows."""
    cur = copy.deepcopy(RATIO_BASE)
    cur["suites"]["stream"][1]["us_per_call"] = 1.0      # -33% < -25%
    cmp = compare_reports(RATIO_BASE, cur, tolerance=0.25)
    assert cmp["regressions"] == ["stream/ingest_producers4"]
    row = next(r for r in cmp["rows"]
               if r["name"] == "stream/ingest_producers4")
    assert row["kind"] == "ratio" and row["regressed"]


def test_ratio_row_improvement_is_a_higher_ratio():
    cur = copy.deepcopy(RATIO_BASE)
    cur["suites"]["stream"][1]["us_per_call"] = 2.0      # +33% better
    cmp = compare_reports(RATIO_BASE, cur, tolerance=0.25)
    assert cmp["regressions"] == []
    assert cmp["improvements"] == ["stream/ingest_producers4"]


def test_ratio_row_best_sample_vetoes_noise():
    """One healthy sample among noisy ones vetoes a ratio alarm (the
    max-sample analog of the wall-clock min-sample veto)."""
    cur = copy.deepcopy(RATIO_BASE)
    cur["suites"]["stream"] = [
        {"name": "stream/ingest_producers4", "us_per_call": v,
         "derived": "", "kind": "ratio"}
        for v in (0.9, 1.0, 1.4)]            # median 1.0, best 1.4
    cmp = compare_reports(RATIO_BASE, cur, tolerance=0.25)
    assert cmp["regressions"] == []
    # ...but a consistently collapsed ratio still fails
    cur["suites"]["stream"] = [
        {"name": "stream/ingest_producers4", "us_per_call": v,
         "derived": "", "kind": "ratio"}
        for v in (0.8, 0.9, 1.0)]
    assert compare_reports(RATIO_BASE, cur,
                           tolerance=0.25)["regressions"] \
        == ["stream/ingest_producers4"]


def test_ratio_kind_read_from_baseline_when_current_omits_it():
    """Old reports without a kind field compare as wall-clock; a kind
    recorded on either side is honored (current wins)."""
    cur = copy.deepcopy(RATIO_BASE)
    del cur["suites"]["stream"][1]["kind"]
    cur["suites"]["stream"][1]["us_per_call"] = 1.0
    cmp = compare_reports(RATIO_BASE, cur, tolerance=0.25)
    # baseline's kind=ratio still applies: a falling ratio regresses
    assert cmp["regressions"] == ["stream/ingest_producers4"]


def test_committed_baseline_has_the_producer_ratio_rows():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BASELINE.json")
    with open(path) as fh:
        baseline = json.load(fh)
    from benchmarks.run import report_kinds
    kinds = report_kinds(baseline)
    assert kinds[("stream", "stream/ingest_producers2")] == "ratio"
    assert kinds[("stream", "stream/ingest_producers4")] == "ratio"
    meds = report_medians(baseline)
    # the dev-container guarantee: concurrency wins at 2 producers
    assert meds[("stream", "stream/ingest_producers2")] >= 1.0
