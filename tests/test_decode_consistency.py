"""Integration test: the serve path (prefill + decode_step) must produce
the same last-position logits as the training forward pass — this checks
KV-cache writes, positions/rope, SSM state streaming, cross-attention
memory and the scheduler-visible decode semantics for every architecture.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.models import registry

ARCHS = list(registry.ARCH_NAMES)
B, S = 2, 12
CACHE = 16

# The seed's decode/forward drift (olmoe-1b-7b single-step, jamba-v0.1-52b
# multi-step) was root-caused to MoE capacity clipping: the sort-based
# dispatch dropped over-capacity token slots in the forward/prefill passes
# (t=24 tokens -> drops under skewed routing) while decode (t=2, no drops)
# computed the same tokens exactly.  With the dropless reference MoE path
# (models/moe.py) forward ≡ decode is bitwise on CPU and both sets are
# empty — this test is a hard gate again.
_SINGLE_STEP_DRIFT: set = set()
_MULTI_STEP_DRIFT: set = set()


def _mark_drift(name, drift):
    return pytest.param(
        name, marks=pytest.mark.xfail(
            reason="seed decode/forward numeric drift > 5e-2 (ROADMAP)",
            strict=False)) if name in drift else name


@pytest.mark.parametrize("name", [_mark_drift(n, _SINGLE_STEP_DRIFT)
                                  for n in ARCHS])
def test_decode_matches_forward(name):
    cfg = registry.get_config(name, reduced=True)
    from repro.sharding import logical as L
    params = L.init_params(jax.random.PRNGKey(1),
                           registry.param_specs(cfg))
    batch = registry.make_train_batch(cfg, S, B, key=jax.random.PRNGKey(2))
    batch.pop("labels")

    # full forward logits at the last position
    logits_full, _ = registry.forward(params, batch, cfg, None)
    want = logits_full[:, -1]

    # prefill on tokens[:-1], then decode the last token
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    cache = registry.init_cache(cfg, B, CACHE)
    _, cache, extras = registry.prefill(params, pre, cache, cfg, None)

    prefix = cfg.num_prefix_embeds if cfg.frontend == "vision" else 0
    pos = jnp.int32(prefix + batch["tokens"].shape[1] - 1)
    dbatch = {"tokens": batch["tokens"][:, -1:], **extras}
    logits_dec, _ = registry.decode_step(params, dbatch, cache, pos, cfg,
                                         None)
    got = logits_dec[:, -1]

    err = float(jnp.max(jnp.abs(want - got)))
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    assert err / scale < 5e-2, f"{name}: rel err {err/scale:.3e}"


@pytest.mark.parametrize("name", [_mark_drift(n, _MULTI_STEP_DRIFT)
                                  for n in ("qwen2-1.5b", "rwkv6-7b",
                                            "jamba-v0.1-52b")])
def test_multi_step_decode_matches_forward(name):
    """Decode N tokens one-by-one; each step must match the forward pass
    truncated at that position."""
    cfg = registry.get_config(name, reduced=True)
    from repro.sharding import logical as L
    params = L.init_params(jax.random.PRNGKey(3),
                           registry.param_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)

    cache = registry.init_cache(cfg, B, CACHE)
    split = S - 4
    _, cache, extras = registry.prefill(
        params, {"tokens": toks[:, :split]}, cache, cfg, None)
    for i in range(split, S):
        logits_dec, cache = registry.decode_step(
            params, {"tokens": toks[:, i:i + 1], **extras}, cache,
            jnp.int32(i), cfg, None)
        logits_full, _ = registry.forward(
            params, {"tokens": toks[:, :i + 1]}, cfg, None)
        err = float(jnp.max(jnp.abs(logits_full[:, -1]
                                    - logits_dec[:, -1])))
        scale = float(jnp.max(jnp.abs(logits_full[:, -1]))) + 1e-6
        assert err / scale < 5e-2, f"{name} step {i}: {err/scale:.3e}"


@pytest.mark.parametrize("name", ["qwen2-1.5b", "rwkv6-7b",
                                  "jamba-v0.1-52b", "olmoe-1b-7b"])
def test_decode_cache_matches_prefill(name):
    """Regression guard for cache-layout bugs: the cache after
    prefill(S-4) + 4 decode steps must equal one full prefill(S) tensor-by-
    tensor (bitwise on CPU), not just produce matching logits."""
    cfg = registry.get_config(name, reduced=True)
    from repro.sharding import logical as L
    params = L.init_params(jax.random.PRNGKey(3),
                           registry.param_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)

    stepped = registry.init_cache(cfg, B, CACHE)
    split = S - 4
    _, stepped, extras = registry.prefill(
        params, {"tokens": toks[:, :split]}, stepped, cfg, None)
    for i in range(split, S):
        _, stepped = registry.decode_step(
            params, {"tokens": toks[:, i:i + 1], **extras}, stepped,
            jnp.int32(i), cfg, None)

    full = registry.init_cache(cfg, B, CACHE)
    _, full, _ = registry.prefill(params, {"tokens": toks}, full, cfg, None)

    flat_a = jax.tree_util.tree_flatten_with_path(full)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(stepped)[0]
    for (path_a, leaf_a), (_, leaf_b) in zip(flat_a, flat_b):
        assert leaf_a.dtype == leaf_b.dtype, jax.tree_util.keystr(path_a)
        err = float(jnp.max(jnp.abs(leaf_a.astype(jnp.float32)
                                    - leaf_b.astype(jnp.float32))))
        assert err == 0.0, (f"{name} cache leaf "
                            f"{jax.tree_util.keystr(path_a)}: {err:.3e}")
