"""The documentation surface is part of tier-1: every fenced example in
docs/BQL.md must execute against an in-memory deployment (the same gate
CI runs via tools/check_docs.py)."""
import pathlib
import runpy

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_docs_bql_examples_execute(monkeypatch, capsys):
    docs = ROOT / "docs" / "BQL.md"
    gate = ROOT / "tools" / "check_docs.py"
    if not docs.exists() or not gate.exists():
        pytest.skip("docs gate not present")
    monkeypatch.setattr("sys.argv",
                        ["check_docs.py", "--docs", str(docs)])
    module = runpy.run_path(str(gate), run_name="check_docs")
    assert module["main"]() == 0, capsys.readouterr().out
