"""The documentation surface is part of tier-1: every fenced example in
docs/BQL.md and docs/OPERATIONS.md must execute against an in-memory
deployment (the same gate CI runs via tools/check_docs.py)."""
import pathlib
import runpy

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("doc", ["BQL.md", "OPERATIONS.md"])
def test_docs_examples_execute(doc, monkeypatch, capsys):
    docs = ROOT / "docs" / doc
    gate = ROOT / "tools" / "check_docs.py"
    if not docs.exists() or not gate.exists():
        pytest.skip("docs gate not present")
    monkeypatch.setattr("sys.argv",
                        ["check_docs.py", "--docs", str(docs)])
    module = runpy.run_path(str(gate), run_name="check_docs")
    assert module["main"]() == 0, capsys.readouterr().out
