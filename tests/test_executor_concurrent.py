"""Concurrent DAG executor + plan-cache tests: DAG construction and
ordering, serial/concurrent determinism, cross-engine overlap, exception
propagation from failed sub-queries, early cancel, and plan-cache
hit/miss/LRU/staleness semantics."""
import time

import numpy as np
import pytest

from repro.core import bql, signatures
from repro.core.api import default_deployment
from repro.core.executor import (ExecutorConfig, LocalQueryExecutionException,
                                 PlanAbortedException, QueryExecutionPlan,
                                 assign_ids, build_task_graph,
                                 critical_path_seconds)
from repro.core.monitor import Monitor
from repro.core.planner import PlanCache
from repro.data.mimic import load_mimic_demo

# two independent relational sub-queries feeding one array-island join:
# the branches share no DAG edges, so a concurrent executor overlaps them
CROSS_Q = (
    "bdarray(cross_join("
    "bdcast(bdrel(select subject_id, dob_year from mimic2v26.d_patients),"
    " pat_arr, '<dob_year:int32>[subject_id=0:*,1000,0]', array),"
    "bdcast(bdrel(select poe_id, dose from mimic2v26.poe_order),"
    " ord_arr, '<dose:double>[poe_id=0:*,1000,0]', array)))")


@pytest.fixture(scope="module")
def bd():
    bd = default_deployment()
    load_mimic_demo(bd, num_patients=32, num_orders=64, wave_len=256,
                    num_logs=16)
    return bd


def _two_engine_plan(bd, root) -> QueryExecutionPlan:
    """A QEP whose two relational children run on different engines.

    Built explicitly (not via enumerate_plans) so Monitor straggler
    avoidance accumulated by earlier tests can't hide hoststore1."""
    nodes, casts = assign_ids(root)
    assert len(nodes) == 3 and len(casts) == 2
    return QueryExecutionPlan(
        root=root,
        node_engines={0: "hoststore0", 1: "hoststore1", 2: "densehbm0"},
        cast_methods={cid: "binary" for cid in casts})


# -- DAG construction ---------------------------------------------------------
def test_task_graph_structure():
    root = bql.parse(CROSS_Q)
    nodes, casts = assign_ids(root)
    assert len(nodes) == 3 and len(casts) == 2
    deps = build_task_graph(nodes, casts)
    # root node waits on both casts; each cast waits on its child node
    assert sorted(deps[("node", 2)]) == [("cast", 0), ("cast", 1)]
    assert deps[("cast", 0)] == [("node", 0)]
    assert deps[("cast", 1)] == [("node", 1)]
    assert deps[("node", 0)] == [] and deps[("node", 1)] == []


def test_scoped_query_spares_quoted_literals():
    from repro.core.executor import _scoped_query
    q = "select c, x from t where label = 'c' and note = \"c c\""
    out = _scoped_query(q, {"c": "c__qep0"})
    assert out == ("select c__qep0, x from t where label = 'c'"
                   " and note = \"c c\"")


def test_critical_path_is_longest_chain():
    root = bql.parse(CROSS_Q)
    nodes, casts = assign_ids(root)
    deps = build_task_graph(nodes, casts)
    durations = {("node", 0): 1.0, ("cast", 0): 1.0,
                 ("node", 1): 5.0, ("cast", 1): 1.0,
                 ("node", 2): 1.0}
    assert critical_path_seconds(deps, durations) == pytest.approx(7.0)
    assert sum(durations.values()) == pytest.approx(9.0)  # serial sum


# -- determinism --------------------------------------------------------------
def test_concurrent_matches_serial_bitwise(bd):
    plan = _two_engine_plan(bd, bql.parse(CROSS_Q))
    ex = bd.planner.executor
    r_serial = ex.execute_plan(plan, mode="serial")
    r_conc = ex.execute_plan(plan, mode="concurrent")
    assert set(r_serial.value.attrs) == set(r_conc.value.attrs)
    for name in r_serial.value.attrs:
        np.testing.assert_array_equal(
            np.asarray(r_serial.value.attrs[name]),
            np.asarray(r_conc.value.attrs[name]))
    # canonical stage ordering: same stage names in the same order
    assert [s for s, _ in r_serial.stages] == [s for s, _ in r_conc.stages]
    assert r_conc.critical_path_seconds <= r_conc.serial_sum_seconds + 1e-9


def test_cross_engine_branches_overlap(bd, monkeypatch):
    """With latency injected into each sub-query, the concurrent wall time
    beats serial (branches overlap) while results stay identical."""
    from repro.core import shims
    real_execute = shims.execute
    delay = 0.15

    def slow_execute(island, engine, query):
        if island == "relational":
            time.sleep(delay)
        return real_execute(island, engine, query)

    monkeypatch.setattr(shims, "execute", slow_execute)
    plan = _two_engine_plan(bd, bql.parse(CROSS_Q))
    ex = bd.planner.executor
    r_serial = ex.execute_plan(plan, mode="serial")
    r_conc = ex.execute_plan(plan, mode="concurrent")
    for name in r_serial.value.attrs:
        np.testing.assert_array_equal(
            np.asarray(r_serial.value.attrs[name]),
            np.asarray(r_conc.value.attrs[name]))
    # serial pays both delays on the wall; concurrent pays ~one
    assert r_serial.wall_seconds >= 2 * delay
    assert r_conc.wall_seconds < r_serial.wall_seconds
    assert r_conc.critical_path_seconds < r_conc.serial_sum_seconds


# -- failure handling ---------------------------------------------------------
def test_exception_propagates_from_failed_subquery(bd):
    q = CROSS_Q.replace("mimic2v26.poe_order", "no_such_table")
    plans = bd.planner.enumerate_plans(bql.parse(q))
    ex = bd.planner.executor
    for mode in ("serial", "concurrent"):
        with pytest.raises(LocalQueryExecutionException):
            ex.execute_plan(plans[0], mode=mode)


def test_should_abort_raises_plan_aborted(bd):
    plan = _two_engine_plan(bd, bql.parse(CROSS_Q))
    ex = bd.planner.executor
    with pytest.raises(PlanAbortedException):
        ex.execute_plan(plan, should_abort=lambda: True)


def test_aborted_plan_leaves_no_materialized_objects(bd):
    """A plan cancelled mid-flight must sweep its scoped cast outputs
    (training-mode early cancel would otherwise leak objects forever)."""
    plan = _two_engine_plan(bd, bql.parse(CROSS_Q))
    ex = bd.planner.executor
    before = {n: e.list_objects() for n, e in bd.engines.items()}
    calls = [0]

    def abort_after_three() -> bool:
        calls[0] += 1
        return calls[0] > 3          # first cast has materialized by then

    with pytest.raises(PlanAbortedException):
        ex.execute_plan(plan, mode="serial",
                        should_abort=abort_after_three, scope="leaktest")
    after = {n: e.list_objects() for n, e in bd.engines.items()}
    assert after == before


def test_identical_cast_subtrees_under_different_parents(bd):
    """Two structurally identical bdcast subexpressions under different
    parent nodes must migrate to each parent's own engine (regression:
    parent lookup by dataclass equality conflated them)."""
    from repro.core.engines import DenseHBMEngine
    if "densehbm1" not in bd.engines:
        bd.add_engine(DenseHBMEngine("densehbm1", None, None))
    inner = ("bdcast(bdrel(select subject_id, dob_year from"
             " mimic2v26.d_patients), pa,"
             " '<dob_year:int32>[subject_id=0:*,1000,0]', array)")
    q = (f"bdarray(cross_join(scan({inner}),"
         f" bdcast(bdarray(scan({inner})), pb, 's2', array)))")
    root = bql.parse(q)
    nodes, casts = assign_ids(root)
    assert len(nodes) == 4 and len(casts) == 3
    # the two identical casts land on different parents: mid + root
    plan = QueryExecutionPlan(
        root=root,
        node_engines={0: "hoststore0", 1: "hoststore0",
                      2: "densehbm1", 3: "densehbm0"},
        cast_methods={cid: "binary" for cid in casts})
    for mode in ("serial", "concurrent"):
        res = bd.planner.executor.execute_plan(plan, mode=mode)
        assert "dob_year" in res.value.attrs      # root cross_join ran


# -- plan cache ---------------------------------------------------------------
def _sig_and_plan(query):
    root = bql.parse(query)
    sig = signatures.of_query(root)
    nodes, casts = assign_ids(root)
    plan = QueryExecutionPlan(
        root=root, node_engines={nid: "hoststore0" for nid in nodes},
        cast_methods={cid: "binary" for cid in casts})
    return sig, plan


def test_plan_cache_hit_miss_and_lru_eviction():
    cache = PlanCache(Monitor(), max_size=2, max_age_seconds=100.0)
    queries = ["bdrel(select a from t)",
               "bdrel(select a from t where a > 1)",
               "bdrel(select a from t order by a limit 5)"]
    sig0, plan0 = _sig_and_plan(queries[0])
    assert cache.get(sig0) is None                      # cold miss
    cache.put(sig0, plan0)
    hit = cache.get(sig0)
    assert hit is not None and hit.qep_id == plan0.qep_id
    for q in queries[1:]:
        cache.put(*_sig_and_plan(q))
    assert len(cache) == 2                              # LRU capacity
    assert cache.get(sig0) is None                      # evicted (oldest)
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["evictions"] == 1
    assert stats["misses"] == 2


def test_plan_cache_staleness_eviction_via_monitor():
    monitor = Monitor()
    cache = PlanCache(monitor, max_size=8, max_age_seconds=100.0)
    sig, plan = _sig_and_plan("bdrel(select a from t)")
    monitor.add_measurement(sig, plan.qep_id, 0.5)
    cache.put(sig, plan)
    assert cache.get(sig) is not None
    # a faster QEP lands in the Monitor -> the cached plan is superseded
    monitor.add_measurement(sig, "some_other_qep", 0.001)
    assert cache.get(sig) is None
    assert cache.stats()["stale_evictions"] == 1


def test_plan_cache_ttl_eviction():
    cache = PlanCache(Monitor(), max_size=8, max_age_seconds=0.0)
    sig, plan = _sig_and_plan("bdrel(select a from t)")
    cache.put(sig, plan)
    time.sleep(0.01)
    assert cache.get(sig) is None
    assert cache.stats()["stale_evictions"] == 1


def test_evict_stale_sweep():
    monitor = Monitor()
    cache = PlanCache(monitor, max_size=8, max_age_seconds=100.0)
    sig, plan = _sig_and_plan("bdrel(select a from t)")
    cache.put(sig, plan)
    monitor.add_measurement(sig, "faster_qep", 1e-6)
    assert cache.evict_stale() == 1
    assert len(cache) == 0


# -- planner integration ------------------------------------------------------
def test_training_then_lean_hits_plan_cache(bd):
    q = ("bdarray(scan(bdcast(bdrel(select poe_id, icustay_id from"
         " mimic2v26.poe_order), icu_copy,"
         " '<icustay_id:int32>[poe_id=0:*,1000,0]', array)))")
    r_train = bd.query(q, training=True)
    assert r_train.plans_considered > 1
    r_lean = bd.query(q)
    assert r_lean.plan_cache_hit
    assert r_lean.plans_considered == 1                 # skipped enumeration
    assert r_lean.qep_id == r_train.qep_id
    assert any("Plan cache hit" in s for s, _ in r_lean.stages)
    np.testing.assert_array_equal(
        np.asarray(r_lean.value.attrs["icustay_id"]),
        np.asarray(r_train.value.attrs["icustay_id"]))


def test_training_mode_concurrent_exploration_records_all(bd):
    q = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
         " mimic2v26.poe_order), subj_copy,"
         " '<subject_id:int32>[poe_id=0:*,2000,0]', array)))")
    # this query shares its signature with earlier tests' queries (only
    # non-dotted column names differ), so Monitor measurements accumulate
    # across them; drop straggler state so enumeration isn't flakily
    # narrowed below the number of already-measured QEPs
    bd.monitor.engine_ewma.clear()
    r = bd.query(q, training=True)
    sig_key = r.signature_key
    perf = {k: v for k, v in bd.monitor.get_benchmark_performance(
        signatures.of_query(bql.parse(q))).items() if v}
    assert len(perf) >= 1                   # at least the winner measured
    assert r.plans_considered >= len(perf)


def test_serial_config_still_works(bd):
    cfg = ExecutorConfig(mode="serial", max_workers=1)
    from repro.core.executor import Executor
    ex = Executor(bd.engines, bd.migrator, bd.monitor, config=cfg)
    plan = _two_engine_plan(bd, bql.parse(CROSS_Q))
    res = ex.execute_plan(plan)
    assert res.value.attrs
