"""Per-kernel shape/dtype sweeps: pallas (interpret=True) vs pure-jnp
oracle, assert_allclose (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(7)


# -- flash attention ------------------------------------------------------------
@pytest.mark.parametrize("b,s,hq,hkv,dh", [
    (1, 128, 4, 4, 64), (2, 256, 4, 2, 64), (1, 512, 8, 2, 128),
    (2, 128, 6, 3, 32), (1, 384, 2, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, hq, hkv, dh, dtype):
    from repro.kernels.flash_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((b, s, hq, dh)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, dh)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, dh)), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.gqa_attention(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_non_causal():
    from repro.kernels.flash_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 128, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    want = ref.gqa_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_ragged_fallback():
    from repro.kernels.flash_attention import ops, ref
    q = jnp.asarray(RNG.standard_normal((1, 100, 2, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 100, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 100, 2, 64)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)    # oracle fallback
    want = ref.gqa_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# -- rwkv6 chunked scan -----------------------------------------------------------
@pytest.mark.parametrize("b,s,h,d,chunk", [
    (1, 64, 1, 16, 16), (2, 128, 2, 32, 32), (1, 256, 4, 64, 64),
    (2, 96, 2, 32, 32),
])
def test_rwkv6_scan_sweep(b, s, h, d, chunk):
    from repro.kernels.rwkv6_scan import ops, ref
    r = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.85, 0.999, (b, s, h, d)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((h, d)), jnp.float32)
    s0 = jnp.asarray(RNG.standard_normal((b, h, d, d)), jnp.float32) * 0.3
    y, sf = ops.wkv6(r, k, v, w, u, state=s0, chunk=chunk)
    yr, sr = ref.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               atol=5e-4, rtol=5e-4)


def test_rwkv6_zero_state_and_model_consistency():
    """Kernel == model-internal reference scan (models/rwkv6.wkv_scan)."""
    from repro.kernels.rwkv6_scan import ops
    from repro.models.rwkv6 import wkv_scan
    b, s, h, d = 1, 64, 2, 32
    r = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.9, 0.999, (b, s, h, d)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((h, d)), jnp.float32)
    y1, s1 = ops.wkv6(r, k, v, w, u, chunk=32)
    y2, s2 = wkv_scan(r, k, v, w, u, jnp.zeros((b, h, d, d), jnp.float32))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)


# -- mamba selective scan ----------------------------------------------------------
@pytest.mark.parametrize("b,s,di,n,bd,chunk", [
    (1, 64, 64, 8, 64, 32), (2, 128, 128, 16, 64, 64),
    (1, 96, 256, 16, 128, 32),
])
def test_mamba_scan_sweep(b, s, di, n, bd, chunk):
    from repro.kernels.mamba_scan import ops, ref
    u = jnp.asarray(RNG.standard_normal((b, s, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, di)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, (di, n)), jnp.float32)
    bb = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((b, s, n)), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((b, di, n)), jnp.float32) * 0.2
    y, h = ops.selective_scan(u, dt, a, bb, c, h0=h0, bd=bd, chunk=chunk)
    yr, hr = ref.selective_scan(u, dt, a, bb, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=5e-5, rtol=5e-5)


# -- quant cast ----------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1000,), (64, 128), (3, 7, 33),
                                   (8, 128)])
def test_quant_roundtrip_error_bound(shape):
    from repro.kernels.quant_cast import ops
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    q, scale = ops.quantize(x)
    back = ops.dequantize(q, scale, shape)
    # per-block bound: |err| <= scale/2 <= absmax/254 * ~1.01
    err = jnp.abs(back - x)
    bound = float(jnp.max(jnp.abs(x))) / 127.0 * 0.51 + 1e-7
    assert float(jnp.max(err)) <= bound * 2.01


def test_quant_kernel_matches_ref():
    from repro.kernels.quant_cast import ops, ref
    from repro.kernels.quant_cast import quant_cast as k
    rng = np.random.default_rng(123)
    x2d = jnp.asarray(rng.standard_normal((32, k.BLOCK)), jnp.float32)
    qk, sk = k.quantize_2d(x2d, interpret=True)
    qr, sr = ref.quantize_blocks(x2d)
    # values exactly at a .5 rounding boundary may differ by 1 LSB between
    # the interpreter and the jnp path; dequantized error stays bounded
    assert int(np.abs(np.asarray(qk, np.int32)
                      - np.asarray(qr, np.int32)).max()) <= 1
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-7)
    back_k = k.dequantize_2d(qk, sk, interpret=True)
    back_r = ref.dequantize_blocks(qr, sr)
    np.testing.assert_allclose(np.asarray(back_k), np.asarray(back_r),
                               atol=float(sr.max()), rtol=1e-6)


def test_quant_zero_block():
    from repro.kernels.quant_cast import ops
    x = jnp.zeros((256,), jnp.float32)
    q, scale = ops.quantize(x)
    back = ops.dequantize(q, scale, (256,))
    assert float(jnp.max(jnp.abs(back))) == 0.0
