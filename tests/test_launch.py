"""Launch-layer tests: collective-stats HLO parsing, rule selection,
sharding divisibility fallback, spec trees, and a real (subprocess)
single-cell dry-run on the production mesh."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch.dryrun import collective_stats, _shape_bytes
from repro.launch.mesh import make_mesh
from repro.sharding import logical as L


def test_shape_bytes_parsing():
    assert _shape_bytes("%x = f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("%y = (bf16[64], s8[1,2,3])") == 64 * 2 + 6
    assert _shape_bytes("%z = pred[]") == 1


def test_collective_stats_ring_factors():
    hlo = "\n".join([
        "%ar = f32[1024] all-reduce(%a), replica_groups=[2,4]<=[8]",
        "%ag = bf16[2048] all-gather(%b), replica_groups=[4,2]<=[8]",
        "%rs = f32[256] reduce-scatter(%c), replica_groups=[2,4]<=[8]",
        "%cp = f32[100] collective-permute(%d), source_target_pairs={{0,1}}",
        "%aa = f32[512] all-to-all(%e), replica_groups=[1,8]<=[8]",
    ])
    st = collective_stats(hlo)
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == pytest.approx(
        2 * 1024 * 4 * 3 / 4)
    assert st["all-gather"]["bytes"] == pytest.approx(2048 * 2 * 1 / 2)
    assert st["reduce-scatter"]["bytes"] == pytest.approx(256 * 4 * 3)
    assert st["collective-permute"]["bytes"] == 100 * 4
    assert st["all-to-all"]["bytes"] == pytest.approx(512 * 4 * 7 / 8)
    assert st["total_bytes"] > 0


def test_collective_stats_ignores_trivial_groups():
    hlo = "%ar = f32[1024] all-reduce(%a), replica_groups=[8,1]<=[8]"
    st = collective_stats(hlo)
    assert st["all-reduce"]["count"] == 0


def test_sharding_divisibility_fallback():
    mesh = make_mesh((1, 2), ("data", "model"))
    rules = L.default_rules(mesh)
    # 12 heads on model=2 divides -> sharded; 13 doesn't -> replicated
    ok = L.sharding_for(L.ParamSpec((64, 12, 8),
                                    (L.EMBED, L.HEADS, L.HEAD_DIM)),
                        mesh, rules)
    bad = L.sharding_for(L.ParamSpec((64, 13, 8),
                                     (L.EMBED, L.HEADS, L.HEAD_DIM)),
                         mesh, rules)
    assert ok.spec[1] == "model"
    assert bad.spec[1] is None


def test_pick_rules_kv_policy():
    from repro.launch.specs import pick_rules
    from repro.models import registry
    mesh = make_mesh((2, 16), ("data", "model"))
    # kv=16 divides model=16 -> heads sharded, cache seq unsharded
    r1 = pick_rules(registry.get_config("olmoe-1b-7b"), mesh)
    assert r1.mesh_axes(L.KV_HEADS) == "model"
    assert r1.mesh_axes(L.KV_SEQ) is None
    # kv=8 does not divide 16 -> cache sequence sharded instead
    r2 = pick_rules(registry.get_config("command-r-35b"), mesh)
    assert r2.mesh_axes(L.KV_HEADS) is None
    assert r2.mesh_axes(L.KV_SEQ) == "model"


def test_spec_tree_structs_no_allocation():
    from repro.models import registry
    cfg = registry.get_config("command-r-plus-104b")   # 104B: specs only
    specs = registry.param_specs(cfg)
    structs = L.spec_tree_structs(specs)
    n = L.count_params(specs)
    assert n > 95e9                                   # ~104B params
    leaf = jax.tree.leaves(structs)[0]
    assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """Deliverable (e) in miniature: a full lower+compile on the 16x16
    production mesh for the smallest arch, via the real CLI."""
    out = str(tmp_path / "cell.jsonl")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen2-1.5b", "--shape", "decode_32k",
         "--no-cost-probe", "--out", out],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(open(out).read().strip())
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["memory"]["argument_bytes"] > 0
