"""Migrator ``stream``-route error paths (the live state-move used by
shard rebalancing): a missing migration target engine, handle lock
contention while standing queries tick, and relocation of a stream with
a non-empty insertion buffer — pending out-of-order rows must be neither
lost nor double-counted."""
import threading

import numpy as np
import pytest

from repro.core.api import default_deployment
from repro.core.migrator import MigrationParams
from repro.stream.engine import Stream


def test_migrate_shard_to_missing_engine_fails_cleanly():
    """A bad target engine must raise before any state moves — the shard
    stays live on its source and keeps accepting appends."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "v.stream", ("x",),
                            capacity=128, shards=2, num_engines=2,
                            block_rows=4)
    sh.append({"x": np.arange(16, dtype=float)})
    with pytest.raises(ValueError, match="does not exist"):
        sh.migrate_shard(0, bd.migrator, bd.engines, "streamstore9")
    with pytest.raises(ValueError, match="no shard"):
        sh.migrate_shard(7, bd.migrator, bd.engines, "streamstore1")
    assert sh.shard_engines() == ["streamstore0", "streamstore1"]
    assert sh.migrations == 0
    sh.append({"x": np.arange(16, dtype=float)})
    np.testing.assert_array_equal(
        np.asarray(sh.snapshot().columns["x"]),
        np.concatenate([np.arange(16), np.arange(16)]))


def test_shard_move_under_concurrent_appends_and_ticks():
    """Handle lock contention: a producer thread appends and ticks while
    shards migrate back and forth.  Nothing is lost or double-counted —
    the gather still sees every retained row exactly once, in order."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "c.stream", ("ts", "x"),
                            capacity=4096, shards=2, num_engines=2,
                            block_rows=8, ts_field="ts", max_delay=4.0)
    cq = bd.register_continuous("bdstream(snapshot(c.stream))",
                                name="snap")
    stop = threading.Event()
    fed = {"rows": 0}
    err = []

    def producer():
        rng = np.random.default_rng(7)
        base = 0.0
        try:
            while not stop.is_set():
                ts = base + np.arange(16, dtype=float)
                base += 16
                order = np.argsort(ts + rng.uniform(-1.5, 1.5, 16))
                sh.append({"ts": ts[order], "x": ts[order] * 2.0})
                fed["rows"] += 16
                bd.streams.tick()
        except Exception as exc:                          # noqa: BLE001
            err.append(exc)

    t = threading.Thread(target=producer)
    t.start()
    moves = 0
    for _ in range(6):
        # ping-pong shard 0 between the two engines under live traffic
        dest = "streamstore1" if sh.shard_engines()[0] == \
            "streamstore0" else "streamstore0"
        sh.migrate_shard(0, bd.migrator, bd.engines, dest)
        moves += 1
    stop.set()
    t.join(timeout=10.0)
    assert not t.is_alive() and not err
    assert moves == 6 and sh.migrations == 6
    sh.flush()
    snap = sh.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    # every flushed row exactly once, in seq order, values intact
    assert sh.total_appended == fed["rows"] - sh._pending_rows
    np.testing.assert_array_equal(
        seqs, np.arange(sh.total_appended - len(seqs),
                        sh.total_appended))
    np.testing.assert_array_equal(np.asarray(snap.columns["x"]),
                                  np.asarray(snap.columns["ts"]) * 2.0)
    assert cq.errors == 0


def test_stream_route_moves_non_empty_insertion_buffer():
    """Relocating an event-time stream with pending out-of-order rows:
    the insertion buffer, watermark, and late counters travel; flushing
    on the destination yields each pending row exactly once."""
    bd = default_deployment(stream_engines=2)
    src = bd.engines["streamstore0"]
    dst = bd.engines["streamstore1"]
    s = bd.register_stream("streamstore0", "ev.stream", ("ts", "x"),
                           capacity=64, ts_field="ts", max_delay=5.0)
    s.append({"ts": [2.0, 9.0, 7.0], "x": [20.0, 90.0, 70.0]})
    s.append({"ts": [1.0], "x": [10.0]})       # late (wm = 4)
    assert s._pending_rows == 2 and s.total_late == 1
    appended, flushed_rows = s.total_appended, s.num_rows
    result = bd.migrator.migrate(src, "ev.stream", dst, "ev.stream",
                                 MigrationParams(method="stream"))
    assert result.method == "stream"
    assert not src.has("ev.stream")            # moved, not copied
    moved = dst.get("ev.stream")
    assert isinstance(moved, Stream)
    assert moved._pending_rows == 2            # buffer travelled
    assert moved.total_late == 1 and moved.watermark == 4.0
    assert moved.total_appended == appended
    assert moved.num_rows == flushed_rows
    out = moved.flush()
    assert out["flushed"] == 2                 # once, not twice
    np.testing.assert_array_equal(
        np.asarray(moved.snapshot().columns["ts"]), [2, 7, 9])
    assert moved.total_appended == appended + 2
    # a late arrival on the destination is still judged by the moved
    # watermark, and the memo/counters keep accumulating from their
    # migrated values (no reset, no double count)
    r = moved.append({"ts": [3.0], "x": [30.0]})
    assert r["late"] == 1 and moved.total_late == 2
