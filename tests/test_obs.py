"""Observability tests (repro.obs): span nesting and cross-thread
propagation (executor workers, committer lanes), histogram percentile
correctness against numpy, registry snapshot consistency under
concurrent writers, Chrome-trace export round-trip, the slow-op
threshold with an injected clock, Prometheus text exposition + the
/metrics HTTP endpoint, the late-row/eviction metric feeds, and the
admin.status() vs background-mutation race regression."""
import json
import re
import threading
import time
import urllib.request
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import admin
from repro.core.api import default_deployment
from repro.obs import metrics, trace

WINDOW_CQ = ("bdarray(aggregate(bdcast(bdstream(window("
             "mimic2v26.waveform_stream, 32)), w_arr,"
             " '<signal:double>[tick=0:31,32,0]', array), avg(signal)))")


@pytest.fixture
def traced():
    prev = trace.set_enabled(True)
    trace.reset()
    yield
    trace.set_enabled(prev)
    trace.reset()


@pytest.fixture
def registry():
    metrics.reset()
    yield metrics.REGISTRY
    metrics.reset()


# -- tracing core -------------------------------------------------------------
def test_span_nesting_links_parent_and_trace(traced):
    with trace.span("stream/tick", trace_id="tick-1") as root:
        with trace.span("planner/query") as child:
            with trace.span("executor/node", engine="e0"):
                pass
    recs = {r.name: r for r in trace.spans()}
    assert set(recs) == {"stream/tick", "planner/query", "executor/node"}
    assert recs["stream/tick"].parent_id is None
    assert recs["planner/query"].parent_id == root.span_id
    assert recs["executor/node"].parent_id == child.span_id
    assert {r.trace_id for r in recs.values()} == {"tick-1"}
    assert recs["executor/node"].attrs["engine"] == "e0"


def test_disabled_tracing_is_noop():
    prev = trace.set_enabled(False)
    try:
        trace.reset()
        assert trace.span("x/y") is trace.NOOP
        with trace.span("x/y") as sp:
            sp.set(a=1)                       # no-op surface
        assert trace.spans() == []

        def fn():
            return 7
        assert trace.bind(fn) is fn           # identity when disabled
    finally:
        trace.set_enabled(prev)


def test_top_level_spans_get_distinct_trace_ids(traced):
    with trace.span("a/one"):
        pass
    with trace.span("a/two"):
        pass
    ids = [r.trace_id for r in trace.spans()]
    assert len(set(ids)) == 2


def test_span_records_error_attr(traced):
    with pytest.raises(ValueError):
        with trace.span("executor/node"):
            raise ValueError("boom")
    (rec,) = trace.spans()
    assert rec.attrs["error"] == "ValueError"


def test_bind_propagates_parent_across_pool_threads(traced):
    def work(i):
        with trace.span("executor/task", i=i):
            time.sleep(0.001)
        return i

    with trace.span("executor/plan") as root:
        bound = trace.bind(work)
        with ThreadPoolExecutor(max_workers=4) as pool:
            # one bound fn running concurrently on several threads: each
            # call must plant/reset only its own contextvar token
            assert sorted(pool.map(bound, range(8))) == list(range(8))
    recs = [r for r in trace.spans() if r.name == "executor/task"]
    assert len(recs) == 8
    assert all(r.parent_id == root.span_id for r in recs)
    assert all(r.trace_id == root.trace_id for r in recs)
    main_tid = threading.get_ident()
    assert any(r.thread_id != main_tid for r in recs)


def test_chrome_trace_round_trip(traced, tmp_path):
    def work(i):
        with trace.span("committer/commit", shard=i):
            pass

    with trace.span("stream/append", trace_id="tick-3") as root:
        with ThreadPoolExecutor(max_workers=2) as pool:
            list(pool.map(trace.bind(work), range(2)))
    out = tmp_path / "trace.json"
    n = trace.save_chrome_trace(str(out))
    doc = json.loads(out.read_text())
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert n == len(xs) == 3
    for e in xs:
        assert e["dur"] >= 1 and isinstance(e["ts"], int)
        assert e["cat"] in ("stream", "committer")
        assert e["args"]["trace_id"] == "tick-3"
    # cross-thread children carry flow arrows: "s" on the parent thread,
    # "f" (bp="e") on the child's, sharing the child's span id
    starts = {e["id"]: e for e in events if e["ph"] == "s"}
    finishes = {e["id"]: e for e in events if e["ph"] == "f"}
    child_ids = {e["args"]["span_id"] for e in xs
                 if e["ph"] == "X" and e["name"] == "committer/commit"
                 and e["tid"] != root.span_id}
    cross = {e["args"]["span_id"] for e in xs
             if e["args"]["parent_id"] is not None
             and e["tid"] != next(x["tid"] for x in xs
                                  if x["name"] == "stream/append")}
    assert set(starts) == set(finishes) == cross and child_ids
    for sid in cross:
        assert finishes[sid]["bp"] == "e"
        assert starts[sid]["tid"] != finishes[sid]["tid"]
    # thread-name metadata for every participating thread
    tids = {e["tid"] for e in xs}
    named = {e["tid"] for e in events if e["ph"] == "M"}
    assert tids <= named


def test_flamegraph_shows_paths_and_counts(traced):
    with trace.span("stream/tick"):
        for _ in range(3):
            with trace.span("planner/query"):
                pass
    text = trace.flamegraph()
    assert "stream/tick" in text and "planner/query" in text
    row = next(ln for ln in text.splitlines() if "planner/query" in ln)
    assert re.search(r"\s3\s", row)           # call count aggregated


def test_slow_op_threshold_with_injected_clock(traced, monkeypatch):
    ticks = iter([0.0, 0.050, 1.0, 1.250])    # 50 ms span, then 250 ms
    monkeypatch.setattr(trace, "_clock", lambda: next(ticks))
    monkeypatch.setenv("REPRO_SLOW_OP_MS", "100")
    monkeypatch.setenv("REPRO_TRACE", "1")
    trace.refresh()
    assert trace.slow_op_threshold_ms() == 100.0
    with trace.span("executor/cast", method="staged"):
        pass
    with trace.span("migrator/route", src="a", dst="b"):
        pass
    slow = trace.slow_ops()
    assert [s["name"] for s in slow] == ["migrator/route"]
    assert slow[0]["ms"] == 250.0
    assert slow[0]["attrs"] == {"src": "a", "dst": "b"}
    monkeypatch.delenv("REPRO_SLOW_OP_MS")
    monkeypatch.delenv("REPRO_TRACE")
    trace.refresh()                           # back to defaults


# -- metrics core -------------------------------------------------------------
def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-4.0, sigma=2.0, size=20_000)
    h = metrics.Histogram()
    for v in samples:
        h.observe(v)
    assert h.count == samples.size
    assert h.sum == pytest.approx(samples.sum())
    for q in (0.50, 0.95, 0.99):
        ref = float(np.quantile(samples, q))
        est = h.quantile(q)
        # log-bucket interpolation is within one bucket ratio of truth
        assert ref / metrics.BUCKET_RATIO <= est \
            <= ref * metrics.BUCKET_RATIO


def test_counter_set_total_is_monotone(registry):
    c = metrics.counter("repro_test_total", "t", stream="s")
    c.set_total(5)
    c.set_total(3)                            # stale source: ignored
    assert c.value == 5
    c.inc(2)
    assert c.value == 7
    # same labels -> same series object
    assert metrics.counter("repro_test_total", stream="s") is c


def test_metric_type_mismatch_raises(registry):
    metrics.counter("repro_test_kind_total")
    with pytest.raises(ValueError):
        metrics.gauge("repro_test_kind_total")


def test_registry_snapshot_consistent_under_concurrent_writers(registry):
    stop = threading.Event()
    n_threads, per_thread = 4, 2000

    def writer(tid):
        c = metrics.counter("repro_conc_total", "c", t=tid)
        h = metrics.histogram("repro_conc_seconds", "h")
        for i in range(per_thread):
            c.inc()
            h.observe(1e-4 * (i + 1))

    def reader():
        while not stop.is_set():
            snap = metrics.snapshot()
            json.dumps(snap)                  # JSON-safe at any moment
            metrics.prometheus_text()

    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in readers:
        t.start()
    with ThreadPoolExecutor(max_workers=n_threads) as pool:
        list(pool.map(writer, range(n_threads)))
    stop.set()
    for t in readers:
        t.join(timeout=5.0)
        assert not t.is_alive()
    snap = metrics.snapshot()
    totals = {r["labels"]["t"]: r["value"]
              for r in snap["repro_conc_total"]["series"]}
    assert totals == {str(i): per_thread for i in range(n_threads)}
    (hist,) = snap["repro_conc_seconds"]["series"]
    assert hist["count"] == n_threads * per_thread


def test_prometheus_text_format(registry):
    metrics.counter("repro_fmt_total", "a counter", stream="s\"1\"").inc(3)
    metrics.gauge("repro_fmt_gauge", "a gauge").set(1.5)
    h = metrics.histogram("repro_fmt_seconds", "a histogram")
    for v in (0.001, 0.01, 0.1):
        h.observe(v)
    text = metrics.prometheus_text()
    assert '# TYPE repro_fmt_total counter' in text
    assert 'repro_fmt_total{stream="s\\"1\\""} 3' in text
    assert "repro_fmt_gauge 1.5" in text
    # histogram: cumulative buckets ending in +Inf == _count, plus _sum
    buckets = [int(m.group(1)) for m in re.finditer(
        r'repro_fmt_seconds_bucket\{le="[^"]+"\} (\d+)', text)]
    assert buckets == sorted(buckets) and buckets[-1] == 3
    assert 'repro_fmt_seconds_bucket{le="+Inf"} 3' in text
    assert "repro_fmt_seconds_count 3" in text
    # every sample line parses as <name>{labels} <value>
    for line in text.splitlines():
        if line.startswith("#"):
            continue
        assert re.match(r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? \S+$',
                        line), line


def test_metrics_http_endpoint(registry):
    metrics.counter("repro_http_total", "served").inc()
    server = metrics.start_http_server(port=0)
    try:
        host, port = server.server_address[:2]
        with urllib.request.urlopen(
                f"http://{host}:{port}/metrics", timeout=5) as resp:
            body = resp.read().decode()
            assert resp.status == 200
            assert "text/plain" in resp.headers["Content-Type"]
        assert "repro_http_total 1" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{host}:{port}/nope", timeout=5)
    finally:
        server.shutdown()


# -- integration: spans across the real layers --------------------------------
def test_tick_trace_spans_cross_layers_with_parent_links(traced):
    from repro.data.mimic import stream_mimic_waveforms
    bd = default_deployment()
    bd.register_continuous(WINDOW_CQ, every_n_ticks=1, name="wave_avg")
    for _ in stream_mimic_waveforms(bd, batch_rows=32, num_batches=3):
        pass
    recs = trace.spans()
    layers = {r.name.split("/", 1)[0] for r in recs}
    assert {"stream", "planner", "executor", "committer"} <= layers
    by_id = {r.span_id: r for r in recs}
    # every tick roots one trace: stream/query -> planner/query ->
    # executor/plan -> executor/node chain shares the tick's trace_id
    tick = next(r for r in recs if r.name == "stream/tick")
    assert tick.trace_id.startswith("tick-")
    q = next(r for r in recs if r.name == "stream/query")
    assert by_id[q.parent_id].name == "stream/tick"
    planner_spans = [r for r in recs if r.name == "planner/query"]
    assert any(r.parent_id is not None
               and by_id[r.parent_id].name == "stream/query"
               for r in planner_spans)
    nodes = [r for r in recs if r.name == "executor/node"]
    assert nodes and all(
        by_id[r.parent_id].name == "executor/plan" for r in nodes)
    # concurrent executor stages hop threads; parent links must survive
    plan = next(r for r in recs if r.name == "executor/plan")
    assert any(r.thread_id != plan.thread_id for r in nodes)


def test_sharded_append_spans_reach_committer_lanes(traced):
    bd = default_deployment()
    bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                       capacity=8192, shards=2, num_engines=2)
    stream = bd.engines["streamstore0"].get("vitals.stream")
    try:
        # >= PARALLEL_APPEND_MIN_ROWS rows: commits fan out to the
        # scatter pool, so the lane spans run on pool threads
        stream.append({"hr": np.arange(4096.0)})
    finally:
        stream.close()
    recs = trace.spans()
    root = next(r for r in recs if r.name == "stream/append")
    # lane spans carry shard=; the shard rings' own commit spans (from
    # Stream._append_prepared, nested inside) carry lane= instead
    commits = [r for r in recs if r.name == "committer/commit"
               and "shard" in r.attrs]
    assert {r.attrs["shard"] for r in commits} == {0, 1}
    assert all(r.parent_id == root.span_id for r in commits)
    assert all(r.trace_id == root.trace_id for r in commits)
    assert any(r.thread_id != root.thread_id for r in commits)
    stages = [r for r in recs if r.name == "stream/reserve"
              or r.name == "stream/stage"]
    assert {r.name for r in stages} == {"stream/reserve", "stream/stage"}


# -- metric feeds from the running system -------------------------------------
def test_late_and_eviction_metrics_exported(registry):
    bd = default_deployment()
    bd.register_stream("streamstore0", "ev.stream", ("ts", "x"),
                       capacity=4, ts_field="ts", max_delay=0.0)
    stream = bd.engines["streamstore0"].get("ev.stream")
    # 10 rows into a 4-slot ring: 6 evicted, eviction horizon advances
    stream.append({"ts": np.arange(10.0), "x": np.zeros(10)})
    r = stream.append({"ts": [2.0], "x": [0.0]})    # below wm: late
    assert r["late"] == 1
    bd.streams.tick()
    snap = metrics.snapshot()
    late = {r["labels"]["stream"]: r["value"] for r in
            snap["repro_stream_late_rows_dropped_total"]["series"]}
    assert late["ev.stream"] == 1
    ev = {r["labels"]["stream"]: r["value"] for r in
          snap["repro_stream_eviction_ts"]["series"]}
    assert ev["ev.stream"] == stream._evicted_ts > float("-inf")
    wm = {r["labels"]["stream"]: r["value"] for r in
          snap["repro_stream_watermark"]["series"]}
    assert wm["ev.stream"] == stream.watermark


def test_standing_query_counters_absorbed(registry):
    from repro.data.mimic import stream_mimic_waveforms
    bd = default_deployment()
    bd.register_continuous(WINDOW_CQ, every_n_ticks=1, name="wave_avg")
    for _ in stream_mimic_waveforms(bd, batch_rows=32, num_batches=3):
        pass
    snap = metrics.snapshot()
    ticks = {r["labels"]["query"]: r["value"] for r in
             snap["repro_stream_query_ticks_total"]["series"]}
    assert ticks["wave_avg"] == 3
    (tick_hist,) = snap["repro_stream_tick_seconds"]["series"]
    assert tick_hist["count"] == 3
    modes = {r["labels"]["mode"]: r["value"] for r in
             snap["repro_queries_total"]["series"]}
    assert modes.get("lean", 0) >= 3


# -- the status() race regression (satellite: snapshot under lock) ------------
def test_status_consistent_while_monitoring_task_mutates():
    """admin.status() used to iterate Monitor dicts the background
    MonitoringTask / tick driver mutate — hammer it against a running
    fleet and require structurally complete JSON-serializable output."""
    from repro.data.mimic import load_mimic_demo
    bd = default_deployment()
    load_mimic_demo(bd)
    bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                       capacity=2048)
    bd.register_continuous(
        "bdstream(aggregate(window(vitals.stream, 32), avg(hr)))",
        every_n_ticks=1, name="hr_avg")
    stream = bd.engines["streamstore0"].get("vitals.stream")
    task = bd.start_monitoring(interval_seconds=0.001)
    task.start()
    stop = threading.Event()
    errors = []

    def producer():
        rng = np.random.default_rng(0)
        while not stop.is_set():
            try:
                with stream.producer() as p:
                    p.append({"hr": rng.standard_normal(64)})
                bd.streams.tick()
            except Exception as exc:          # noqa: BLE001 — recorded
                errors.append(exc)
                return

    threads = [threading.Thread(target=producer) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        deadline = time.monotonic() + 1.0
        while time.monotonic() < deadline:
            st = admin.status(bd)
            json.dumps(st)                    # serializable mid-mutation
            assert set(st) == {"engines", "islands", "monitor",
                               "concurrency", "streams", "plan_cache",
                               "catalog", "serve", "ml"}
            assert "watermarks" in st["streams"]
            json.loads(bd.monitor.to_json())
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5.0)
        task.stop()
        bd.monitoring_task = None
    assert errors == []
    assert all(not t.is_alive() for t in threads)
