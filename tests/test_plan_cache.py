"""Dedicated PlanCache staleness tests (paper §V.E / ROADMAP PR-1 knobs):
each eviction path — TTL expiry, monitor-version bump with best-QEP
mismatch, and the background ``evict_stale()`` sweep — gets its own unit
test, plus the keep-paths (version bump *without* a better QEP, and
``refresh_version`` after a hit's own measurement)."""
import time

from repro.core import bql, signatures
from repro.core.executor import QueryExecutionPlan, assign_ids
from repro.core.monitor import Monitor
from repro.core.planner import PlanCache


def _sig_and_plan(query: str, engine: str = "hoststore0"):
    root = bql.parse(query)
    sig = signatures.of_query(root)
    nodes, casts = assign_ids(root)
    plan = QueryExecutionPlan(
        root=root, node_engines={nid: engine for nid in nodes},
        cast_methods={cid: "binary" for cid in casts})
    return sig, plan


def test_ttl_expiry_evicts_on_get():
    cache = PlanCache(Monitor(), max_size=8, max_age_seconds=0.005)
    sig, plan = _sig_and_plan("bdrel(select a from db.t)")
    cache.put(sig, plan)
    assert cache.get(sig) is not None              # fresh: still cached
    time.sleep(0.01)
    assert cache.get(sig) is None                  # aged out
    stats = cache.stats()
    assert stats["stale_evictions"] == 1
    assert stats["size"] == 0


def test_version_bump_with_best_qep_mismatch_evicts():
    monitor = Monitor()
    cache = PlanCache(monitor, max_size=8, max_age_seconds=100.0)
    sig, plan = _sig_and_plan("bdrel(select a from db.t)")
    monitor.add_measurement(sig, plan.qep_id, 0.5)
    cache.put(sig, plan)
    # new measurements land AND the Monitor's best QEP moved elsewhere
    monitor.add_measurement(sig, "engines[0:hoststore1]|casts[]", 1e-4)
    assert cache.get(sig) is None
    assert cache.stats()["stale_evictions"] == 1


def test_version_bump_without_better_qep_keeps_entry():
    monitor = Monitor()
    cache = PlanCache(monitor, max_size=8, max_age_seconds=100.0)
    sig, plan = _sig_and_plan("bdrel(select a from db.t)")
    monitor.add_measurement(sig, plan.qep_id, 0.5)
    cache.put(sig, plan)
    # new measurement for the SAME plan: version bumps, best unchanged
    monitor.add_measurement(sig, plan.qep_id, 0.4)
    entry = cache.get(sig)
    assert entry is not None and entry.qep_id == plan.qep_id
    # the entry resynced its stored version, so the next get is a plain
    # hit without a best_qep rescan
    assert entry.monitor_version == monitor.signature_version(sig)
    assert cache.stats()["stale_evictions"] == 0


def test_refresh_version_after_hit_measurement():
    monitor = Monitor()
    cache = PlanCache(monitor, max_size=8, max_age_seconds=100.0)
    sig, plan = _sig_and_plan("bdrel(select a from db.t)")
    cache.put(sig, plan)
    # the lean-mode hit path records its own measurement then resyncs
    monitor.add_measurement(sig, plan.qep_id, 0.01)
    cache.refresh_version(sig)
    entry = cache._entries[sig.key()][1]
    assert entry.monitor_version == monitor.signature_version(sig)
    assert cache.get(sig) is not None


def test_evict_stale_sweep_drops_aged_and_superseded():
    monitor = Monitor()
    cache = PlanCache(monitor, max_size=8, max_age_seconds=100.0)
    sig_keep, plan_keep = _sig_and_plan("bdrel(select a from db.t)")
    sig_aged, plan_aged = _sig_and_plan("bdrel(select b from db.u)")
    sig_sup, plan_sup = _sig_and_plan("bdrel(select c from db.v)")
    cache.put(sig_keep, plan_keep)
    cache.put(sig_aged, plan_aged)
    cache.put(sig_sup, plan_sup)
    # the kept entry is the Monitor's own best plan for its signature
    # (without a record, best_qep's closest-signature fallback would
    # report the superseding plan and sweep this entry too)
    monitor.add_measurement(sig_keep, plan_keep.qep_id, 0.01)
    # age one entry artificially; supersede another via the Monitor
    cache._entries[sig_aged.key()][1].inserted_at -= 1000.0
    monitor.add_measurement(sig_sup, "engines[0:other]|casts[]", 1e-6)
    assert cache.evict_stale() == 2
    assert len(cache) == 1
    assert cache.get(sig_keep) is not None
    assert cache.stats()["stale_evictions"] == 2


def test_lru_eviction_is_separate_from_staleness():
    cache = PlanCache(Monitor(), max_size=2, max_age_seconds=100.0)
    pairs = [_sig_and_plan(q) for q in (
        "bdrel(select a from db.t)",
        "bdrel(select b from db.u)",
        "bdrel(select c from db.v)")]
    for sig, plan in pairs:
        cache.put(sig, plan)
    assert len(cache) == 2
    assert cache.get(pairs[0][0]) is None          # LRU-dropped
    stats = cache.stats()
    assert stats["evictions"] == 1                 # capacity, not stale
    assert stats["stale_evictions"] == 0
