"""Polystore core tests: BQL parsing, island queries (the paper's §VI
examples), planner training/lean modes, monitor matching, migrator routes,
catalog queries — the paper's behaviour as executable assertions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bql, datamodel as dm, signatures
from repro.core.api import default_deployment
from repro.core.migrator import MigrationParams
from repro.data.mimic import load_mimic_demo


@pytest.fixture(scope="module")
def bd():
    bd = default_deployment()
    load_mimic_demo(bd, num_patients=64, num_orders=256, wave_len=512,
                    num_logs=32)
    return bd


# -- BQL parser ----------------------------------------------------------------
def test_parse_simple_island():
    root = bql.parse("bdrel(select * from t limit 4)")
    assert root.island == "relational"
    assert root.query == "select * from t limit 4"
    assert root.casts == []


def test_parse_nested_cast():
    q = ("bdarray(scan(bdcast(bdrel(select a from t), obj,"
         " '<a:int32>[i=0:*,10,0]', array)))")
    root = bql.parse(q)
    assert root.island == "array"
    assert "obj" in root.query and "bdcast" not in root.query
    assert len(root.casts) == 1
    cast = root.casts[0]
    assert cast.dest_name == "obj"
    assert cast.dest_island == "array"
    assert cast.child.island == "relational"


def test_parse_double_nested_cast():
    q = ("bdrel(select * from bdcast(bdarray(filter(bdcast(bdrel("
         "select a from t), x, 's1', array), dim1>0)), y, 's2',"
         " relational) limit 2)")
    root = bql.parse(q)
    assert len(root.casts) == 1
    inner = root.casts[0].child
    assert inner.island == "array"
    assert len(inner.casts) == 1
    assert inner.casts[0].child.island == "relational"


def test_parse_catalog():
    root = bql.parse("bdcatalog(select * from engines)")
    assert isinstance(root, bql.CatalogQueryNode)


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        bql.parse("select * from t")
    with pytest.raises(ValueError):
        bql.parse("bdcast(bdrel(select 1), a, b)")


# -- island queries (paper examples) ---------------------------------------------
def test_relational_island_limit(bd):
    r = bd.query("bdrel(select * from mimic2v26.d_patients limit 4)")
    assert r.value.num_rows == 4


def test_relational_island_filter_agg(bd):
    r = bd.query("bdrel(select count(*) from mimic2v26.d_patients"
                 " where sex = 1)")
    cnt = int(np.asarray(next(iter(r.value.columns.values())))[0])
    full = bd.engines["hoststore0"].get("mimic2v26.d_patients")
    want = int(np.asarray(full.columns["sex"]).sum())
    assert cnt == want


def test_relational_group_by(bd):
    r = bd.query("bdrel(select sex, avg(dob_year) from"
                 " mimic2v26.d_patients group by sex)")
    assert r.value.num_rows == 2


def test_array_island_filter(bd):
    r = bd.query("bdarray(filter(myarray, dim1>150))")
    assert int(r.value.mask().sum()) == 256 - 151


def test_array_island_aggregate(bd):
    r = bd.query("bdarray(aggregate(mimic2v26.waveform, avg(signal)))")
    got = float(np.asarray(next(iter(r.value.attrs.values())))[0])
    full = bd.engines["densehbm0"].get("mimic2v26.waveform")
    want = float(jnp.mean(full.attrs["signal"]))
    assert abs(got - want) < 1e-6


def test_text_island_range(bd):
    r = bd.query("bdtext({ 'op' : 'range', 'table' : 'mimic_logs',"
                 " 'range' : { 'start' : ['r_0001','',''],"
                 " 'end' : ['r_0015','',''] } })")
    assert len(r.value) == 15


def test_inter_island_cast_rel_to_array(bd):
    q = ("bdarray(scan(bdcast(bdrel(select poe_id, subject_id from"
         " mimic2v26.poe_order), poe_order_copy,"
         " '<subject_id:int32>[poe_id=0:*,10000000,0]', array)))")
    r = bd.query(q)
    assert "subject_id" in r.value.attrs
    assert r.value.dim_names == ("poe_id",)
    stage_names = [s for s, _ in r.stages]
    assert any("Migration" in s for s in stage_names)


def test_catalog_query(bd):
    r = bd.query("bdcatalog(select name from engines)")
    names = {row["name"] for row in r.value}
    assert {"hoststore0", "densehbm0", "kvstore0"} <= names


# -- planner / monitor ------------------------------------------------------------
def test_training_mode_explores_and_lean_follows(bd):
    q = ("bdarray(scan(bdcast(bdrel(select poe_id, dose from"
         " mimic2v26.poe_order), d_copy,"
         " '<dose:double>[poe_id=0:*,1000,0]', array)))")
    r_train = bd.query(q, training=True)
    assert r_train.plans_considered > 1
    r_lean = bd.query(q, training=False)
    assert r_lean.qep_id == r_train.qep_id     # follows the trained best


def test_monitor_closest_signature(bd):
    s1 = signatures.of_query(bql.parse(
        "bdrel(select * from mimic2v26.d_patients limit 4)"))
    s2 = signatures.of_query(bql.parse(
        "bdrel(select * from mimic2v26.d_patients limit 9)"))
    assert s1.distance(s2) == 0.0              # same structure
    s3 = signatures.of_query(bql.parse("bdarray(filter(myarray, dim1>1))"))
    assert s1.distance(s3) > 1.0
    bd.monitor.add_measurement(s1, "qepX", 0.002)
    got = bd.monitor.get_closest_signature(s2)
    assert got is not None and got.distance(s2) <= s3.distance(s2)


def test_monitor_straggler_detection(bd):
    m = bd.monitor
    for _ in range(8):
        m.observe_engine("fast_a", 0.001)
        m.observe_engine("fast_b", 0.0012)
        m.observe_engine("slow_c", 0.5)
    assert "slow_c" in m.stragglers(factor=3.0)
    assert "fast_a" not in m.stragglers(factor=3.0)


# -- migrator ------------------------------------------------------------------
def test_binary_and_staged_agree(bd):
    src = bd.engines["hoststore0"]
    dst = bd.engines["densehbm0"]
    for method in ("binary", "staged"):
        bd.migrator.migrate(src, "mimic2v26.poe_order", dst,
                            f"poe_{method}", MigrationParams(method=method))
    b = dst.get("poe_binary")
    s = dst.get("poe_staged")
    for field in b.attrs:
        np.testing.assert_allclose(np.asarray(b.attrs[field], np.float64),
                                   np.asarray(s.attrs[field], np.float64),
                                   rtol=1e-12)


def test_quant_migration_bounded_error(bd):
    src = bd.engines["densehbm0"]
    dst = bd.engines["kvstore0"]
    bd.migrator.migrate(src, "mimic2v26.waveform", dst, "wave_q",
                        MigrationParams(method="quant"))
    from repro.kernels.quant_cast import ops as qops
    q = dst.get("wave_q")["signal"]
    orig = src.get("mimic2v26.waveform").attrs["signal"]
    back = qops.dequantize(q["q"], q["scale"], orig.shape)
    err = float(jnp.max(jnp.abs(back - jnp.asarray(orig, jnp.float32))))
    bound = float(jnp.max(jnp.abs(orig))) / 127.0 * 1.01
    assert err <= bound


def test_migration_result_accounting(bd):
    src = bd.engines["hoststore0"]
    dst = bd.engines["hoststore1"]
    res = bd.migrator.migrate(src, "mimic2v26.d_patients", dst,
                              "dp_copy", MigrationParams(method="binary"))
    assert res.rows == 64
    assert res.bytes_moved > 0
    assert res.seconds >= 0


# -- catalog --------------------------------------------------------------------
def test_catalog_persistence_roundtrip(tmp_path, bd):
    path = str(tmp_path / "catalog.json")
    bd.catalog.save(path)
    from repro.core.catalog import Catalog
    loaded = Catalog.load(path)
    assert {e.name for e in loaded.engines.values()} \
        == {e.name for e in bd.catalog.engines.values()}
    assert len(loaded.objects) == len(bd.catalog.objects)
    assert loaded.engines_for_island("array")[0].name == "densehbm0"
