"""Property-based tests (hypothesis) on system invariants: BQL parsing,
relational-algebra laws, signature metric axioms, quantization bounds,
monitor plan selection, MoE dispatch conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bql, datamodel as dm, signatures
from repro.core.monitor import Monitor

_SET = settings(max_examples=40, deadline=None)

names = st.from_regex(r"[a-z][a-z0-9_]{0,8}", fullmatch=True)
small_ints = st.integers(min_value=0, max_value=100)


# -- BQL parser properties ----------------------------------------------------------
@_SET
@given(tbl=names, n=st.integers(1, 99))
def test_bql_island_roundtrip(tbl, n):
    q = f"bdrel(select * from {tbl} limit {n})"
    root = bql.parse(q)
    assert root.island == "relational"
    assert root.query == f"select * from {tbl} limit {n}"


@_SET
@given(tbl=names, obj=names, depth=st.integers(1, 4))
def test_bql_nested_cast_depth(tbl, obj, depth):
    q = f"bdrel(select a from {tbl})"
    for i in range(depth):
        island = "bdarray" if i % 2 == 0 else "bdrel"
        inner_q = f"scan(bdcast({q}, {obj}{i}, 's', x))" \
            if island == "bdarray" \
            else f"select a from bdcast({q}, {obj}{i}, 's', x)"
        q = f"{island}({inner_q})"
    root = bql.parse(q)
    seen = sum(1 for node in root.walk()
               if isinstance(node, bql.CastNode))
    assert seen == depth


# -- relational algebra laws ---------------------------------------------------------
@st.composite
def tables(draw):
    n = draw(st.integers(1, 30))
    a = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    b = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    return dm.Table({"a": jnp.asarray(a), "b": jnp.asarray(b)})


@_SET
@given(t=tables(), thresh=st.integers(-50, 50))
def test_filter_subset_and_idempotent(t, thresh):
    mask = t.columns["a"] > thresh
    f1 = t.filter(mask)
    assert f1.num_rows <= t.num_rows
    assert bool((f1.columns["a"] > thresh).all()) or f1.num_rows == 0
    f2 = f1.filter(f1.columns["a"] > thresh)
    assert f2.num_rows == f1.num_rows            # idempotent


@_SET
@given(t=tables())
def test_sort_is_ordered_permutation(t):
    s = t.sort_by("a")
    assert s.num_rows == t.num_rows
    arr = np.asarray(s.columns["a"])
    assert (np.diff(arr) >= 0).all()
    assert sorted(np.asarray(t.columns["a"]).tolist()) == arr.tolist()


@_SET
@given(t=tables())
def test_group_agg_sum_conservation(t):
    g = t.group_agg("b", "sum", "a")
    total = float(np.asarray(g.columns["sum_a"]).sum())
    assert total == float(np.asarray(t.columns["a"]).sum())


@_SET
@given(t=tables(), limit=st.integers(1, 40))
def test_limit_bounds(t, limit):
    l = t.limit(limit)
    assert l.num_rows == min(limit, t.num_rows)


# -- signature metric axioms -----------------------------------------------------------
_QUERIES = [
    "bdrel(select * from t1 limit 5)",
    "bdrel(select a, b from t2 where a > 3)",
    "bdarray(filter(arr1, dim1>10))",
    "bdarray(aggregate(arr2, avg(x)))",
    "bdtext({ 'op' : 'scan', 'table' : 'logs' })",
    "bdarray(scan(bdcast(bdrel(select a from t1), c1, 's', array)))",
]


@_SET
@given(i=st.integers(0, len(_QUERIES) - 1),
       j=st.integers(0, len(_QUERIES) - 1))
def test_signature_metric_axioms(i, j):
    si = signatures.of_query(bql.parse(_QUERIES[i]))
    sj = signatures.of_query(bql.parse(_QUERIES[j]))
    assert si.distance(si) == 0.0
    assert si.distance(sj) == sj.distance(si)
    assert si.distance(sj) >= 0.0
    if i == j:
        assert si.distance(sj) == 0.0


# -- monitor best-plan selection ---------------------------------------------------------
@_SET
@given(times=st.lists(st.floats(0.001, 10.0), min_size=2, max_size=6,
                      unique=True))
def test_monitor_picks_minimum(times):
    mon = Monitor()
    sig = signatures.of_query(bql.parse(_QUERIES[0]))
    for idx, t in enumerate(times):
        mon.add_measurement(sig, f"qep{idx}", t)
    best = mon.best_qep(sig)
    assert best == f"qep{int(np.argmin(times))}"


# -- quantization bound -------------------------------------------------------------------
@_SET
@given(data=st.lists(st.floats(-1e3, 1e3, allow_nan=False,
                               allow_infinity=False, width=32),
                     min_size=1, max_size=512))
def test_quant_error_bound_holds(data):
    from repro.kernels.quant_cast import ops
    x = jnp.asarray(np.asarray(data, np.float32))
    q, scale = ops.quantize(x)
    back = ops.dequantize(q, scale, x.shape)
    # per-block error bound: half a quantization step (+ fp slack)
    per_block_bound = np.asarray(scale).max() * 0.5 + 1e-5
    assert float(jnp.max(jnp.abs(back - x))) <= per_block_bound * 1.01


# -- MoE dispatch conservation ---------------------------------------------------------------
@_SET
@given(seed=st.integers(0, 2 ** 16), cap=st.floats(0.5, 4.0))
def test_moe_dispatch_conservation(seed, cap):
    """With enough capacity every token-slot lands exactly once; output is
    a convex combination (gates sum to 1) of expert outputs."""
    import dataclasses
    from repro.models import moe
    from repro.models.config import ModelConfig
    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8,
        num_experts=4, top_k=2, moe_d_ff=32, capacity_factor=float(cap))
    rng = np.random.default_rng(seed)
    params = {
        "router": jnp.asarray(rng.standard_normal((16, 4)), jnp.float32),
        "wi_gate": jnp.asarray(rng.standard_normal((4, 16, 32)) * 0.1,
                               jnp.float32),
        "wi_up": jnp.asarray(rng.standard_normal((4, 16, 32)) * 0.1,
                             jnp.float32),
        "wo": jnp.asarray(rng.standard_normal((4, 32, 16)) * 0.1,
                          jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    out, aux = moe.apply_moe(params, x, cfg, None)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.99                   # Switch aux >= 1 at optimum
    if cap >= 2.0:
        # full capacity: compare against dense per-token reference
        xt = x.reshape(-1, 16)
        logits = xt @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        gate, eid = jax.lax.top_k(probs, 2)
        gate = gate / gate.sum(-1, keepdims=True)
        outs = []
        for t in range(xt.shape[0]):
            acc = jnp.zeros(16)
            for j in range(2):
                e = int(eid[t, j])
                h = jax.nn.silu(xt[t] @ params["wi_gate"][e]) \
                    * (xt[t] @ params["wi_up"][e])
                acc = acc + gate[t, j] * (h @ params["wo"][e])
            outs.append(acc)
        want = jnp.stack(outs).reshape(2, 8, 16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-4, rtol=2e-3)
