"""Serving front-door tests: admission control (capacity caps + the
Monitor-fed load circuit breaker), per-subscription backpressure, the
house bit-identity invariant extended to the serving tier (results via
the front door ≡ direct ``register_continuous``), plan-cache warm
sharing across tenants, replica fan-out reads caught up from the
segment log, and the Scheduler /metrics double-close regression."""
import types

import numpy as np
import pytest

from repro.core.api import default_deployment
from repro.serve.engine import ServeConfig, Scheduler
from repro.serve.frontdoor import AdmissionError, FrontDoor
from repro.stream import durability as dur
from repro.stream.spec import Durability, Sharding, StreamSpec

AVG_Q = "bdstream(aggregate(window(fd.s, 8), avg(v)))"


def _door(bd=None, **kwargs):
    bd = bd or default_deployment()
    kwargs.setdefault("stream_engine", "streamstore0")
    cfg = kwargs.pop("config", ServeConfig(streams=(
        StreamSpec("fd.s", ("ts", "v"), capacity=128),)))
    return bd, FrontDoor(bd, cfg, **kwargs)


def _feed(bd, stream, n=8, base=0.0):
    stream.append({"ts": np.arange(float(n)) + base,
                   "v": np.arange(float(n)) + base})
    return bd.streams.tick()


# -- session & subscription lifecycle -----------------------------------------

def test_config_streams_are_registered_via_specs():
    bd, door = _door()
    stream = bd.engines["streamstore0"].get("fd.s")
    assert stream.capacity == 128
    assert stream.spec == door.config.streams[0]


def test_serve_config_rejects_non_spec_streams():
    bd = default_deployment()
    with pytest.raises(TypeError):
        FrontDoor(bd, ServeConfig(streams=({"name": "x"},)),
                  stream_engine="streamstore0")


def test_open_session_is_idempotent_per_tenant():
    _, door = _door()
    assert door.open_session("a") is door.open_session("a")
    assert door.stats()["tenants"] == 1


def test_close_session_releases_query_and_capacity():
    bd, door = _door(max_tenants=1)
    session = door.open_session("a")
    session.subscribe(AVG_Q)
    assert door.stats()["shared_queries"] == 1
    session.close()
    assert door.stats()["tenants"] == 0
    assert door.stats()["shared_queries"] == 0
    assert not bd.streams.queries          # CQ deregistered
    door.open_session("b")                 # capacity freed


# -- admission control --------------------------------------------------------

def test_admission_rejects_over_max_tenants():
    _, door = _door(max_tenants=2)
    door.open_session("a")
    door.open_session("b")
    with pytest.raises(AdmissionError, match="max_tenants"):
        door.open_session("c")
    assert door.stats()["admission_rejects"] == 1


def test_admission_rejects_over_per_tenant_queries():
    _, door = _door(max_queries_per_tenant=1)
    session = door.open_session("a")
    session.subscribe(AVG_Q)
    with pytest.raises(AdmissionError, match="max_queries_per_tenant"):
        session.subscribe(AVG_Q, every_n_ticks=2)


def test_load_circuit_breaker_from_monitor_drops():
    """The breaker is fed by Monitor.stream_stats: once the standing
    queries have visibly lost rows to ring overflow, new admissions
    are refused until the operator re-arms."""
    bd = default_deployment()
    bd, door = _door(bd, config=ServeConfig(streams=(
        StreamSpec("fd.s", ("ts", "v"), capacity=4, rolling=False),)),
        admit_max_dropped=0)
    session = door.open_session("a")
    session.subscribe("bdstream(snapshot(fd.s))")
    stream = bd.engines["streamstore0"].get("fd.s")
    stream.append({"ts": np.arange(16.), "v": np.arange(16.)})
    bd.streams.tick()                      # stream_stats sees the drops
    with pytest.raises(AdmissionError, match="dropped"):
        door.open_session("b")
    with pytest.raises(AdmissionError, match="dropped"):
        session.subscribe("bdstream(rate(fd.s))")
    door.reset_admission()                 # incident over
    door.open_session("b")


# -- backpressure -------------------------------------------------------------

def test_slow_consumer_drops_oldest_results_only():
    bd, door = _door(result_buffer=3)
    sub = door.open_session("a").subscribe(AVG_Q)
    stream = bd.engines["streamstore0"].get("fd.s")
    for i in range(5):
        _feed(bd, stream, base=8.0 * i)
    assert sub.pending == 3 and sub.dropped == 2
    results = sub.poll()
    # the *newest* three survived, in order
    assert [tick for tick, _ in results] == [3, 4, 5]
    assert door.stats()["results_dropped"] == 2
    assert sub.poll() == []                # drained


# -- bit-identity & warm sharing ----------------------------------------------

def test_front_door_results_bit_identical_to_direct():
    """The house invariant, extended: every result a tenant receives
    through the front door is bitwise equal to what a directly
    registered continuous query produces for the same BQL and ticks."""
    bd, door = _door()
    sub_a = door.open_session("a").subscribe(AVG_Q)
    sub_b = door.open_session("b").subscribe(AVG_Q)
    direct = bd.streams.register_continuous(AVG_Q, name="direct")
    stream = bd.engines["streamstore0"].get("fd.s")
    direct_values = []
    for i in range(4):
        ran = dict(_feed(bd, stream, base=8.0 * i))
        direct_values.append(np.asarray(
            next(iter(ran["direct"].value.attrs.values()))))
    for sub in (sub_a, sub_b):
        got = sub.poll()
        assert len(got) == 4
        for (tick, value), want in zip(got, direct_values):
            have = np.asarray(next(iter(value.attrs.values())))
            assert have.tobytes() == want.tobytes()


def test_identical_subscriptions_share_one_execution():
    bd, door = _door()
    subs = [door.open_session(f"t{i}").subscribe(AVG_Q)
            for i in range(4)]
    assert door.stats()["shared_queries"] == 1
    assert door.stats()["shared_attaches"] == 3
    stream = bd.engines["streamstore0"].get("fd.s")
    _feed(bd, stream)
    _feed(bd, stream, base=8.0)
    (cq,) = bd.streams.queries.values()
    assert cq.executions == 2              # one per tick, not per tenant
    assert cq.cache_hits >= 1              # warm plan cache after tick 1
    assert all(len(s.poll()) == 2 for s in subs)
    # a different cadence is a different execution
    door.open_session("t0").subscribe(AVG_Q, every_n_ticks=2)
    assert door.stats()["shared_queries"] == 2


def test_close_stops_fanout_and_deregisters():
    bd, door = _door()
    sub = door.open_session("a").subscribe(AVG_Q)
    stream = bd.engines["streamstore0"].get("fd.s")
    _feed(bd, stream)
    door.close()
    door.close()                           # idempotent
    _feed(bd, stream, base=8.0)
    assert len(sub.poll()) == 1            # nothing delivered post-close
    assert not bd.streams.queries


# -- replica fan-out ----------------------------------------------------------

def test_replica_copy_leaves_primary_and_serves_reads(tmp_path):
    bd = default_deployment()
    bd, door = _door(bd, config=ServeConfig(streams=(
        StreamSpec("fd.s", ("ts", "v"), capacity=128,
                   sharding=Sharding(shards=2),
                   durability=Durability(str(tmp_path))),)))
    stream = bd.engines["streamstore0"].get("fd.s")
    stream.append({"ts": np.arange(16.), "v": np.arange(16.)})
    (rname,) = door.replicate("fd.s", n=1)
    assert rname == "fd.s.replica0"
    assert bd.engines["streamstore0"].get("fd.s") is stream
    # replica serves the window read, bit-identical to the primary
    session = door.open_session("a")
    got = session.read("fd.s", 4)
    want = stream.window(4)
    assert np.asarray(got.attrs["v"]).tobytes() == \
        np.asarray(want.attrs["v"]).tobytes()


def test_replica_catch_up_from_segment_log(tmp_path):
    bd = default_deployment()
    bd, door = _door(bd, config=ServeConfig(streams=(
        StreamSpec("fd.s", ("ts", "v"), capacity=256,
                   sharding=Sharding(shards=2, block_rows=8),
                   durability=Durability(str(tmp_path))),)))
    primary = bd.engines["streamstore0"].get("fd.s")
    primary.append({"ts": np.arange(24.), "v": np.arange(24.)})
    door.replicate("fd.s", n=2)
    # primary moves on; replicas are stale until refreshed
    primary.append({"ts": np.arange(24., 48.), "v": np.arange(24., 48.)})
    rows = door.refresh_replicas("fd.s")
    assert set(rows) == {"fd.s.replica0", "fd.s.replica1"}
    assert all(n == 24 for n in rows.values())

    def denamed(fp):
        fp = dict(fp)
        fp.pop("name", None)
        if "shards" in fp:
            fp["shards"] = [dict(d, name=None) for d in fp["shards"]]
        return fp

    want = denamed(dur.fingerprint(primary))
    for i in range(2):
        replica = None
        for ename, engine in bd.engines.items():
            from repro.stream.engine import StreamEngine
            if isinstance(engine, StreamEngine) \
                    and engine.has(f"fd.s.replica{i}"):
                replica = engine.get(f"fd.s.replica{i}")
        assert denamed(dur.fingerprint(replica)) == want
    # refresh again: incremental, nothing to replay
    assert all(n == 0 for n in door.refresh_replicas("fd.s").values())


def test_refresh_replicas_requires_durability():
    bd, door = _door()
    stream = bd.engines["streamstore0"].get("fd.s")
    stream.append({"ts": np.arange(8.), "v": np.arange(8.)})
    door.replicate("fd.s", n=1)
    with pytest.raises(AdmissionError, match="durability"):
        door.refresh_replicas("fd.s")


# -- serve stats surfacing ----------------------------------------------------

def test_serve_stats_flow_to_monitor_and_admin_status():
    from repro.core import admin
    bd, door = _door()
    door.open_session("a").subscribe(AVG_Q)
    stream = bd.engines["streamstore0"].get("fd.s")
    _feed(bd, stream)
    snap = bd.monitor.snapshot()["serve_stats"]
    assert snap["tenants"] == 1 and snap["results_delivered"] == 1
    st = admin.status(bd)
    assert st["serve"]["subscriptions"] == 1
    assert st["serve"]["p99_tick_ms"] >= 0.0


# -- Scheduler close: idempotent + atexit -------------------------------------

def test_scheduler_close_is_idempotent_and_releases_port():
    import socket

    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    port = sock.getsockname()[1]
    sock.close()
    session = types.SimpleNamespace(
        scfg=ServeConfig(metrics_port=port))
    sched = Scheduler(session)
    assert sched._metrics_server is not None
    sched.close()
    sched.close()                          # the regression: second close
    sched.close()                          # must be a no-op, not a hang
    assert sched._metrics_server is None
    # socket actually released: we can bind the port again immediately
    probe = socket.socket()
    probe.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    probe.bind(("127.0.0.1", port))
    probe.close()


def test_scheduler_close_unregisters_atexit_hook():
    import atexit

    session = types.SimpleNamespace(scfg=ServeConfig(metrics_port=0))
    # metrics_port=0 binds an ephemeral port (start_http_server treats
    # 0 as "any"); a Scheduler without a port registers no hook
    none_session = types.SimpleNamespace(
        scfg=ServeConfig(metrics_port=None))
    sched_none = Scheduler(none_session)
    sched_none.close()                     # idempotent without a server
    sched_none.close()
    sched = Scheduler(session)
    sched.close()
    # re-registering after close must not resurrect the old server
    assert sched._metrics_server is None
    atexit.unregister(sched.close)         # harmless either way
