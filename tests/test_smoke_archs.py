"""Per-architecture smoke tests (deliverable (f)): every assigned arch
instantiates a REDUCED config of the same family and runs one forward +
one train step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.sharding import logical as L
from repro.train.step import TrainConfig, init_train_state, make_train_step

ARCHS = list(registry.ARCH_NAMES)
SEQ, BATCH = 32, 2


@pytest.fixture(scope="module")
def states():
    return {}


def _state_for(name):
    cfg = registry.get_config(name, reduced=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    return cfg, state


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name):
    cfg, state = _state_for(name)
    batch = registry.make_train_batch(cfg, SEQ, BATCH)
    logits, aux = registry.forward(state["params"], batch, cfg, None)
    s_text = registry.text_len(cfg, SEQ)
    total = SEQ if cfg.frontend != "vision" else SEQ
    assert logits.shape == (BATCH, total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{name}: non-finite aux"


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_updates_and_finite(name):
    cfg, state = _state_for(name)
    tcfg = TrainConfig(optimizer=AdamWConfig(total_steps=10,
                                             warmup_steps=2))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = jax.tree.map(jnp.asarray,
                         registry.make_train_batch(cfg, SEQ, BATCH))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # at least one parameter changed
    before = jax.tree.leaves(state["params"])
    after = jax.tree.leaves(new_state["params"])
    changed = any(bool(jnp.any(a != b)) for a, b in zip(before, after))
    assert changed, f"{name}: no parameter update"
    assert int(new_state["opt"]["step"]) == 1


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_nonzero_and_spec_axes(name):
    cfg = registry.get_config(name, reduced=True)
    specs = registry.param_specs(cfg)
    n = L.count_params(specs)
    assert n > 1000
    for leaf in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, L.ParamSpec)):
        assert len(leaf.shape) == len(leaf.axes)


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The FULL configs carry the exact published hyper-parameters."""
    cfg = registry.get_config(name)
    expected = {
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (name, got, expected)


def test_moe_details():
    olmoe = registry.get_config("olmoe-1b-7b")
    assert (olmoe.num_experts, olmoe.top_k) == (64, 8)
    dsm = registry.get_config("deepseek-moe-16b")
    assert (dsm.num_experts, dsm.top_k, dsm.num_shared_experts) == (64, 6, 2)
    jamba = registry.get_config("jamba-v0.1-52b")
    assert (jamba.num_experts, jamba.top_k) == (16, 2)
    assert jamba.layer_plan()[4][0] == "attn"       # 1:7 attn interleave
    assert sum(m == "attn" for m, _ in jamba.layer_plan()) == 1
    assert sum(f == "moe" for _, f in jamba.layer_plan()) == 4


def test_long_context_applicability():
    from repro.configs.shapes import SHAPES, applicable
    long = SHAPES["long_500k"]
    runnable = [n for n in ARCHS
                if applicable(registry.get_config(n), long)[0]]
    assert sorted(runnable) == ["jamba-v0.1-52b", "rwkv6-7b"]
