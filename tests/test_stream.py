"""Streaming island tests (paper §III; arXiv:1609.07548 S-Store member):
ring-buffer semantics, window views, island ops through the Query
Endpoint, cast routes into the array/relational islands, the
continuous-query runtime (incl. the acceptance criterion: >=20 ticks,
bit-identical to batch, 2nd+ ticks hitting the plan cache), bounded
engine op logs, and the Monitor cost-model early cancel."""
import numpy as np
import pytest

from repro.core import admin, bql, islands, signatures
from repro.core.api import default_deployment
from repro.data.mimic import load_mimic_demo, stream_mimic_waveforms
from repro.stream.engine import Stream, StreamEngine, StreamException

WINDOW_CQ = ("bdarray(aggregate(bdcast(bdstream(window("
             "mimic2v26.waveform_stream, 32)), w_arr,"
             " '<signal:double>[tick=0:31,32,0]', array), avg(signal)))")


# -- ring buffer --------------------------------------------------------------
def test_stream_append_and_snapshot_order():
    s = Stream("s", ("x",), capacity=8)
    s.append({"x": [1.0, 2.0, 3.0]})
    s.append({"x": [4.0, 5.0]})
    snap = s.snapshot()
    np.testing.assert_array_equal(np.asarray(snap.columns["x"]),
                                  [1, 2, 3, 4, 5])
    np.testing.assert_array_equal(np.asarray(snap.columns["seq"]),
                                  [0, 1, 2, 3, 4])


def test_stream_ring_overflow_drops_oldest():
    s = Stream("s", ("x",), capacity=4)
    s.append({"x": [0.0, 1.0, 2.0]})
    s.append({"x": [3.0, 4.0, 5.0]})          # overwrites seq 0,1
    assert s.total_appended == 6 and s.total_dropped == 2
    snap = s.snapshot()
    np.testing.assert_array_equal(np.asarray(snap.columns["x"]),
                                  [2, 3, 4, 5])
    np.testing.assert_array_equal(np.asarray(snap.columns["seq"]),
                                  [2, 3, 4, 5])


def test_stream_batch_larger_than_capacity_keeps_tail():
    s = Stream("s", ("x",), capacity=4)
    s.append({"x": [1.0]})
    s.append({"x": list(range(10))})
    assert s.total_dropped == 7               # 1 buffered + 6 of the batch
    np.testing.assert_array_equal(
        np.asarray(s.snapshot().columns["x"]), [6, 7, 8, 9])


def test_stream_field_mismatch_raises():
    s = Stream("s", ("x", "y"), capacity=4)
    with pytest.raises(StreamException):
        s.append({"x": [1.0]})
    with pytest.raises(StreamException):
        s.append({"x": [1.0], "y": [1.0, 2.0]})   # ragged


# -- windows ------------------------------------------------------------------
def test_tumbling_window_is_seq_aligned():
    s = Stream("s", ("x",), capacity=64)
    s.append({"x": np.arange(10, dtype=float)})
    w = s.window(4)                     # windows [0,4),[4,8); last = [4,8)
    assert w.dim_names == ("tick",)
    np.testing.assert_array_equal(np.asarray(w.attrs["x"]), [4, 5, 6, 7])
    s.append({"x": np.arange(10, 14, dtype=float)})
    np.testing.assert_array_equal(                 # now [8,12) is complete
        np.asarray(s.window(4).attrs["x"]), [8, 9, 10, 11])


def test_tumbling_window_unavailable_raises():
    s = Stream("s", ("x",), capacity=8)
    s.append({"x": [1.0, 2.0]})
    with pytest.raises(StreamException):
        s.window(4)                     # no complete window yet
    s2 = Stream("s2", ("x",), capacity=4)
    s2.append({"x": np.arange(16, dtype=float)})
    with pytest.raises(StreamException):
        s2.window(8)                    # complete but already evicted


def test_sliding_window_stacks():
    s = Stream("s", ("x",), capacity=16)
    s.append({"x": np.arange(8, dtype=float)})
    w = s.window(4, 2)
    assert w.dim_names == ("window", "tick")
    np.testing.assert_array_equal(
        np.asarray(w.attrs["x"]),
        [[0, 1, 2, 3], [2, 3, 4, 5], [4, 5, 6, 7]])


# -- island ops through the Query Endpoint ------------------------------------
@pytest.fixture()
def bd():
    bd = default_deployment()
    load_mimic_demo(bd, num_patients=16, num_orders=32, wave_len=128,
                    num_logs=8)
    return bd


def test_streaming_island_registered(bd):
    assert "streaming" in islands.ISLANDS
    eng = bd.catalog.engines_for_island("streaming")
    assert [e.name for e in eng] == ["streamstore0"]
    assert isinstance(bd.engines["streamstore0"], StreamEngine)


def test_streaming_ops_via_bql(bd):
    bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                       capacity=64)
    r = bd.query("bdstream(append(vitals.stream,"
                 " '[{\"hr\": 60.0}, {\"hr\": 80.0}]'))")
    assert float(r.value.columns["appended"][0]) == 2.0
    snap = bd.query("bdstream(snapshot(vitals.stream))").value
    assert islands.validate_result("streaming", snap)
    np.testing.assert_array_equal(np.asarray(snap.columns["hr"]), [60, 80])
    agg = bd.query("bdstream(aggregate(window(vitals.stream, 2),"
                   " avg(hr)))").value
    assert islands.validate_result("streaming", agg)
    assert float(agg.attrs["avg_hr"][0]) == pytest.approx(70.0)
    rate = bd.query("bdstream(rate(vitals.stream))").value
    assert float(rate.columns["appended"][0]) == 2.0


def test_window_casts_binary_to_array_and_staged_to_table(bd):
    stream = bd.register_stream("streamstore0", "vitals.stream",
                                ("hr",), capacity=64)
    stream.append({"hr": np.arange(8, dtype=float)})
    r = bd.query("bdarray(aggregate(bdcast(bdstream(window("
                 "vitals.stream, 8)), w_arr,"
                 " '<hr:double>[tick=0:7,8,0]', array), max(hr)))")
    assert float(r.value.attrs["max_hr"][0]) == 7.0
    # staged route: the window's dims become relational columns
    r = bd.query("bdrel(select tick, hr from bdcast(bdstream(window("
                 "vitals.stream, 8)), w_tbl, '', relational)"
                 " where hr >= 6)")
    np.testing.assert_array_equal(np.asarray(r.value.columns["hr"]),
                                  [6, 7])
    np.testing.assert_array_equal(np.asarray(r.value.columns["tick"]),
                                  [6, 7])


# -- continuous queries -------------------------------------------------------
def test_continuous_query_cadence_and_registration(bd):
    cq2 = bd.register_continuous("bdstream(rate(mimic2v26."
                                 "waveform_stream))", every_n_ticks=3)
    with pytest.raises(ValueError):
        bd.register_continuous("not bql at all")
    with pytest.raises(ValueError):
        bd.register_continuous("bdstream(rate(x))", name=cq2.name)
    bd.register_stream("streamstore0", "mimic2v26.waveform_stream",
                       ("signal", "hr"), capacity=64)
    bd.engines["streamstore0"].get("mimic2v26.waveform_stream").append(
        {"signal": [0.5], "hr": [70.0]})
    for _ in range(7):
        bd.streams.tick()
    assert bd.streams.ticks == 7
    assert cq2.executions == 2                 # ticks 3 and 6


def test_continuous_query_acceptance_20_ticks(bd):
    """Acceptance criterion: a standing query over the MIMIC waveform
    stream runs >= 20 ticks bit-identical to the same BQL re-run as a
    batch query on the snapshot, with 2nd+ ticks hitting the plan cache
    (verified via the cache hit counter in admin.status())."""
    cq = bd.register_continuous(WINDOW_CQ, every_n_ticks=1,
                                name="wave_avg")
    hits_before = admin.status(bd)["plan_cache"]["hits"]
    ticks = 0
    for info in stream_mimic_waveforms(bd, batch_rows=32, num_batches=22,
                                       capacity=2048):
        ticks += 1
        assert info["ran"][0][0] == "wave_avg"
        # batch re-run of the identical BQL on the current snapshot
        batch = bd.query(WINDOW_CQ)
        np.testing.assert_array_equal(
            np.asarray(cq.last_value.attrs["avg_signal"]),
            np.asarray(batch.value.attrs["avg_signal"]))
    assert ticks >= 20 and cq.executions == ticks
    assert cq.cache_hits == cq.executions - 1      # all 2nd+ ticks hit
    status = admin.status(bd)
    assert status["plan_cache"]["hits"] - hits_before \
        >= 2 * ticks - 1                           # CQ ticks + batch runs
    # metrics surfaced through the admin streams section + Monitor
    m = status["streams"]["queries"]["wave_avg"]
    assert m["executions"] == ticks
    assert m["cache_hits"] == ticks - 1
    assert "wave_avg" in status["streams"]["monitor_ewma_ms"]
    assert status["streams"]["streams"][
        "mimic2v26.waveform_stream"]["appended"] == 32 * ticks


def test_tick_isolates_failing_queries(bd):
    """A standing query whose window isn't complete yet must not crash
    the tick, the feed loop, or the other standing queries."""
    bd.register_stream("streamstore0", "vitals.stream", ("hr",),
                       capacity=256)
    stream = bd.engines["streamstore0"].get("vitals.stream")
    big = bd.register_continuous(
        "bdstream(aggregate(window(vitals.stream, 128), avg(hr)))",
        name="big_window")
    ok = bd.register_continuous("bdstream(snapshot(vitals.stream))",
                                name="snap")
    stream.append({"hr": np.arange(64, dtype=float)})
    ran = bd.streams.tick()                    # big_window fails, snap runs
    assert [n for n, _ in ran] == ["snap"]
    assert big.errors == 1 and big.executions == 0
    assert "no complete window" in big.last_error
    assert ok.executions == 1
    stream.append({"hr": np.arange(64, dtype=float)})
    bd.streams.tick()                          # 128 rows: both succeed now
    assert big.errors == 1 and big.executions == 1
    assert bd.streams.status()["queries"]["big_window"]["errors"] == 1


def test_transient_stream_error_keeps_cached_plan(bd):
    """An evicted tumbling window raises without evicting the cached
    plan — the next healthy tick is still a plan-cache hit."""
    from repro.core.executor import LocalQueryExecutionException
    bd.register_stream("streamstore0", "ring.stream", ("x",), capacity=16)
    stream = bd.engines["streamstore0"].get("ring.stream")
    q = "bdstream(window(ring.stream, 16))"
    stream.append({"x": np.arange(16, dtype=float)})
    assert not bd.query(q).plan_cache_hit          # miss: plan now cached
    stream.append({"x": np.arange(8, dtype=float)})
    # window [0,16) is the latest complete one but its head was evicted
    with pytest.raises(LocalQueryExecutionException):
        bd.query(q)
    stream.append({"x": np.arange(8, dtype=float)})    # [16,32) complete
    r = bd.query(q)
    assert r.plan_cache_hit                        # plan survived the error


def test_memoized_window_aggregate_survives_eviction(bd):
    """The rolling fast path keeps the latest complete window's aggregate
    after the ring evicts the raw rows (the value is already folded), so
    the standing query keeps its answer; an *uncached* aggregate over the
    same evicted window still raises — no silent partial windows."""
    from repro.core.executor import LocalQueryExecutionException
    bd.register_stream("streamstore0", "ring.stream", ("x",), capacity=16)
    stream = bd.engines["streamstore0"].get("ring.stream")
    q = "bdstream(aggregate(window(ring.stream, 16), sum(x)))"
    stream.append({"x": np.arange(16, dtype=float)})
    first = bd.query(q)
    assert float(first.value.attrs["sum_x"][0]) == float(np.arange(16).sum())
    stream.append({"x": np.arange(8, dtype=float)})    # evicts [0,8)
    r = bd.query(q)                    # memoized: same window, same value
    assert float(r.value.attrs["sum_x"][0]) == \
        float(first.value.attrs["sum_x"][0])
    with pytest.raises(LocalQueryExecutionException):  # not memoized
        bd.query("bdstream(aggregate(window(ring.stream, 16), max(x)))")


def test_drops_charged_only_to_streams_the_query_reads(bd):
    bd.register_stream("streamstore0", "stable.stream", ("x",),
                       capacity=64)
    bd.register_stream("streamstore0", "lossy.stream", ("x",), capacity=4)
    cq = bd.register_continuous("bdstream(snapshot(stable.stream))",
                                name="stable_snap")
    bd.engines["streamstore0"].get("stable.stream").append(
        {"x": [1.0, 2.0]})
    bd.engines["streamstore0"].get("lossy.stream").append(
        {"x": np.arange(20, dtype=float)})         # drops 16 on lossy
    bd.streams.tick()
    assert cq.executions == 1
    assert cq.drops_seen == 0                      # lossy's loss isn't ours


def test_continuous_query_counts_drops_between_executions(bd):
    bd.register_stream("streamstore0", "tiny.stream", ("x",), capacity=4)
    stream = bd.engines["streamstore0"].get("tiny.stream")
    cq = bd.register_continuous("bdstream(snapshot(tiny.stream))",
                                every_n_ticks=2, name="snap")
    stream.append({"x": np.arange(6, dtype=float)})    # drops 2
    bd.streams.tick()                                  # not due
    bd.streams.tick()                                  # due: sees 2 drops
    assert cq.executions == 1 and cq.drops_seen == 2
    stream.append({"x": np.arange(4, dtype=float)})    # drops 4 more
    bd.streams.tick()
    bd.streams.tick()
    assert cq.drops_seen == 6


# -- signatures ---------------------------------------------------------------
def test_streaming_signature_counts_ops():
    sig = signatures.of_query(bql.parse(WINDOW_CQ))
    ops = dict(sig.ops)
    assert ops.get("window") == 1 and ops.get("aggregate") == 1
    assert "mimic2v26.waveform_stream" in sig.objects
    assert sig.num_casts == 1
    assert sorted(sig.islands) == ["array", "streaming"]


# -- bounded op logs (satellite) ----------------------------------------------
def test_op_log_is_bounded_and_resettable():
    from repro.core.engines import HostStoreEngine
    eng = HostStoreEngine("h")
    n = eng.OP_LOG_LIMIT + 1000
    for i in range(n):
        eng.record("op", float(i))
    assert len(eng.op_log) == eng.OP_LOG_LIMIT     # bounded ring buffer
    assert eng.ops_recorded == n                   # lifetime count intact
    assert eng.recent_ops(3) == [("op", float(i))
                                 for i in (n - 3, n - 2, n - 1)]
    assert eng.reset_op_log() == eng.OP_LOG_LIMIT
    assert len(eng.op_log) == 0 and eng.ops_recorded == n


def test_monitoring_refresh_reads_bounded_log(bd):
    task = bd.start_monitoring(interval_seconds=1e9)
    bd.engines["hoststore0"].record("x", 0.01)
    task.tick()                                # must not raise on deques
    assert bd.monitor.engine_ewma.get("hoststore0") is not None


# -- cost-model early cancel (satellite) --------------------------------------
def _training_query():
    # poe_order lives on both hoststore0 and hoststore1 -> >= 2 plans
    return ("bdarray(scan(bdcast(bdrel(select poe_id, dose from"
            " mimic2v26.poe_order), dose_copy,"
            " '<dose:double>[poe_id=0:*,1000,0]', array)))")


def test_cost_model_cancel_skips_known_slow_plans(bd):
    q = _training_query()
    root = bql.parse(q)
    sig = signatures.of_query(root)
    plans = bd.planner.enumerate_plans(root)
    assert len(plans) >= 2
    bd.monitor.add_measurement(sig, plans[0].qep_id, 1e-4)
    for p in plans[1:]:
        bd.monitor.add_measurement(sig, p.qep_id, 30.0)
    before = bd.planner.cost_model_cancels
    r = bd.query(q, training=True)
    assert bd.planner.cost_model_cancels - before == len(plans) - 1
    assert r.qep_id == plans[0].qep_id
    # cancelled plans never ran: their measurement count is still 1
    perf = bd.monitor.get_benchmark_performance(sig)
    for p in plans[1:]:
        assert len(perf[p.qep_id]) == 1
    assert admin.status(bd)["concurrency"]["cost_model_cancels"] \
        == bd.planner.cost_model_cancels


def test_cost_model_cancel_reprobes_after_streak(bd):
    """A stale estimate must not blacklist a QEP forever: after
    ``cost_cancel_reprobe`` consecutive cancels the plan runs once and
    refreshes its Monitor estimate."""
    q = _training_query()
    root = bql.parse(q)
    sig = signatures.of_query(root)
    plans = bd.planner.enumerate_plans(root)
    assert len(plans) >= 2
    bd.monitor.add_measurement(sig, plans[0].qep_id, 1e-4)
    slow = plans[1]
    bd.monitor.add_measurement(sig, slow.qep_id, 30.0)
    reprobe = bd.planner.config.cost_cancel_reprobe
    for _ in range(reprobe):               # cancelled on each of these
        bd.monitor.engine_ewma.clear()     # keep enumeration stable
        bd.query(q, training=True)
        assert len(bd.monitor.get_benchmark_performance(sig)
                   [slow.qep_id]) == 1
    bd.monitor.engine_ewma.clear()
    bd.query(q, training=True)             # streak exceeded: re-probed
    assert len(bd.monitor.get_benchmark_performance(sig)
               [slow.qep_id]) == 2


def test_cost_model_cancel_spares_unestimated_plans(bd):
    q = _training_query().replace("dose_copy", "dose_copy2")
    root = bql.parse(q)
    sig = signatures.of_query(root)
    plans = bd.planner.enumerate_plans(root)
    assert len(plans) >= 2
    # only one plan has history: the rest must still run (exploration)
    bd.monitor.add_measurement(sig, plans[0].qep_id, 1e-4)
    before = bd.planner.cost_model_cancels
    bd.query(q, training=True)
    assert bd.planner.cost_model_cancels == before
    perf = bd.monitor.get_benchmark_performance(sig)
    assert sum(1 for v in perf.values() if v) >= 2
