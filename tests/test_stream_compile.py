"""The compiled standing-query path (repro.stream.compile): every op
the jaxpr plan compiler lowers — tumbling/sliding windows, event-time
windows, rolling aggregates, the banded interval join — must be
**bit-identical** to the interpreter in shim.py: same values, same
dtypes, same column order, same error strings, same JOIN_STATS deltas.
That is the house invariant the jit-parity CI lane enforces; these
tests are its unit-level teeth.

Also covered: the plan cache (second execution is a cache hit, not a
recompile), the fallback taxonomy (out-of-family ops bump
``interpreted``, uncompilable family ops bump ``fallbacks`` with a
reason), x64 hygiene (the compiled path must not flip the global
``jax_enable_x64`` switch), and the Pallas kernels against their jnp
references and numpy.

Skips cleanly when jax is missing (the compiled path itself must also
*fall back* cleanly then — covered by test_backend_jit_without_jax)."""
import numpy as np
import pytest

from repro.core.api import default_deployment
from repro.stream import compile as qc
from repro.stream import kernels
from repro.stream.engine import StreamException


@pytest.fixture(autouse=True)
def _fresh_stats():
    qc.reset_stats()
    yield
    qc.reset_stats()


def _deploy(rng):
    """One deployment with the full op-family zoo: a plain stream, an
    event-time stream, and a 2-shard colocated event-time pair."""
    bd = default_deployment()
    p = bd.register_stream("streamstore0", "c.p", ("v", "w"),
                           capacity=256)
    s = bd.register_stream("streamstore0", "c.s", ("ts", "x"),
                           capacity=256, ts_field="ts", max_delay=0.0)
    a = bd.register_stream("streamstore0", "c.a", ("ts", "x"),
                           capacity=256, ts_field="ts", max_delay=0.0,
                           shards=2, num_engines=2)
    b = bd.register_stream("streamstore0", "c.b", ("ts", "y"),
                           capacity=256, ts_field="ts", max_delay=0.0,
                           shards=2, num_engines=2)
    n = 96
    p.append({"v": rng.normal(size=n), "w": rng.normal(size=n)})
    ts = np.sort(rng.uniform(0, 50, size=n))
    s.append({"ts": ts, "x": rng.normal(size=n)})
    s.flush()
    a.append({"ts": ts, "x": rng.normal(size=n)})
    b.append({"ts": ts + rng.uniform(-0.2, 0.2, size=n),
              "y": rng.normal(size=n)})
    a.flush()
    b.flush()
    return bd


# every family shape the compiler claims; parity must be *bitwise*
_FAMILY = [
    "window(c.p, 32)",
    "window(c.p, 32, 8)",
    "ewindow(c.s, 10, 5)",
    "aggregate(window(c.p, 16), sum(v))",
    "aggregate(window(c.p, 16), avg(v))",
    "aggregate(window(c.p, 16), min(v))",
    "aggregate(window(c.p, 16), max(v))",
    "aggregate(window(c.p, 16), count(*))",
    "aggregate(window(c.p, 32, 8), max(w))",
    "aggregate(ewindow(c.s, 10, 5), sum(x))",
    "join(ewindow(c.s, 20, 10), ewindow(c.s, 20, 10), on=ts, tol=0.5)",
    "join(ewindow(c.a, 20, 10), ewindow(c.b, 20, 10),"
    " on=ts, tol=0.25)",
]


def _run(bd, query, backend, monkeypatch):
    monkeypatch.setenv(qc.BACKEND_ENV, backend)
    return bd.query(f"bdstream({query})").value


def _assert_identical(ref, got, query):
    assert type(ref) is type(got), query
    r_cols = dict(getattr(ref, "columns", None) or ref.attrs)
    g_cols = dict(getattr(got, "columns", None) or got.attrs)
    assert list(r_cols) == list(g_cols), f"column order: {query}"
    for k in r_cols:
        rv, gv = np.asarray(r_cols[k]), np.asarray(g_cols[k])
        assert rv.dtype == gv.dtype, f"{query} [{k}]"
        np.testing.assert_array_equal(rv, gv, err_msg=f"{query} [{k}]")


@pytest.mark.parametrize("query", _FAMILY)
def test_jit_bitwise_parity_per_op(query, monkeypatch):
    pytest.importorskip("jax")
    from repro.stream import shim
    rng = np.random.default_rng(7)
    bd = _deploy(rng)
    before = dict(shim.JOIN_STATS)
    ref = _run(bd, query, "interpreter", monkeypatch)
    mid = dict(shim.JOIN_STATS)
    got = _run(bd, query, "jit", monkeypatch)
    after = dict(shim.JOIN_STATS)
    _assert_identical(ref, got, query)
    st = qc.stats()
    assert st["fallbacks"] == 0, st
    assert st["executions"] >= 1
    # the jit run moves JOIN_STATS exactly as the interpreter run did
    for k in before:
        assert after[k] - mid[k] == mid[k] - before[k], (k, query)


def test_plan_cache_hits_on_second_execution(monkeypatch):
    pytest.importorskip("jax")
    bd = _deploy(np.random.default_rng(8))
    monkeypatch.setenv(qc.BACKEND_ENV, "jit")
    bd.query("bdstream(window(c.p, 32))")
    st = qc.stats()
    assert st["compiles"] == 1 and st["cache_hits"] == 0
    bd.query("bdstream(window(c.p, 32))")
    bd.query("bdstream(window(c.p,   32))")   # normalized: same plan
    st = qc.stats()
    assert st["compiles"] == 1 and st["cache_hits"] == 2


def test_out_of_family_ops_stay_interpreted(monkeypatch):
    bd = _deploy(np.random.default_rng(9))
    monkeypatch.setenv(qc.BACKEND_ENV, "jit")
    bd.query("bdstream(snapshot(c.p))")
    st = qc.stats()
    assert st["interpreted"] == 1
    assert st["fallbacks"] == 0 and st["compiles"] == 0


def test_error_strings_match_interpreter(monkeypatch):
    pytest.importorskip("jax")
    bd = default_deployment()
    bd.register_stream("streamstore0", "c.empty", ("v",), capacity=64)
    msgs = {}
    for backend in ("interpreter", "jit"):
        monkeypatch.setenv(qc.BACKEND_ENV, backend)
        # the executor wraps the StreamException; the *full* wrapped
        # string must match, so the underlying messages are identical
        with pytest.raises(Exception) as exc:
            bd.query("bdstream(window(c.empty, 16))")
        msgs[backend] = str(exc.value)
    assert "no complete window of size 16" in msgs["interpreter"]
    assert msgs["interpreter"] == msgs["jit"]


def test_non_finite_join_keys_fall_back_with_reason(monkeypatch):
    """A compiled join whose *data* defeats it (NaN keys break the
    sorted-search lowering) must fall back to the interpreter and count
    the reason — the jit-parity lane alarms on unexpected fallbacks."""
    pytest.importorskip("jax")
    bd = default_deployment()
    s = bd.register_stream("streamstore0", "c.nan", ("t", "v"),
                           capacity=64)
    t = np.arange(16.0)
    t[3] = np.nan
    s.append({"t": t, "v": np.arange(16.0)})
    q = "bdstream(join(window(c.nan, 16), window(c.nan, 16)," \
        " on=t, tol=0.5))"
    monkeypatch.setenv(qc.BACKEND_ENV, "interpreter")
    ref = bd.query(q).value
    monkeypatch.setenv(qc.BACKEND_ENV, "jit")
    got = bd.query(q).value
    _assert_identical(ref, got, q)        # interpreter served both
    st = qc.stats()
    assert st["fallbacks"] == 1
    assert st["fallback_reasons"] == {"non-finite join keys": 1}


def test_compiled_path_does_not_flip_global_x64(monkeypatch):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    bd = _deploy(np.random.default_rng(11))
    ambient = jnp.asarray(np.zeros(1)).dtype
    monkeypatch.setenv(qc.BACKEND_ENV, "jit")
    out = bd.query("bdstream(window(c.p, 32))").value
    # outputs land in the ambient default dtype and the global default
    # is untouched — the f64 math happened under a *scoped* enable_x64
    assert np.asarray(out.attrs["v"]).dtype == ambient
    assert jnp.asarray(np.zeros(1)).dtype == ambient
    assert not jax.config.jax_enable_x64


def test_backend_env_validation_and_default(monkeypatch):
    monkeypatch.delenv(qc.BACKEND_ENV, raising=False)
    assert qc.backend() == "interpreter"
    monkeypatch.setenv(qc.BACKEND_ENV, "jit")
    assert qc.backend() == "jit"


# -- Pallas kernels vs references --------------------------------------------
def test_window_minmax_kernel_matches_numpy():
    pytest.importorskip("jax")
    if not kernels.AVAILABLE:
        pytest.skip("pallas unavailable")
    import jax.numpy as jnp
    rng = np.random.default_rng(12)
    for w, size in [(1, 4), (5, 16), (8, 8), (13, 32)]:
        vals = rng.normal(size=(w, size))
        for is_max in (False, True):
            got = np.asarray(kernels.window_minmax(
                jnp.asarray(vals), is_max))
            ref = np.asarray(kernels.window_minmax_ref(
                jnp.asarray(vals), is_max))
            exp = vals.max(axis=1) if is_max else vals.min(axis=1)
            np.testing.assert_array_equal(got, exp.astype(got.dtype))
            np.testing.assert_array_equal(got, ref)


def test_join_bounds_kernel_matches_searchsorted():
    pytest.importorskip("jax")
    if not kernels.AVAILABLE:
        pytest.skip("pallas unavailable")
    import jax.numpy as jnp
    rng = np.random.default_rng(13)
    for nl, nr in [(1, 1), (7, 33), (130, 64), (3, 1000)]:
        lt = rng.uniform(0, 100, size=nl)
        rs = np.sort(rng.uniform(0, 100, size=nr))
        # inject exact ties: bisection must break them like searchsorted
        lt[0] = rs[0]
        tol = 1.5
        lo, hi = kernels.join_bounds(
            jnp.asarray(lt), jnp.asarray(rs), tol)
        exp_lo = np.searchsorted(rs, lt - tol, side="left")
        exp_hi = np.searchsorted(rs, lt + tol, side="right")
        np.testing.assert_array_equal(np.asarray(lo), exp_lo)
        np.testing.assert_array_equal(np.asarray(hi), exp_hi)


def test_pallas_enabled_parity(monkeypatch):
    """Full family parity with the Pallas lowerings switched on: the
    kernels must be drop-in bit-identical, not merely close."""
    pytest.importorskip("jax")
    if not kernels.AVAILABLE:
        pytest.skip("pallas unavailable")
    rng = np.random.default_rng(14)
    bd = _deploy(rng)
    monkeypatch.setenv(kernels.PALLAS_ENV, "1")
    for query in ("aggregate(window(c.p, 16), max(v))",
                  "aggregate(window(c.p, 16), min(v))",
                  "join(ewindow(c.s, 20, 10), ewindow(c.s, 20, 10),"
                  " on=ts, tol=0.5)"):
        ref = _run(bd, query, "interpreter", monkeypatch)
        got = _run(bd, query, "jit", monkeypatch)
        _assert_identical(ref, got, query)
    assert qc.stats()["fallbacks"] == 0
