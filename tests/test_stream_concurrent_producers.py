"""Race/stress suite for the multi-producer ingest path: N producer
threads x M batches with barrier starts, producers racing ``flush()``,
a live shard migration mid-ingest, and a standing query ticking
throughout.  Every scenario pins the same invariants the property suite
(tests/test_stream_properties.py) checks sequentially:

  * gathered ``seq`` strictly increasing and gap-free (the committed
    frontier never exposes half a batch),
  * each reserved block contiguous in seq and in producer batch order,
  * ``total_dropped + retained == appended``,
  * watermark monotone, rolling sum == recomputed sum.

The flake-hunter workflow re-runs this file 5x at REPRO_MAX_WORKERS=8
(nightly + stream-path PRs) to shake out lock-order regressions."""
import threading

import numpy as np
import pytest

from repro.core.api import default_deployment
from repro.stream.engine import Stream


def _producer_value(pid: int, batch: int, i: int) -> float:
    """Encode (producer, batch, row) into one float64 so a gathered row
    can be attributed exactly (all components < 1000)."""
    return pid * 1_000_000.0 + batch * 1_000.0 + i


def _check_blocks(values: np.ndarray, batch_rows: int) -> None:
    """Gathered values must decompose into whole batches: contiguous in
    seq, rows in producer order within each block, batches of one
    producer in that producer's send order."""
    assert values.shape[0] % batch_rows == 0
    seen_batches: dict = {}
    for s in range(0, values.shape[0], batch_rows):
        block = values[s:s + batch_rows]
        pid = int(block[0] // 1_000_000)
        batch = int(block[0] // 1_000) % 1_000
        expect = np.array([_producer_value(pid, batch, i)
                           for i in range(batch_rows)])
        np.testing.assert_array_equal(block, expect)
        # batches of one producer appear in send order (the earliest
        # retained batch may be any index when the ring evicted older
        # ones, but later ones must follow consecutively)
        last = seen_batches.get(pid)
        if last is not None:
            assert batch == last + 1, (pid, batch, last)
        seen_batches[pid] = batch


@pytest.mark.parametrize("shard_key", [None, "v"])
def test_barrier_start_producers_keep_seq_gap_free(shard_key):
    """N threads x M batches, all released at once: the gather sees
    every row exactly once, seqs 0..N*M*R-1, each seq block whole."""
    nproducers, nbatches, batch_rows = 6, 30, 64
    bd = default_deployment()
    sh = bd.register_stream(
        "streamstore0", "race.stream", ("v",), capacity=1_000_000,
        shards=4, num_engines=2, block_rows=batch_rows,
        shard_key=shard_key)
    barrier = threading.Barrier(nproducers)
    errors = []

    def feed(pid):
        try:
            with sh.producer(name=f"p{pid}") as producer:
                barrier.wait()
                for b in range(nbatches):
                    producer.append({"v": np.array(
                        [_producer_value(pid, b, i)
                         for i in range(batch_rows)])})
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=feed, args=(pid,))
               for pid in range(nproducers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors and not any(t.is_alive() for t in threads)
    total = nproducers * nbatches * batch_rows
    assert sh.total_appended == total == sh.reserved
    snap = sh.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    np.testing.assert_array_equal(seqs, np.arange(total))
    if shard_key is None:
        # block_rows == batch_rows: every batch is one whole seq block
        _check_blocks(np.asarray(snap.columns["v"], np.float64),
                      batch_rows)
    ic = sh.ingest_concurrency()
    assert ic["producers_peak"] == nproducers
    assert ic["producers_open"] == 0
    assert ic["blocks_reserved"] == nproducers * nbatches
    assert ic["rows_reserved"] == total
    assert ic["in_flight_rows"] == 0
    sh.close()


def test_unsharded_stream_concurrent_appends_and_drop_accounting():
    """Plain Stream under producer contention, with a capacity small
    enough to force drops: batches stay whole (a ring write is one
    ordered commit) and total_dropped + retained == appended."""
    stream = Stream("u.race", ("v",), capacity=512)
    nproducers, nbatches, batch_rows = 5, 40, 32
    barrier = threading.Barrier(nproducers)
    errors = []

    def feed(pid):
        try:
            with stream.producer() as producer:
                barrier.wait()
                for b in range(nbatches):
                    producer.append({"v": np.array(
                        [_producer_value(pid, b, i)
                         for i in range(batch_rows)])})
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=feed, args=(pid,))
               for pid in range(nproducers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors
    total = nproducers * nbatches * batch_rows
    assert stream.total_appended == total
    assert stream.num_rows == 512
    assert stream.total_dropped + stream.num_rows == total
    # the ring holds the newest rows; batches land whole and in order
    snap = stream.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    np.testing.assert_array_equal(seqs, np.arange(total - 512, total))
    _check_blocks(np.asarray(snap.columns["v"], np.float64), batch_rows)


def test_producers_racing_flush_on_event_time_stream():
    """Concurrent producers + concurrent flush() punctuation on a
    key-hashed event-time stream: the watermark stays monotone, no row
    is lost or duplicated, and the final gather is ts-sorted."""
    bd = default_deployment()
    sh = bd.register_stream(
        "streamstore0", "ev.race", ("ts", "k"), capacity=500_000,
        shards=3, num_engines=2, shard_key="k",
        ts_field="ts", max_delay=4.0)
    nproducers, nbatches, batch_rows = 4, 25, 32
    barrier = threading.Barrier(nproducers + 1)
    stop = threading.Event()
    errors = []
    marks = []

    def feed(pid):
        try:
            rng = np.random.default_rng(pid)
            base = 0.0
            barrier.wait()
            for b in range(nbatches):
                ts = base + np.arange(batch_rows, dtype=float)
                base += batch_rows
                order = np.argsort(ts + rng.uniform(-2, 2, batch_rows))
                sh.append({"ts": ts[order],
                           "k": rng.uniform(0, 30, batch_rows)})
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    def flusher():
        barrier.wait()
        while not stop.is_set():
            sh.flush()
            marks.append(sh.watermark)

    threads = [threading.Thread(target=feed, args=(pid,))
               for pid in range(nproducers)]
    ft = threading.Thread(target=flusher)
    for t in threads + [ft]:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    stop.set()
    ft.join(timeout=10.0)
    assert not errors and not ft.is_alive()
    sh.flush()
    # every non-late row exactly once, ts-sorted in the gather
    appended = sh.total_appended
    assert appended + sh.total_late == nproducers * nbatches * batch_rows
    assert sh._pending_rows == 0
    snap = sh.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    np.testing.assert_array_equal(seqs, np.arange(appended))
    ts_col = np.asarray(snap.columns["ts"])
    assert (np.diff(ts_col) >= 0).all()
    # watermark observed by the racing flusher was monotone
    assert all(a <= b for a, b in zip(marks, marks[1:]))
    sh.close()


def test_live_shard_migration_mid_ingest_with_standing_query():
    """The full chaos scenario: barrier-started producers hammer a
    sharded stream while shard 0 ping-pongs between engines and a
    standing snapshot query ticks on its own thread.  No row lost, no
    row duplicated, no standing-query error, seqs gap-free."""
    nproducers, nbatches, batch_rows = 4, 30, 48
    bd = default_deployment()
    sh = bd.register_stream(
        "streamstore0", "mig.race", ("v",), capacity=1_000_000,
        shards=2, num_engines=2, block_rows=16)
    cq = bd.register_continuous("bdstream(snapshot(mig.race))",
                                name="snap")
    barrier = threading.Barrier(nproducers + 2)
    done = threading.Event()
    errors = []

    def feed(pid):
        try:
            with sh.producer() as producer:
                barrier.wait()
                for b in range(nbatches):
                    producer.append({"v": np.array(
                        [_producer_value(pid, b, i)
                         for i in range(batch_rows)])})
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    def ticker():
        barrier.wait()
        while not done.is_set():
            bd.streams.tick()

    moves = []

    def migrator():
        barrier.wait()
        while not done.is_set():
            dest = ("streamstore1"
                    if sh.shard_engines()[0] == "streamstore0"
                    else "streamstore0")
            sh.migrate_shard(0, bd.migrator, bd.engines, dest)
            moves.append(dest)

    threads = [threading.Thread(target=feed, args=(pid,))
               for pid in range(nproducers)]
    tick_t = threading.Thread(target=ticker)
    mig_t = threading.Thread(target=migrator)
    for t in threads + [tick_t, mig_t]:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    done.set()
    tick_t.join(timeout=10.0)
    mig_t.join(timeout=10.0)
    assert not errors
    assert not any(t.is_alive() for t in threads + [tick_t, mig_t])
    assert len(moves) >= 1 and sh.migrations == len(moves)
    total = nproducers * nbatches * batch_rows
    assert sh.total_appended == total == sh.reserved
    snap = sh.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    np.testing.assert_array_equal(seqs, np.arange(total))
    # a batch is one contiguous seq block, so the seq-ordered gather
    # still decomposes into whole batches even across the moves
    _check_blocks(np.asarray(snap.columns["v"], np.float64), batch_rows)
    assert cq.errors == 0 and cq.executions >= 1
    sh.close()


def test_concurrent_rolling_aggregate_matches_recompute():
    """Rolling cumulative sums survive producer contention: after a
    concurrent ingest burst, the O(1) window aggregate equals a cold
    recompute over the materialized window."""
    bd = default_deployment()
    sh = bd.register_stream("streamstore0", "agg.race", ("v",),
                            capacity=100_000, shards=2, num_engines=2,
                            block_rows=8)
    nproducers, nbatches, batch_rows = 4, 20, 40
    barrier = threading.Barrier(nproducers)
    errors = []

    def feed(pid):
        try:
            barrier.wait()
            rng = np.random.default_rng(pid)
            for _ in range(nbatches):
                sh.append({"v": rng.standard_normal(batch_rows)})
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=feed, args=(pid,))
               for pid in range(nproducers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not errors
    size = 1024
    rolling = sh.window_aggregate(size, "sum", "v")
    materialized = float(np.asarray(sh.window(size).attrs["v"],
                                    np.float64).sum())
    # cumulative-ring range sums differ from a cold recompute only by
    # float64 rounding (same tolerance the stream bench asserts)
    assert rolling == pytest.approx(materialized, abs=1e-6)
    sh.close()


def test_single_producer_results_bit_identical_to_serial_reference():
    """One producer through the reservation path must behave exactly
    like PR-3's serial scatter: same append result dicts, same gather,
    zero commit waits."""
    rng = np.random.default_rng(0)
    batches = [rng.standard_normal(37) for _ in range(12)]
    bd_a = default_deployment()
    sh = bd_a.register_stream("streamstore0", "s.one", ("v",),
                              capacity=4096, shards=3, num_engines=2,
                              block_rows=8)
    ref = Stream("ref", ("v",), capacity=4096)
    results = []
    for b in batches:
        results.append((sh.append({"v": b}), ref.append({"v": b})))
    for got, want in results:
        assert got["appended"] == want["appended"]
        assert got["dropped"] == want["dropped"]
        assert got["rows"] == want["rows"]
    np.testing.assert_array_equal(
        np.asarray(sh.snapshot().columns["v"]),
        np.asarray(ref.snapshot().columns["v"]))
    assert sh.ingest_concurrency()["commit_waits"] == 0
    assert ref.ingest_concurrency()["commit_waits"] == 0


def test_readers_see_consistent_cuts_under_concurrent_eviction():
    """Small shard rings + concurrent producers + a racing reader: every
    snapshot is a point-in-time cut (all shard locks held for the
    sweep), so gathered seqs stay strictly increasing and decompose
    into whole batches even while eviction churns the rings."""
    nproducers, nbatches, batch_rows = 3, 60, 32
    bd = default_deployment()
    sh = bd.register_stream(
        "streamstore0", "cut.race", ("v",), capacity=16 * batch_rows,
        shards=2, num_engines=2, block_rows=batch_rows)
    barrier = threading.Barrier(nproducers + 1)
    done = threading.Event()
    errors = []

    def feed(pid):
        try:
            with sh.producer() as producer:
                barrier.wait()
                for b in range(nbatches):
                    producer.append({"v": np.array(
                        [_producer_value(pid, b, i)
                         for i in range(batch_rows)])})
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    def reader():
        try:
            barrier.wait()
            while not done.is_set():
                snap = sh.snapshot()
                seqs = np.asarray(snap.columns["seq"])
                if seqs.size == 0:
                    continue
                assert (np.diff(seqs) > 0).all(), "seqs not increasing"
                values = np.asarray(snap.columns["v"], np.float64)
                # whole batches only: each retained seq block is one
                # producer's batch, read in one consistent cut
                for s in range(0, values.shape[0], batch_rows):
                    block = values[s:s + batch_rows]
                    if block.shape[0] < batch_rows:
                        continue
                    pid = int(block[0] // 1_000_000)
                    batch = int(block[0] // 1_000) % 1_000
                    np.testing.assert_array_equal(block, np.array(
                        [_producer_value(pid, batch, i)
                         for i in range(batch_rows)]))
        except Exception as exc:                          # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=feed, args=(pid,))
               for pid in range(nproducers)]
    rt = threading.Thread(target=reader)
    for t in threads + [rt]:
        t.start()
    for t in threads:
        t.join(timeout=60.0)
    done.set()
    rt.join(timeout=10.0)
    assert not errors, errors
    total = nproducers * nbatches * batch_rows
    assert sh.total_appended == total
    assert sh.total_dropped + sh.num_rows == total
    sh.close()


def test_hard_killed_producer_cannot_stall_a_shard_lane():
    """PR-5 carry-over regression: a producer that reserves a seq block
    (taking commit tickets on its shard lanes) and then dies without
    ever staging must not wedge the ordered committer.  After one full
    stall interval with zero lane progress the committer *steals* the
    dead tickets, the frontier reaps the abandoned block as a permanent
    hole (staging-failure semantics), a live producer sails through,
    and a revived zombie commit raises instead of double-advancing."""
    import time

    bd = default_deployment()
    s = bd.register_stream("streamstore0", "kill.s", ("v",),
                           capacity=4096, shards=2, num_engines=2,
                           block_rows=4)
    s.append({"v": np.arange(16.0)})          # healthy first batch

    # simulate the hard kill: reserve seqs + tickets, never stage/commit
    with s._reserve_lock:
        t = s.reserved
        n = 8
        s.reserved += n
        touched = s._touched_shards(t, n)
        tickets = {i: s._committers[i].issue() for i in touched}
        s.blocks_reserved += 1
        s.rows_reserved += n
    with s._frontier:
        s._pending_blocks[t] = (n, dict(tickets))
    for c in s._committers:
        c.stall_timeout = 0.2                 # keep the test fast

    done = {}

    def live():
        t0 = time.monotonic()
        s.append({"v": np.arange(100.0, 124.0)})
        done["dt"] = time.monotonic() - t0

    th = threading.Thread(target=live)
    th.start()
    th.join(timeout=30.0)
    assert not th.is_alive(), "live producer stalled behind dead block"
    # bounded by a couple of stall intervals, not forever
    assert done["dt"] < 10.0, done

    ic = s.ingest_concurrency()
    assert ic["commit_steals"] > 0, ic
    assert ic["blocks_abandoned"] == 1, ic
    snap = s.snapshot()
    seqs = np.asarray(snap.columns["seq"])
    assert s.total_appended == 16 + 8 + 24    # hole still counted
    assert seqs[-1] == s.total_appended - 1   # live batch visible
    assert (np.diff(seqs) > 0).all()
    # the hole is exactly the dead block: those seqs never materialize
    assert not np.isin(np.arange(16, 24), seqs).any()

    # a revived zombie must get an error, not a double lane-advance
    from repro.stream.engine import StreamException
    with pytest.raises(StreamException, match="stolen after"):
        s._committers[touched[0]].commit(tickets[touched[0]],
                                         lambda: None)
    s.close()
