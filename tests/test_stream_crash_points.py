"""Hypothesis crash-point property suite: the generalized form of the
exhaustive sweep in tests/test_stream_durability.py.

One strategy draws the whole experiment — an ingest schedule (batch
sizes/values from a drawn seed), a checkpoint cadence (which appends
are followed by a blocking checkpoint), and a crash countdown ``k`` —
then the test arms ``runtime.fault`` so the k-th crash site reached
(segment-log write boundaries, checkpoint begin/promote/gc/prune)
raises ``SimulatedCrash`` mid-workload.  The property is the house
invariant of the durability layer:

  recover() ≡ some prefix of the uncrashed run, and replaying the
  remaining schedule from that prefix reconverges **bit-identically**
  to the uncrashed final state (fingerprints compare counters,
  watermarks, exact ring bytes, pending buffers, dead letters).

Shrinking note (the "custom shrinker" is strategy design, not a
Hypothesis hook): every component is ordered so default shrinking
minimizes failures — ``k`` shrinks toward 1, i.e. the EARLIEST crash
site that exhibits the bug; the schedule shrinks toward fewer/smaller
batches and zero checkpoints; the value seed toward 0.  A ``k`` larger
than the workload's crash surface simply never fires, which doubles as
the uncrashed control case (and is why ``k`` needs no upper coupling
to the drawn schedule).

``REPRO_CRASH_EXAMPLES`` scales example counts (default 40; the
acceptance bar is 200 locally, CI pins a derandomized subset).  Skips
cleanly when hypothesis is not installed (CI installs the [property]
extra).  Registered in the flake-hunter 5x matrix.
"""
import os
import shutil
import tempfile

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.runtime import fault  # noqa: E402
from repro.stream import durability as dur  # noqa: E402
from repro.stream.engine import (SEQ_FIELD, ShardedStream,  # noqa: E402
                                 Stream)

EXAMPLES = int(os.environ.get("REPRO_CRASH_EXAMPLES", "40"))
COMMON = dict(deadline=None, derandomize=bool(os.environ.get("CI")),
              suppress_health_check=[HealthCheck.too_slow])


@pytest.fixture(autouse=True)
def _disarm():
    yield
    fault.disarm_crash_points()


@st.composite
def plain_experiment(draw):
    nops = draw(st.integers(min_value=2, max_value=7))
    sizes = draw(st.lists(st.integers(1, 40), min_size=nops,
                          max_size=nops))
    ckpt_after = sorted(draw(st.sets(st.integers(0, nops - 1),
                                     max_size=2)))
    seed = draw(st.integers(0, 2 ** 16))
    k = draw(st.integers(min_value=1, max_value=60))
    return sizes, ckpt_after, seed, k


def _values(seed, sizes):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=n) for n in sizes]


def _run_plain(directory, batches, ckpt_after, capacity):
    s = Stream("t", ("a",), capacity)
    h = dur.attach(s, directory)
    for i, v in enumerate(batches):
        s.append({"a": v})
        if i in ckpt_after:
            h.checkpoint()
    return s


@settings(max_examples=EXAMPLES, **COMMON)
@given(exp=plain_experiment())
def test_plain_crash_recover_replay_bit_identical(exp):
    sizes, ckpt_after, seed, k = exp
    batches = _values(seed, sizes)
    capacity = 32

    ref = Stream("t", ("a",), capacity)
    snaps = [dur.fingerprint(ref)]
    for v in batches:
        ref.append({"a": v})
        snaps.append(dur.fingerprint(ref))

    d = tempfile.mkdtemp(prefix="crashprop_")
    try:
        fault.arm_crash_point("stream/*", at_hit=k)
        crashed = False
        try:
            _run_plain(d, batches, ckpt_after, capacity)
        except fault.SimulatedCrash:
            crashed = True
        report = fault.disarm_crash_points()
        assert crashed == (report["fired"] is not None)

        r = dur.recover(d)
        fp = dur.fingerprint(r.stream)
        assert fp in snaps, \
            f"fired={report['fired']}: recovered state matches no prefix"
        p = snaps.index(fp)
        if not crashed:
            assert p == len(batches)       # control case: nothing lost
        dur.attach(r.stream, d)
        for v in batches[p:]:
            r.stream.append({"a": v})
        assert dur.fingerprint(r.stream) == snaps[-1]
        # the continuation's own log is consistent too
        assert dur.fingerprint(dur.recover(d).stream) == snaps[-1]
    finally:
        fault.disarm_crash_points()
        shutil.rmtree(d, ignore_errors=True)


@st.composite
def sharded_experiment(draw):
    nshards = draw(st.integers(2, 3))
    nops = draw(st.integers(2, 6))
    sizes = draw(st.lists(st.integers(1, 30), min_size=nops,
                          max_size=nops))
    ckpt_after = sorted(draw(st.sets(st.integers(0, nops - 1),
                                     max_size=2)))
    block_rows = draw(st.sampled_from([4, 8]))
    seed = draw(st.integers(0, 2 ** 16))
    k = draw(st.integers(min_value=1, max_value=80))
    return nshards, sizes, ckpt_after, block_rows, seed, k


def _mk_sharded(nshards, block_rows):
    shards = [(f"e{i}", Stream(f"w@shard{i}", ("a", SEQ_FIELD), 128))
              for i in range(nshards)]
    return ShardedStream("w", ("a",), shards, block_rows=block_rows)


@settings(max_examples=EXAMPLES, **COMMON)
@given(exp=sharded_experiment())
def test_sharded_crash_recover_replay_bit_identical(exp):
    nshards, sizes, ckpt_after, block_rows, seed, k = exp
    batches = _values(seed, sizes)

    ref = _mk_sharded(nshards, block_rows)
    snaps = [dur.fingerprint(ref)]
    for v in batches:
        ref.append({"a": v})
        snaps.append(dur.fingerprint(ref))

    d = tempfile.mkdtemp(prefix="crashprop_")
    try:
        ss = _mk_sharded(nshards, block_rows)
        h = dur.attach(ss, d)
        fault.arm_crash_point("stream/*", at_hit=k)
        try:
            for i, v in enumerate(batches):
                ss.append({"a": v})
                if i in ckpt_after:
                    h.checkpoint()
        except fault.SimulatedCrash:
            pass
        fault.disarm_crash_points()

        r = dur.recover(d)
        fp = dur.fingerprint(r.stream)
        assert fp in snaps, "recovered state matches no append prefix"
        p = snaps.index(fp)
        dur.attach(r.stream, d)
        for v in batches[p:]:
            r.stream.append({"a": v})
        assert dur.fingerprint(r.stream) == snaps[-1]
        assert dur.fingerprint(dur.recover(d).stream) == snaps[-1]
    finally:
        fault.disarm_crash_points()
        shutil.rmtree(d, ignore_errors=True)


@st.composite
def event_time_experiment(draw):
    nops = draw(st.integers(2, 6))
    sizes = draw(st.lists(st.integers(1, 16), min_size=nops,
                          max_size=nops))
    # bounded disorder: each batch's timestamps jitter within max_delay
    max_delay = draw(st.sampled_from([1.0, 4.0]))
    late_at = draw(st.one_of(st.none(), st.integers(1, nops - 1)))
    flush_end = draw(st.booleans())
    ckpt_after = sorted(draw(st.sets(st.integers(0, nops - 1),
                                     max_size=2)))
    seed = draw(st.integers(0, 2 ** 16))
    k = draw(st.integers(min_value=1, max_value=60))
    return (sizes, max_delay, late_at, flush_end, ckpt_after, seed, k)


def _event_batches(seed, sizes, max_delay, late_at):
    """Monotone-ish timestamps with jitter < max_delay, plus one
    definitely-late row injected mid-schedule when drawn."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for i, n in enumerate(sizes):
        ts = t + np.arange(n) + rng.uniform(0, max_delay * 0.9, n)
        t += n
        if late_at is not None and i == late_at:
            ts = np.concatenate([ts, [0.0]])     # below any watermark
        out.append({"ts": ts, "v": rng.normal(size=ts.shape[0])})
    return out, t


def _run_event(directory, batches, ckpt_after, max_delay, flush_to,
               sink):
    s = Stream("e", ("ts", "v"), 64, ts_field="ts",
               max_delay=max_delay)
    if sink:
        s._late_sink = Stream("e.__late", ("ts", "v"), 64)
    h = dur.attach(s, directory) if directory is not None else None
    for i, cols in enumerate(batches):
        s.append(cols)
        if h is not None and i in ckpt_after:
            h.checkpoint()
    if flush_to is not None:
        s.flush(flush_to)
    return s


@settings(max_examples=EXAMPLES, **COMMON)
@given(exp=event_time_experiment())
def test_event_time_crash_preserves_watermark_and_dead_letters(exp):
    sizes, max_delay, late_at, flush_end, ckpt_after, seed, k = exp
    batches, t_end = _event_batches(seed, sizes, max_delay, late_at)
    flush_to = t_end + max_delay if flush_end else None

    # reference: fingerprint after every append (and the final flush)
    ref = _run_event(None, [], [], max_delay, None, sink=True)
    snaps = [dur.fingerprint(ref)]
    for cols in batches:
        ref.append(cols)
        snaps.append(dur.fingerprint(ref))
    if flush_to is not None:
        ref.flush(flush_to)
        snaps.append(dur.fingerprint(ref))

    d = tempfile.mkdtemp(prefix="crashprop_")
    try:
        fault.arm_crash_point("stream/*", at_hit=k)
        try:
            _run_event(d, batches, ckpt_after, max_delay, flush_to,
                       sink=True)
        except fault.SimulatedCrash:
            pass
        fault.disarm_crash_points()

        r = dur.recover(d)
        fp = dur.fingerprint(r.stream)
        assert fp in snaps, "recovered state matches no prefix"
        p = snaps.index(fp)
        dur.attach(r.stream, d)
        for cols in batches[p:len(batches)]:
            r.stream.append(cols)
        if flush_to is not None:
            r.stream.flush(flush_to)
        assert dur.fingerprint(r.stream) == snaps[-1]
    finally:
        fault.disarm_crash_points()
        shutil.rmtree(d, ignore_errors=True)
